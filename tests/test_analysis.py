"""repro.analysis: static pre-flight validator + journal sanitizer.

Covers every diagnostic code in ``diagnostics.CODES`` with one triggering
fixture AND a clean twin (the nearby spec that must NOT trigger it), the
AppManager/PilotRuntime wiring (``validate=``, ``sanitize=True``), the CLI,
and a property test: any randomly generated pipeline set the validator
accepts must complete in sim mode without deadlock (and any set that
deadlocks must have been rejected).
"""
import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import (CODES, DiagnosticError, JournalSanitizer,
                            sanitize_file, validate_app)
from repro.analysis.__main__ import main as analysis_cli
from repro.core import AppManager, Channel, Kernel, PipelineSpec, Stage, \
    TaskSpec
from repro.dist.topology import SlotTopology
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal, journal_from_env
from repro.staging import LocalityMap, StagingLayer


def _noop(duration=0.01, **attrs):
    k = Kernel("synthetic.noop")
    k.sim_duration = duration
    for name, v in attrs.items():
        setattr(k, name, v)
    return k


def _chain(name="p", n_stages=1, outputs=None, inputs=None):
    return PipelineSpec(
        [Stage([TaskSpec(_noop())], name=f"s{i}",
               outputs=outputs, inputs=inputs)
         for i in range(n_stages)], name=name)


# ===================================================== validator: E codes

def test_clean_app_has_no_findings():
    ch = Channel("t1")
    prod = _chain("prod", 2, outputs=[ch])
    cons = _chain("cons", 2, inputs={"x": ch})
    report = validate_app([prod, cons])
    assert report.ok and not report.diagnostics


def test_single_pipelinespec_accepted():
    assert validate_app(_chain()).ok


def test_e101_port_type_mismatch():
    ch = Channel("typed", dtype=int)
    bad = PipelineSpec([Stage([TaskSpec(_noop(output_dtype=str))],
                              name="s0", outputs=[ch])], name="p")
    assert "E101" in validate_app([bad]).codes()
    ok = PipelineSpec([Stage([TaskSpec(_noop(output_dtype=bool))],
                             name="s0", outputs=[Channel("typed2",
                                                         dtype=int)])],
                      name="p")
    assert "E101" not in validate_app([ok]).codes()  # bool <: int


def test_e101_task_level_output():
    ch = Channel("typed3", dtype=int)
    bad = PipelineSpec(
        [Stage([TaskSpec(_noop(output_dtype=str), outputs=[ch])],
               name="s0")], name="p")
    assert "E101" in validate_app([bad]).codes()


def test_e102_channel_without_producer():
    orphan = Channel("orphan")
    report = validate_app([_chain("c", inputs={"x": orphan})])
    assert report.codes() == ["E102"]
    # clean twin: the same shape with a producer
    ch = Channel("fed")
    report = validate_app([_chain("p", outputs=[ch]),
                           _chain("c", inputs={"x": ch})])
    assert "E102" not in report.codes()


def test_e102_preseeded_channel_is_fine():
    ch = Channel("seeded")
    ch.put("warm", 1)
    assert validate_app([_chain("c", inputs={"x": ch})],
                        channels={"seeded": ch}).ok


def test_e103_future_of_unknown_stage():
    orphan = Stage([TaskSpec(_noop())], name="elsewhere")
    report = validate_app([_chain("c", inputs={"x": orphan.future()})])
    assert "E103" in report.codes()
    # clean twin: a future of a stage in a submitted sibling pipeline
    prod = _chain("prod")
    cons = _chain("cons", inputs={"x": prod.stages[0].future()})
    assert validate_app([prod, cons]).ok


def test_e104_ensemble_cycle():
    a, b = Channel("a2b"), Channel("b2a")
    pa = PipelineSpec([Stage([TaskSpec(_noop())], name="s0",
                             inputs={"x": b}, outputs=[a])], name="A")
    pb = PipelineSpec([Stage([TaskSpec(_noop())], name="s0",
                             inputs={"x": a}, outputs=[b])], name="B")
    codes = validate_app([pa, pb]).codes()
    assert "E104" in codes and "E106" not in codes


def test_e105_starved_consumer():
    ch = Channel("short")
    prod = _chain("prod", 1, outputs=[ch])          # one put
    cons = _chain("cons", 3, inputs={"x": ch})      # needs three
    report = validate_app([prod, cons])
    assert "E105" in report.codes()
    assert validate_app([_chain("prod", 3, outputs=[ch]),
                         _chain("cons", 3, inputs={"x": ch})]).ok


def test_e106_wedged_producer_no_consumer():
    ch = Channel("narrow", capacity=1)
    prod = _chain("prod", 2, outputs=[ch])
    codes = validate_app([prod]).codes()
    assert "E106" in codes
    # clean twin: a consumer that drains between puts
    ch2 = Channel("drained", capacity=1)
    report = validate_app([_chain("prod", 2, outputs=[ch2]),
                           _chain("cons", 2, inputs={"x": ch2})])
    assert report.ok


def test_e106_capacity_deadlock_cycle():
    data = Channel("data", capacity=1)
    gate = Channel("gate")
    prod = PipelineSpec(
        [Stage([TaskSpec(_noop())], name="p0", outputs=[data]),
         Stage([TaskSpec(_noop())], name="p1", outputs=[data]),
         Stage([TaskSpec(_noop())], name="p2", outputs=[gate])], name="P")
    cons = PipelineSpec(
        [Stage([TaskSpec(_noop())], name="c0",
               inputs={"g": gate, "d": data})], name="C")
    report = validate_app([prod, cons])
    assert "E106" in report.codes()
    # only the root cause is reported, not one finding per parked pipeline
    assert len(report.errors) == 1


def test_e107_unknown_kernel_name():
    bad = PipelineSpec([Stage([TaskSpec("no.such.kernel")], name="s0")],
                       name="p")
    report = validate_app([bad])
    assert "E107" in report.codes()
    d = next(d for d in report.diagnostics if d.code == "E107")
    assert d.pipeline == "p" and d.stage == 0
    ok = PipelineSpec([Stage([TaskSpec("synthetic.noop")], name="s0")],
                      name="p")
    assert "E107" not in validate_app([ok]).codes()


def test_e108_slots_unsatisfiable_vs_w202_recarve():
    topo = SlotTopology.even(range(8), 2, axis_names=("data",))
    rt = PilotRuntime(topology=topo, mode="sim")   # 2 slots, growable to 8
    too_wide = _chain("p")
    too_wide.stages[0].tasks[0].kernel.cores = 16
    assert "E108" in validate_app([too_wide], runtime=rt).codes()
    growable = _chain("p")
    growable.stages[0].tasks[0].kernel.cores = 8
    codes = validate_app([growable], runtime=rt).codes()
    assert "W202" in codes and "E108" not in codes


def test_e108_sharding_blocks_model_axis_split():
    # splitting the leading "model" axis would invalidate tp placements,
    # so the only reachable width is the current 2 slots
    topo = SlotTopology.even(range(8), 2, axis_names=("model",))
    rt = PilotRuntime(topology=topo, mode="sim")
    p = _chain("p")
    p.stages[0].tasks[0].kernel.cores = 4
    assert "E108" in validate_app([p], runtime=rt).codes()


def test_e109_staging_overflow_vs_w204_spill(tmp_path):
    def run_with(spill_dir):
        staging = StagingLayer(locality=LocalityMap(2, slots_per_pod=1),
                               threshold_bytes=1, byte_budget=100,
                               spill_dir=spill_dir)
        rt = PilotRuntime(slots=2, mode="real", staging=staging)
        p = _chain("p")
        p.stages[0].tasks[0].kernel.output_nbytes = 1000
        return validate_app([p], runtime=rt).codes()

    assert "E109" in run_with(None)
    codes = run_with(str(tmp_path / "spill"))
    assert "W204" in codes and "E109" not in codes


def test_e109_not_raised_in_sim_mode():
    staging = StagingLayer(locality=LocalityMap(2, slots_per_pod=1),
                           threshold_bytes=1, byte_budget=100)
    rt = PilotRuntime(slots=2, mode="sim", staging=staging)
    p = _chain("p")
    p.stages[0].tasks[0].kernel.output_nbytes = 1000
    assert validate_app([p], runtime=rt).ok    # virtual blobs: no memory


def test_e110_two_channels_one_name():
    report = validate_app([_chain("p", outputs=[Channel("same")]),
                           _chain("c", inputs={"x": Channel("same")})])
    assert "E110" in report.codes()
    shared = Channel("same2")
    assert "E110" not in validate_app(
        [_chain("p", outputs=[shared]),
         _chain("c", inputs={"x": shared})]).codes()


def test_e111_duplicate_pipeline_name():
    assert "E111" in validate_app([_chain("twin"),
                                   _chain("twin")]).codes()
    assert "E111" in validate_app([_chain("prior")],
                                  existing_pipelines=["prior"]).codes()
    assert validate_app([_chain("one"), _chain("two")]).ok


def test_e112_duplicate_task_names():
    p = PipelineSpec([Stage([TaskSpec(_noop(), name="dup"),
                             TaskSpec(_noop(), name="dup")],
                            name="s0")], name="p")
    assert "E112" in validate_app([p]).codes()
    q = PipelineSpec([Stage([TaskSpec(_noop(), name="t0"),
                             TaskSpec(_noop(), name="t1")],
                            name="s0")], name="p")
    assert validate_app([q]).ok


def test_e113_malformed_ports():
    p = PipelineSpec([Stage([TaskSpec(_noop())], name="s0", inputs=42)],
                     name="p")
    report = validate_app([p])
    assert "E113" in report.codes()
    q = PipelineSpec([Stage([TaskSpec(_noop())], name="s0",
                            inputs={"x": "not-a-channel"})], name="p")
    assert "E113" in validate_app([q]).codes()


def test_e115_unknown_sla_class():
    bad = PipelineSpec([Stage([TaskSpec(_noop(), sla="gold")], name="s0")],
                       name="p")
    report = validate_app([bad])
    assert "E115" in report.codes()
    d = next(d for d in report.diagnostics if d.code == "E115")
    assert d.pipeline == "p" and d.stage == 0
    ok = PipelineSpec([Stage([TaskSpec(_noop(), sla="latency"),
                              TaskSpec(_noop())], name="s0")], name="p")
    assert "E115" not in validate_app([ok]).codes()


def test_e115_capacity_bytes_without_staging():
    def codes(staging):
        ch = Channel("meter", capacity_bytes=1 << 20)
        prod = _chain("prod", outputs=[ch])
        prod.stages[0].tasks[0].kernel.output_nbytes = 100
        cons = _chain("cons", inputs={"x": ch})
        rt = PilotRuntime(slots=2, mode="sim", staging=staging)
        return validate_app([prod, cons], runtime=rt).codes()

    assert "E115" in codes(None)
    assert "E115" not in codes(
        StagingLayer(locality=LocalityMap(2, slots_per_pod=1)))


def test_e115_submit_time_guards():
    bad = PipelineSpec([Stage([TaskSpec(_noop(), sla="gold")], name="s0")],
                       name="p")
    with pytest.raises(DiagnosticError):     # runtime guard, not the linter
        AppManager(PilotRuntime(slots=2, mode="sim")).run(
            bad, validate="off")
    metered = _chain("prod", outputs=[Channel("m2", capacity_bytes=64)])
    with pytest.raises(DiagnosticError):     # bytes need a staging layer
        AppManager(PilotRuntime(slots=2, mode="sim")).run(
            metered, validate="off")


def test_e106_byte_capacity_wedges_producer():
    ch = Channel("bmeter", capacity_bytes=100)
    prod = PipelineSpec(
        [Stage([TaskSpec(_noop(output_nbytes=80))], name=f"s{i}",
               outputs=[ch]) for i in range(2)], name="prod")
    assert "E106" in validate_app([prod]).codes()
    # drained twin: a consumer retires the first put's bytes in time
    ch2 = Channel("bmeter2", capacity_bytes=100)
    prod2 = PipelineSpec(
        [Stage([TaskSpec(_noop(output_nbytes=80))], name=f"s{i}",
               outputs=[ch2]) for i in range(2)], name="prod")
    cons = _chain("cons", 2, inputs={"x": ch2})
    assert "E106" not in validate_app([prod2, cons]).codes()


# ===================================================== validator: W codes

def test_w201_unconsumed_fifo_channel():
    report = validate_app([_chain("p", outputs=[Channel("drop")])])
    assert report.codes() == ["W201"] and report.ok
    # broadcast channels legitimately outlive any declared consumer set
    report = validate_app(
        [_chain("p", outputs=[Channel("bc", mode="broadcast")])])
    assert "W201" not in report.codes()


def test_w202_wider_than_abstract_pilot():
    rt = PilotRuntime(slots=2, mode="sim")
    p = _chain("p")
    p.stages[0].tasks[0].kernel.cores = 4
    codes = validate_app([p], runtime=rt).codes()
    assert "W202" in codes and "E108" not in codes     # resize can grant it
    assert validate_app([_chain("p")], runtime=rt).ok


def test_w203_retries_exceed_pods():
    staging = StagingLayer(locality=LocalityMap(4, slots_per_pod=2))
    rt = PilotRuntime(slots=4, mode="sim", staging=staging, max_retries=5)
    assert "W203" in validate_app([_chain("p")], runtime=rt).codes()
    rt2 = PilotRuntime(slots=4, mode="sim",
                       staging=StagingLayer(
                           locality=LocalityMap(4, slots_per_pod=2)),
                       max_retries=1)
    assert "W203" not in validate_app([_chain("p")], runtime=rt2).codes()


def test_w203_skipped_without_pod_tracking():
    rt = PilotRuntime(slots=2, mode="sim", max_retries=9)
    assert "W203" not in validate_app([_chain("p")], runtime=rt).codes()


def test_w206_latency_starvation_risk():
    lonely = PipelineSpec([Stage([TaskSpec(_noop(), sla="latency")],
                                 name="s0")], name="p")
    report = validate_app([lonely])
    assert "W206" in report.codes() and report.ok
    # clean twin: any lower-priority task gives preemption a victim pool
    mixed = [PipelineSpec([Stage([TaskSpec(_noop(), sla="latency")],
                                 name="s0")], name="p"),
             _chain("bulk")]
    assert "W206" not in validate_app(mixed).codes()
    # throughput-only apps have nothing to starve
    assert "W206" not in validate_app(
        [PipelineSpec([Stage([TaskSpec(_noop(), sla="throughput")],
                             name="s0")], name="p")]).codes()


# ===================================================== sanitizer: S codes

def _scheduled(task="t", attempts=1, **kw):
    return {"event": "scheduled", "task": task, "attempts": attempts, **kw}


def _finished(task="t", attempts=1, **kw):
    return {"event": "finished", "task": task, "state": "DONE",
            "attempts": attempts, **kw}


def test_s301_epoch_regression():
    san = JournalSanitizer()
    san.observe(_scheduled(attempts=2))
    san.observe(_scheduled(attempts=2))
    assert san.report.codes() == ["S301"]
    clean = JournalSanitizer()
    clean.observe(_scheduled(attempts=1))
    clean.observe(_scheduled(attempts=2))
    assert clean.finalize().ok


def test_s301_segment_reset_allows_fresh_epochs():
    san = JournalSanitizer()
    san.observe(_scheduled(attempts=2))
    san.observe({"event": "session_start"})     # restart: epochs reset
    san.observe(_scheduled(attempts=1))
    assert san.finalize().ok


def test_s302_zombie_clobber():
    san = JournalSanitizer()
    san.observe(_scheduled(attempts=1))
    san.observe({"event": "pod_lost", "task": "t", "attempts": 1})
    san.observe(_finished(attempts=1))
    assert "S302" in san.report.codes()
    clean = JournalSanitizer()
    clean.observe(_scheduled(attempts=1))
    clean.observe({"event": "pod_lost", "task": "t", "attempts": 1})
    clean.observe(_scheduled(attempts=2))
    clean.observe(_finished(attempts=2))        # the RETRY finished: fine
    assert clean.finalize().ok


def test_s302_speculative_supersession_is_legal():
    san = JournalSanitizer()
    san.observe(_scheduled(attempts=1))
    san.observe({"event": "canceled", "task": "t", "attempts": 1})
    san.observe(_finished(attempts=1, by="speculative"))
    assert san.finalize().ok


def test_s303_double_release():
    san = JournalSanitizer()
    san.observe(_scheduled(staged=["d1"]))
    san.observe({"event": "staged_release", "task": "t", "digests": ["d1"]})
    san.observe({"event": "staged_release", "task": "t", "digests": ["d1"]})
    assert "S303" in san.report.codes()


def test_s303_missing_release_found_at_finalize():
    san = JournalSanitizer()
    san.observe(_scheduled(staged=["d1"]))
    san.observe(_finished())
    assert san.report.ok                 # terminal record comes FIRST...
    assert "S303" in san.finalize().codes()   # ...closure is post-hoc
    clean = JournalSanitizer()
    clean.observe(_scheduled(staged=["d1"]))
    clean.observe(_finished())
    clean.observe({"event": "staged_release", "task": "t",
                   "digests": ["d1"]})
    assert clean.finalize().ok


def test_s304_take_without_put():
    san = JournalSanitizer()
    san.observe({"event": "channel_take", "channel": "c",
                 "producer": "ghost", "consumer": "x"})
    assert "S304" in san.report.codes()


def test_s304_fifo_double_consume():
    san = JournalSanitizer()
    san.observe({"event": "channel_put", "channel": "c", "producer": "p0",
                 "mode": "fifo"})
    san.observe({"event": "channel_take", "channel": "c", "producer": "p0",
                 "consumer": "a"})
    san.observe({"event": "channel_take", "channel": "c", "producer": "p0",
                 "consumer": "b"})
    assert "S304" in san.report.codes()
    # broadcast fan-out of one put to N consumers is the designed behavior
    bc = JournalSanitizer()
    bc.observe({"event": "channel_put", "channel": "c", "producer": "p0",
                "mode": "broadcast"})
    bc.observe({"event": "channel_take", "channel": "c", "producer": "p0",
                "consumer": "a"})
    bc.observe({"event": "channel_take", "channel": "c", "producer": "p0",
                "consumer": "b"})
    assert bc.finalize().ok
    # replayed take of the SAME consumer (restart) is also legal
    rp = JournalSanitizer()
    rp.observe({"event": "channel_put", "channel": "c", "producer": "p0",
                "mode": "fifo"})
    rp.observe({"event": "channel_take", "channel": "c", "producer": "p0",
                "consumer": "a"})
    rp.observe({"event": "channel_take", "channel": "c", "producer": "p0",
                "consumer": "a"})
    assert rp.finalize().ok


def test_s305_attempt_gap():
    san = JournalSanitizer()
    san.observe(_scheduled(attempts=1))
    san.observe(_scheduled(attempts=3))
    assert "S305" in san.report.codes()


def test_s306_sim_interval_mismatch():
    san = JournalSanitizer()
    san.observe(_scheduled())
    san.observe(_finished(t_exec=2.0, t_data=0.0,
                          v_started=0.0, v_finished=1.0))
    assert "S306" in san.report.codes()
    clean = JournalSanitizer()
    clean.observe(_scheduled())
    clean.observe(_finished(t_exec=1.5, t_data=0.5,
                            v_started=0.0, v_finished=2.0))
    assert clean.finalize().ok


def test_s306_real_exec_data_overlap():
    san = JournalSanitizer()
    san.observe(_scheduled())
    san.observe(_finished(t_exec=2.0, t_data_kernel=0.5, wall=1.0))
    assert "S306" in san.report.codes()
    clean = JournalSanitizer()
    clean.observe(_scheduled())
    clean.observe(_finished(t_exec=0.6, t_data_kernel=0.3, wall=1.0))
    assert clean.finalize().ok


def test_sanitizer_strict_raises_at_violation():
    san = JournalSanitizer(strict=True)
    san.observe(_scheduled(attempts=2))
    with pytest.raises(DiagnosticError) as ei:
        san.observe(_scheduled(attempts=2))
    assert ei.value.diagnostics[0].code == "S301"


def test_sanitize_file_skips_torn_lines(tmp_path):
    path = tmp_path / "j.jsonl"
    path.write_text(json.dumps(_scheduled()) + "\n"
                    + json.dumps(_finished()) + "\n"
                    + '{"task": "t2", "ev')          # torn crash line
    assert sanitize_file(str(path)).ok


# ===================================================== runtime integration

def test_real_run_journal_sanitizes_clean(tmp_path):
    path = str(tmp_path / "run.jsonl")
    ch = Channel("t")
    rt = PilotRuntime(slots=2, mode="sim", journal=Journal(path))
    prof = AppManager(rt).run([_chain("prod", 2, outputs=[ch]),
                               _chain("cons", 2, inputs={"x": ch})])
    assert prof.n_failed == 0
    report = sanitize_file(path)
    assert report.ok, report.format()


def test_live_sanitizer_accepts_clean_run():
    rt = PilotRuntime(slots=2, mode="sim", sanitize=True)
    prof = AppManager(rt).run(_chain("p", 2))
    assert prof.n_failed == 0 and rt.sanitizer.n_records > 0


def test_live_sanitizer_primes_existing_journal(tmp_path):
    path = str(tmp_path / "j.jsonl")
    AppManager(PilotRuntime(slots=2, mode="sim",
                            journal=Journal(path))).run(_chain("p", 2))
    # restart over the same journal with live checking: replayed state
    # must not be reported as violations
    rt = PilotRuntime(slots=2, mode="sim", journal=Journal(path),
                      sanitize=True)
    prof = AppManager(rt).run(_chain("p", 2))
    assert prof.n_failed == 0 and rt.sanitizer.report.ok


def test_run_validate_error_rejects_deadlock_before_launch():
    data = Channel("d", capacity=1)
    gate = Channel("g")
    prod = PipelineSpec(
        [Stage([TaskSpec(_noop())], name="p0", outputs=[data]),
         Stage([TaskSpec(_noop())], name="p1", outputs=[data]),
         Stage([TaskSpec(_noop())], name="p2", outputs=[gate])], name="P")
    cons = PipelineSpec(
        [Stage([TaskSpec(_noop())], name="c0",
               inputs={"g": gate, "d": data})], name="C")
    am = AppManager(PilotRuntime(slots=2, mode="sim"))
    with pytest.raises(DiagnosticError) as ei:
        am.run([prod, cons], validate="error")
    assert any(d.code == "E106" for d in ei.value.diagnostics)
    # nothing launched, nothing registered: the manager is untouched
    assert am.session is None and not am.pipeline_runs


def test_run_validate_warn_proceeds_and_records(capsys):
    orphan = Channel("nope")
    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run(
        [_chain("c", inputs={"x": orphan})], validate="warn")
    assert any("E102" in d for d in prof.results["diagnostics"])
    assert prof.results["pipelines"]["c"]["state"] == "blocked"
    assert "repro.analysis" in capsys.readouterr().err


def test_run_validate_off_skips_linting():
    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run(
        _chain("p"), validate="off")
    assert "diagnostics" not in prof.results


def test_run_validate_rejects_bad_mode():
    with pytest.raises(ValueError):
        AppManager(PilotRuntime(slots=2, mode="sim")).run(
            _chain("p"), validate="loud")


def test_submit_time_unknown_kernel_raises_e107():
    am = AppManager(PilotRuntime(slots=2, mode="sim"))
    bad = PipelineSpec([Stage([TaskSpec("no.such.kernel")], name="s0")],
                       name="p")
    with pytest.raises(DiagnosticError) as ei:
        am.run(bad, validate="off")       # even with the linter off
    d = ei.value.diagnostics[0]
    assert d.code == "E107" and d.pipeline == "p"


def test_named_kernel_spec_resolves_and_runs():
    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run(
        PipelineSpec([Stage([TaskSpec("synthetic.noop"),
                             TaskSpec("synthetic.noop")], name="s0")],
                     name="p"), validate="error")
    assert prof.n_tasks == 2 and prof.n_failed == 0


def test_journal_from_env(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_JOURNAL_DIR", raising=False)
    assert journal_from_env("x").path is None
    monkeypatch.setenv("REPRO_JOURNAL_DIR", str(tmp_path))
    j = journal_from_env("x")
    assert j.path == str(tmp_path / "x.jsonl")


# ===================================================== CLI

def test_cli_codes_lists_registry(capsys):
    assert analysis_cli(["codes"]) == 0
    out = capsys.readouterr().out
    assert all(code in out for code in CODES)


def test_cli_sanitize(tmp_path, capsys):
    clean = tmp_path / "clean.jsonl"
    clean.write_text(json.dumps(_scheduled()) + "\n"
                     + json.dumps(_finished()) + "\n")
    dirty = tmp_path / "dirty.jsonl"
    dirty.write_text(json.dumps(_scheduled(attempts=2)) + "\n"
                     + json.dumps(_scheduled(attempts=2)) + "\n")
    assert analysis_cli(["sanitize", str(clean)]) == 0
    assert analysis_cli(["sanitize", str(tmp_path)]) == 1
    assert "S301" in capsys.readouterr().out
    assert analysis_cli(["sanitize", str(tmp_path / "void")]) == 1


def test_cli_lint(tmp_path, capsys, monkeypatch):
    mod = tmp_path / "lint_target.py"
    mod.write_text(
        "from repro.core import Channel, PipelineSpec, Stage, TaskSpec\n"
        "def build():\n"
        "    return [PipelineSpec([Stage([TaskSpec('synthetic.noop')],\n"
        "                                name='s0')], name='p')]\n"
        "def broken():\n"
        "    ch = Channel('void')\n"
        "    return [PipelineSpec([Stage([TaskSpec('synthetic.noop')],\n"
        "                                name='s0', inputs={'x': ch})],\n"
        "                         name='p')]\n")
    monkeypatch.syspath_prepend(str(tmp_path))
    assert analysis_cli(["lint", "lint_target"]) == 0
    assert analysis_cli(["lint", "lint_target:broken"]) == 1
    assert "E102" in capsys.readouterr().out


# ===================================================== property test

@settings(max_examples=20, deadline=None)
@given(st.data())
def test_accepted_pipelines_complete_in_sim(data):
    """Soundness of the abstract executor: any pipeline set the validator
    accepts completes in sim without deadlock — and any set that ends up
    blocked was rejected up front."""
    pipes = []
    n_chains = data.draw(st.integers(min_value=1, max_value=3))
    for c in range(n_chains):
        cycles = data.draw(st.integers(min_value=1, max_value=3))
        rounds = data.draw(st.integers(min_value=1, max_value=4))
        cap = data.draw(st.integers(min_value=0, max_value=2)) or None
        members = data.draw(st.integers(min_value=1, max_value=2))
        ch = Channel(f"ch{c}", capacity=cap)
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_noop()) for _ in range(members)],
                   name=f"cy{i}", outputs=[ch]) for i in range(cycles)],
            name=f"prod{c}"))
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_noop())], name=f"r{i}", inputs={"x": ch})
             for i in range(rounds)], name=f"cons{c}"))
    report = validate_app(pipes)
    prof = AppManager(PilotRuntime(slots=4, mode="sim")).run(
        pipes, validate="off")
    states = {n: info["state"]
              for n, info in prof.results["pipelines"].items()}
    all_done = all(s == "done" for s in states.values())
    assert report.ok == all_done, (
        f"validator said ok={report.ok} but pipeline states are {states}: "
        f"{report.format()}")
