"""Per-arch smoke tests (reduced configs) + the strong correctness test:
prefill-then-decode must match the full forward for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, reduced
from repro.models import decode_step, forward, init_params
from repro.models.transformer import lm_logits

ARCHS = list(list_configs())


def _inputs(cfg, B, S, key=2):
    kw = {}
    if cfg.vision_tokens:
        kw["vision_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(key), (B, cfg.vision_tokens, cfg.d_model))
    if cfg.encoder_layers:
        kw["enc_frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.encoder_seq, cfg.d_model))
    return kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    out = forward(cfg, params, tokens, **_inputs(cfg, B, S))
    assert out["h"].shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(out["h"].astype(jnp.float32))))
    logits = lm_logits(cfg, params, out["h"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    from repro.configs.base import ShapeSpec
    from repro.data import SyntheticLM
    from repro.train import TrainHyper, build_train_step, make_train_state
    cfg = reduced(get_config(arch)).replace(microbatches=2)
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(cfg, hyper=TrainHyper(warmup=1,
                                                          total_steps=10)))
    batch = SyntheticLM(cfg, ShapeSpec("t", "train", 32, 4)).batch_at(0)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(jax.device_get(state["step"])) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, EXTRA, CLEN = 2, 24, 4, 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + EXTRA), 0,
                                cfg.vocab_size)
    kw = _inputs(cfg, B, S)
    full = lm_logits(cfg, params, forward(cfg, params, tokens, **kw)["h"])
    cache = forward(cfg, params, tokens[:, :S], cache_len=CLEN,
                    **kw)["cache"]
    errs = []
    for t in range(EXTRA):
        pos = jnp.full((B,), S + t, jnp.int32)
        logits, cache = decode_step(cfg, params, cache,
                                    tokens[:, S + t:S + t + 1], pos)
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full[:, S + t]))))
    assert max(errs) < 2e-2, (arch, errs)


def test_moe_no_drop_matches_dense_reference():
    """With generous capacity, sorted-dispatch MoE == dense compute-all."""
    from repro.models import layers as L
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    key = jax.random.PRNGKey(0)
    p = L.init_moe(cfg, key)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = L.apply_moe(cfg, p, x, capacity_factor=float(cfg.num_experts))

    # dense reference: run every expert on all tokens, weight top-k
    xt = x.reshape(-1, cfg.d_model)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_tok)
    w = w / w.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.num_experts):
        hi = xt @ p["wi"][e]
        hg = xt @ p["wg"][e]
        h = jax.nn.silu(hg) * hi
        outs.append(h @ p["wo"][e])
    dense = jnp.stack(outs, 1)                     # (T, E, D)
    sel = jnp.take_along_axis(dense, idx[..., None], axis=1)
    y_ref = (sel * w[..., None]).sum(1).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=2e-2)


def test_vision_embeds_change_output():
    cfg = reduced(get_config("internvl2-26b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                cfg.vocab_size)
    v1 = 0.02 * jax.random.normal(jax.random.PRNGKey(2),
                                  (1, cfg.vision_tokens, cfg.d_model))
    out1 = forward(cfg, params, tokens, vision_embeds=v1)["h"]
    out2 = forward(cfg, params, tokens, vision_embeds=2 * v1)["h"]
    assert float(jnp.max(jnp.abs(out1 - out2))) > 1e-6


def test_encoder_changes_decoder_output():
    cfg = reduced(get_config("whisper-large-v3"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                cfg.vocab_size)
    f1 = 0.02 * jax.random.normal(jax.random.PRNGKey(2),
                                  (1, cfg.encoder_seq, cfg.d_model))
    out1 = forward(cfg, params, tokens, enc_frames=f1)["h"]
    out2 = forward(cfg, params, tokens, enc_frames=-f1)["h"]
    assert float(jnp.max(jnp.abs(out1 - out2))) > 1e-6
