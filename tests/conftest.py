import os
import sys

# Deterministic test settings: force the CPU backend (tests never want an
# accelerator grabbed implicitly) and keep matmul precision fixed.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_DEFAULT_MATMUL_PRECISION", "highest")

# Tests run on the single real CPU device — the 512-device stand-in is set
# ONLY inside repro.launch.dryrun (see system design). Assert nobody leaked it.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "tests must not run with forced host device count"

sys.path.insert(0, os.path.dirname(__file__))
try:
    from hypothesis import settings as _hyp_settings
    # derandomize: property tests draw the same examples on every run/CI box
    _hyp_settings.register_profile("repro-ci", derandomize=True,
                                   deadline=None, print_blob=True)
    _hyp_settings.load_profile("repro-ci")
except ModuleNotFoundError:  # container without hypothesis: seeded stub
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
