import os

# Tests run on the single real CPU device — the 512-device stand-in is set
# ONLY inside repro.launch.dryrun (see system design). Assert nobody leaked it.
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "tests must not run with forced host device count"

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
