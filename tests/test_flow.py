"""Typed data-flow ports (core/flow.py): cross-pipeline coupling, the
incremental frontier scheduler, journaled channel replay, elastic slot
re-carving, and live per-pipeline adaptive strategy."""
import json
import os
import tempfile

import numpy as np
import pytest

from repro.core import (AppManager, Channel, Kernel, PipelineSpec, Stage,
                        TaskSpec, TypedPortError)
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState
from repro.runtime.strategy import AdaptiveSlotStrategy


def _k(sim_duration=0.0, cores=1):
    k = Kernel("synthetic.noop")
    k.sim_duration = sim_duration
    k.cores = cores
    return k


def _echo(value=None, sim_duration=0.0):
    k = Kernel("synthetic.echo")
    k.arguments = {"value": value}
    k.sim_duration = sim_duration
    return k


# -------------------------------------------------- channel coupling

def _producer(ch, cycles=3, members=2, dur=4.0):
    return PipelineSpec(
        [Stage([TaskSpec(_k(dur), name=f"prod.c{c}.m{m}")
                for m in range(members)],
               name=f"cycle{c}", outputs=[ch])
         for c in range(cycles)], name="producer")


def test_channel_consumer_starts_before_producer_drains():
    """The acceptance property: analysis round 0 runs while the producer
    ensemble is still on later cycles — DAG-of-ensembles, not barriers."""
    traj = Channel("traj")
    prod = _producer(traj, cycles=3, members=2, dur=4.0)
    ana = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name=f"ana.r{c}")],
               name=f"round{c}", inputs={"traj": traj})
         for c in range(3)], name="analysis")
    am = AppManager(PilotRuntime(slots=4, mode="sim"))
    prof = am.run([prod, ana])
    assert prof.n_failed == 0
    pipes = prof.results["pipelines"]
    assert pipes["producer"]["state"] == "done"
    assert pipes["analysis"]["state"] == "done"
    g = am.session.graph
    # round 0 starts the moment cycle 0 completes (v=4), long before the
    # producer drains (v=12)
    assert g.tasks["ana.r0"].v_started == 4.0
    prod_drained = max(t.v_finished for n, t in g.tasks.items()
                      if n.startswith("prod.c2"))
    assert g.tasks["ana.r0"].v_started < prod_drained
    # FIFO: round c consumed cycle c's put
    assert len(traj.puts) == 3 and len(traj._taken) == 3


def test_channel_real_mode_delivers_stage_results():
    """Consumers see the producing stage's {task: result} dict on their
    declared port (ctx['inputs'])."""
    ch = Channel("data")
    prod = PipelineSpec(
        [Stage([TaskSpec(_echo({"member": 0}), name="p0"),
                TaskSpec(_echo({"member": 1}), name="p1")],
               name="sim", outputs=[ch])], name="P")
    cons = PipelineSpec(
        [Stage([TaskSpec(_echo("ana"), name="c0")],
               name="ana", inputs={"data": ch})], name="C")
    prof = AppManager(PilotRuntime(slots=4, mode="real")).run([prod, cons])
    assert prof.n_failed == 0
    got = prof.results["tasks"]["c0"]["inputs"]["data"]
    assert got == {"p0": {"value": {"member": 0}},
                   "p1": {"value": {"member": 1}}}


def test_stage_future_cross_pipeline_edge():
    """A StageFuture couples a consumer to ONE named stage of another
    pipeline via direct task dependencies."""
    sim = Stage([TaskSpec(_k(5.0), name=f"a.m{m}") for m in range(2)],
                name="sim")
    tail = Stage([TaskSpec(_k(20.0), name="a.tail")], name="tail")
    A = PipelineSpec([sim, tail], name="A")
    B = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name="b.ana")], name="ana",
               inputs={"members": sim.future()})], name="B")
    am = AppManager(PilotRuntime(slots=4, mode="sim"))
    prof = am.run([A, B])
    assert prof.n_failed == 0
    g = am.session.graph
    assert sorted(g.tasks["b.ana"].deps) == ["a.m0", "a.m1"]
    # consumer ran right after the producer stage, inside A's lifetime
    assert g.tasks["b.ana"].v_started == 5.0
    assert g.tasks["a.tail"].v_finished == 25.0


def test_future_of_later_stage_parks_until_submitted():
    """Consuming a stage the producer pipeline has not reached yet parks
    the consumer; it wakes when the stage is submitted."""
    s0 = Stage([TaskSpec(_k(10.0), name="a.s0")], name="s0")
    s1 = Stage([TaskSpec(_k(10.0), name="a.s1")], name="s1")
    A = PipelineSpec([s0, s1], name="A")
    B = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name="b.c")], name="c",
               inputs={"x": s1.future()})], name="B")
    am = AppManager(PilotRuntime(slots=4, mode="sim"))
    prof = am.run([A, B])
    assert prof.n_failed == 0
    g = am.session.graph
    assert g.tasks["b.c"].deps == ["a.s1"]
    assert g.tasks["b.c"].v_started == 20.0
    assert prof.results["pipelines"]["B"]["state"] == "done"


def test_unfed_consumer_reported_blocked():
    ch = Channel("never")
    good = PipelineSpec([Stage([TaskSpec(_k(1.0))], name="s")], name="good")
    stuck = PipelineSpec(
        [Stage([TaskSpec(_k(1.0))], name="s", inputs={"x": ch})],
        name="stuck")
    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run([good, stuck])
    assert prof.results["pipelines"]["good"]["state"] == "done"
    assert prof.results["pipelines"]["stuck"]["state"] == "blocked"
    assert prof.results["pipelines"]["stuck"]["waiting_on"] == "channel:never"


def test_typed_channel_rejects_wrong_payload():
    ch = Channel("typed", dtype=dict)
    with pytest.raises(TypedPortError, match="expects dict"):
        ch.put("p", {"t0": 3})          # a non-dict task result
    ch.put("p", {"t0": {"ok": 1}})      # dict results pass
    assert ch.has_put("p")


def test_typed_channel_usable_in_sim_mode():
    """DES tasks produce None results; a typed channel must not reject the
    placeholder payloads (no data flows in sim)."""
    ch = Channel("typed", dtype=dict)
    prod = PipelineSpec([Stage([TaskSpec(_k(1.0))], name="s",
                               outputs=[ch])], name="P")
    cons = PipelineSpec([Stage([TaskSpec(_k(1.0))], name="a",
                               inputs={"t": ch})], name="C")
    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run([prod, cons])
    assert prof.n_failed == 0
    assert prof.results["pipelines"]["C"]["state"] == "done"


def test_journal_omits_json_lossy_put_values():
    """A tuple journals as a JSON array and would replay as a list —
    lossy values must be omitted so the restart recomputes them."""
    with tempfile.TemporaryDirectory() as d:
        j = Journal(os.path.join(d, "j.jsonl"))
        j.record_flow("channel_put", "c", "p0", value=(1, 2))   # lossy
        j.record_flow("channel_put", "c", "p1", value=[1, 2])   # exact
        j.close()
        puts, _ = Journal(os.path.join(d, "j.jsonl")).load_flow()
        assert ("c", "p0") not in puts
        assert puts[("c", "p1")] == [1, 2]


def test_task_level_ports_stream_per_task():
    """TaskSpec outputs put each task's bare result; TaskSpec inputs take
    one put per task (finer than stage granularity)."""
    ch = Channel("stream")
    prod = PipelineSpec(
        [Stage([TaskSpec(_echo(i, 1.0), name=f"p{i}", outputs=[ch])
                for i in range(3)], name="sim")], name="P")
    cons = PipelineSpec(
        [Stage([TaskSpec(_echo("c", 1.0), name=f"c{i}",
                         inputs={"v": ch}) for i in range(3)],
               name="ana")], name="C")
    am = AppManager(PilotRuntime(slots=6, mode="real"))
    prof = am.run([prod, cons])
    assert prof.n_failed == 0
    assert len(ch.puts) == 3
    vals = sorted(prof.results["tasks"][f"c{i}"]["inputs"]["v"]["value"]
                  for i in range(3))
    assert vals == [0, 1, 2]


def test_two_consumers_fifo_work_queue():
    """Two consumer pipelines on one channel split the stream: each put is
    consumed exactly once, in order."""
    ch = Channel("q")
    prod = _producer(ch, cycles=4, members=1, dur=1.0)
    consumers = [
        PipelineSpec([Stage([TaskSpec(_k(0.5), name=f"{w}.r{c}")],
                            name=f"r{c}", inputs={"q": ch})
                      for c in range(2)], name=w)
        for w in ("w0", "w1")]
    prof = AppManager(PilotRuntime(slots=4, mode="sim")).run(
        [prod] + consumers)
    assert prof.n_failed == 0
    assert len(ch.puts) == 4 and len(ch._taken) == 4
    for w in ("w0", "w1"):
        assert prof.results["pipelines"][w]["state"] == "done"


def test_channel_backpressure_parks_producer():
    """Channel(capacity=1): the producer pipeline parks once one put sits
    unconsumed, and wakes on the consumer's take — instead of buffering
    every cycle's payload unboundedly."""
    ch = Channel("bp", capacity=1)
    prod = _producer(ch, cycles=4, members=1, dur=1.0)
    cons = PipelineSpec(
        [Stage([TaskSpec(_k(5.0), name=f"slow.r{c}")],
               name=f"r{c}", inputs={"q": ch}) for c in range(4)],
        name="slow")
    am = AppManager(PilotRuntime(slots=4, mode="sim"))
    prof = am.run([prod, cons])
    assert prof.n_failed == 0
    pipes = prof.results["pipelines"]
    assert pipes["producer"]["state"] == "done"
    assert pipes["slow"]["state"] == "done"
    g = am.session.graph
    # unthrottled, the producer would drain by v=4; with capacity=1 each
    # cycle past the first two waits for the slow consumer's take:
    # c0@1, c1@2 (round0 took put0 at v=1), c2 parked until round1 takes
    # at v=6, c3 until round2 takes at v=11
    assert g.tasks["prod.c2.m0"].v_started == 6.0
    assert g.tasks["prod.c3.m0"].v_started == 11.0
    assert ch.n_unconsumed() == 0


def test_channel_backpressure_unfed_producer_reports_blocked():
    """A producer parked on a full channel nobody drains is reported
    blocked with the channel_space marker."""
    ch = Channel("full", capacity=1)
    prod = _producer(ch, cycles=3, members=1, dur=1.0)
    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run([prod])
    pipes = prof.results["pipelines"]
    assert pipes["producer"]["state"] == "blocked"
    assert pipes["producer"]["waiting_on"] == "channel_space:full"
    assert len(ch.puts) == 1                     # exactly capacity


def test_reentrant_wake_cannot_steal_counted_puts():
    """A wake delivered between two of a consumer's counted takes must
    not reentrantly submit another consumer that steals the puts the
    first consumer's blocker check already counted (this crashed with an
    uncaught LookupError before wakes were deferred to the end of the
    outermost submission)."""
    X = Channel("X", capacity=2)
    Z = Channel("Z")
    Z2 = Channel("Z2")
    P = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name=f"px{i}", outputs=[X])
                for i in range(2)], name="s0"),
         Stage([], name="ctl", outputs=[X, Z])], name="P")
    S = PipelineSpec([Stage([TaskSpec(_k(2.0), name="s2")], name="g",
                            outputs=[Z2])], name="S")
    A = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name=f"ax{i}", inputs={"x": X})
                for i in range(2)], name="a", inputs={"z2": Z2})],
        name="A")
    C = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name=f"cx{i}", inputs={"x": X})
                for i in range(2)], name="c", inputs={"z": Z})], name="C")
    prof = AppManager(PilotRuntime(slots=8, mode="sim")).run([P, S, A, C])
    assert prof.n_failed == 0
    pipes = prof.results["pipelines"]
    # A keeps the two puts it counted; C (needing two, with only the
    # control put left) parks instead of crashing the run
    assert pipes["A"]["state"] == "done"
    assert pipes["P"]["state"] == "done"
    assert pipes["C"]["state"] == "blocked"
    assert len(X.puts) == 3


def test_backpressure_counts_task_level_burst():
    """A stage whose N tasks each put task-level outputs bursts N puts
    between blocker checks: the blocker must count the burst (admitting
    only from a drained channel when the burst exceeds capacity)."""
    ch = Channel("burst", capacity=2)
    prod = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name=f"b{c}.{i}", outputs=[ch])
                for i in range(4)], name=f"s{c}") for c in range(2)],
        name="P")
    cons = PipelineSpec(
        [Stage([TaskSpec(_k(2.0), name=f"r{c}")], name=f"r{c}",
               inputs={"q": ch}) for c in range(8)], name="C")
    am = AppManager(PilotRuntime(slots=8, mode="sim"))
    prof = am.run([prod, cons])
    assert prof.n_failed == 0
    assert all(p["state"] == "done"
               for p in prof.results["pipelines"].values())
    g = am.session.graph
    # stage 0 admits into the empty channel (progress guarantee) even
    # though its burst of 4 exceeds capacity 2; stage 1 waits until the
    # consumer fully drains that burst (4th take at v=7)
    assert g.tasks["b0.0"].v_started == 0.0
    assert g.tasks["b1.0"].v_started == 7.0


def test_backpressure_feedback_loop_does_not_self_deadlock():
    """A stage that consumes from AND produces to the same bounded
    channel credits its own takes: the loop cycles instead of parking
    on the space its own take is about to free."""
    ch = Channel("loop", capacity=1)
    seed = PipelineSpec([Stage([TaskSpec(_k(1.0), name="seed")],
                               name="s", outputs=[ch])], name="seed")
    fb = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name=f"fb{c}")], name=f"f{c}",
               inputs={"q": ch}, outputs=[ch]) for c in range(3)],
        name="fb")
    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run([seed, fb])
    assert prof.n_failed == 0
    assert prof.results["pipelines"]["fb"]["state"] == "done"
    assert len(ch.puts) == 4                     # seed + 3 feedback puts


def test_channel_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        Channel("bad", capacity=0)
    with pytest.raises(ValueError, match="mode"):
        Channel("bad", mode="multicast")


def test_broadcast_channel_every_consumer_sees_every_put():
    """mode='broadcast': each consumer pipeline keeps its own cursor —
    N analysis ensembles each consume EVERY trajectory (vs FIFO, which
    splits the stream)."""
    ch = Channel("bcast", mode="broadcast")
    prod = PipelineSpec(
        [Stage([TaskSpec(_echo(c, 1.0), name=f"bp.c{c}")],
               name=f"cycle{c}", outputs=[ch]) for c in range(3)],
        name="producer")
    consumers = [
        PipelineSpec([Stage([TaskSpec(_echo(w, 0.5), name=f"{w}.r{c}")],
                            name=f"r{c}", inputs={"q": ch})
                      for c in range(3)], name=w)
        for w in ("wA", "wB")]
    am = AppManager(PilotRuntime(slots=6, mode="real"))
    prof = am.run([prod] + consumers)
    assert prof.n_failed == 0
    assert len(ch.puts) == 3                     # one blob per cycle...
    for w in ("wA", "wB"):                       # ...each taken by BOTH
        assert prof.results["pipelines"][w]["state"] == "done"
        got = [prof.results["tasks"][f"{w}.r{c}"]["inputs"]["q"]
               for c in range(3)]
        assert [g[f"bp.c{c}"]["value"] for c, g in enumerate(got)] \
            == [0, 1, 2]
    assert ch.n_unconsumed() == 0                # both cursors drained


def test_broadcast_channel_replays_from_journal():
    """Broadcast takes re-bind to their journaled producer on restart."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.jsonl")

        def run():
            rt = PilotRuntime(slots=4, mode="real",
                              journal=Journal(path))
            ch = Channel("b", mode="broadcast")
            prod = PipelineSpec(
                [Stage([TaskSpec(_echo(c), name=f"p.c{c}")],
                       name=f"c{c}", outputs=[ch]) for c in range(2)],
                name="P")
            cons = [PipelineSpec(
                [Stage([TaskSpec(_echo(w), name=f"{w}.r{c}")],
                       name=f"r{c}", inputs={"q": ch})
                 for c in range(2)], name=w) for w in ("x", "y")]
            prof = AppManager(rt).run([prod] + cons)
            rt.journal.close()
            return prof, ch

        prof1, ch1 = run()
        assert prof1.n_failed == 0
        n_lines = len(open(path).read().splitlines())
        prof2, ch2 = run()
        assert prof2.n_failed == 0
        assert ch2.puts == ch1.puts
        assert ch2._cursors == ch1._cursors
        recs = [json.loads(ln) for ln in open(path)]
        assert not [r for r in recs[n_lines:]
                    if r.get("event") == "scheduled"]   # no re-execution


def test_channel_name_collision_rejected():
    a, b = Channel("same"), Channel("same")
    prod = PipelineSpec([Stage([TaskSpec(_k(1.0))], name="s",
                               outputs=[a])], name="P")
    cons = PipelineSpec([Stage([TaskSpec(_k(1.0))], name="s",
                               inputs={"x": b})], name="C")
    with pytest.raises(ValueError, match="two different Channel"):
        AppManager(PilotRuntime(slots=2, mode="sim")).run([prod, cons])


# -------------------------------------------------- journal replay

def _coupled_real(journal_path, probe):
    """Producer (2 cycles) -> analysis (2 rounds) over a journaled real
    runtime; ``probe`` collects (task, inputs) pairs from analysis."""
    rt = PilotRuntime(slots=4, mode="real",
                      journal=Journal(journal_path))
    traj = Channel("traj")

    def ana_kernel(r):
        k = Kernel("synthetic.echo")
        k.arguments = {"value": f"round{r}"}
        k.download_output_data = [
            lambda res, _r=r: probe.append((_r, res.get("inputs")))]
        return k

    prod = PipelineSpec(
        [Stage([TaskSpec(_echo({"cycle": c, "member": m}),
                         name=f"prod.c{c}.m{m}") for m in range(2)],
               name=f"cycle{c}", outputs=[traj])
         for c in range(2)], name="producer")
    ana = PipelineSpec(
        [Stage([TaskSpec(ana_kernel(r), name=f"ana.r{r}")],
               name=f"round{r}", inputs={"traj": traj})
         for r in range(2)], name="analysis")
    am = AppManager(rt)
    prof = am.run([prod, ana])
    rt.journal.close()
    return prof, traj


def test_journal_replays_channel_puts_full_restart():
    """Full-journal restart: nothing re-executes (so the download probe
    stays silent) and the channels repopulate with the IDENTICAL puts and
    consumer bindings from the journal."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.jsonl")
        probe1, probe2 = [], []
        prof1, traj1 = _coupled_real(path, probe1)
        assert prof1.n_failed == 0 and len(probe1) == 2

        n_lines = len(open(path).read().splitlines())
        prof2, traj2 = _coupled_real(path, probe2)
        assert prof2.n_failed == 0
        assert probe2 == []                # nothing re-executed
        assert traj2.puts == traj1.puts    # identical replayed channel state
        assert traj2._taken == traj1._taken
        recs = [json.loads(ln) for ln in open(path)]
        # no task re-executed: no new "scheduled" records after restart
        assert not [r for r in recs[n_lines:] if r.get("event") == "scheduled"]
        puts = [r for r in recs if r.get("event") == "channel_put"]
        assert {(p["channel"], p["producer"]) for p in puts} == {
            ("traj", "producer:0000"), ("traj", "producer:0001")}


def test_journal_replays_channel_puts_midstream_crash():
    """Kill a coupled run mid-stream (truncate the journal to cycle 0's
    records), reload: consumer round 0 sees the IDENTICAL input via the
    journaled put + take, and cycle-0 tasks do not re-execute."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.jsonl")
        probe1, probe2 = [], []
        prof1, traj1 = _coupled_real(path, probe1)
        assert prof1.n_failed == 0 and len(probe1) == 2

        # crash simulation: keep only cycle-0/round-0 records + torn line
        keep = []
        for ln in open(path).read().splitlines():
            rec = json.loads(ln)
            tag = rec.get("task", "") + rec.get("producer", "") \
                + rec.get("consumer", "")
            if ("c1" not in tag and "r1" not in tag
                    and "0001" not in tag):
                keep.append(ln)
        with open(path, "w") as f:
            f.write("\n".join(keep) + '\n{"task": "prod.c1.m0", "ev')

        prof2, traj2 = _coupled_real(path, probe2)
        assert prof2.n_failed == 0
        # round 1 re-executed and saw byte-identical inputs; round 0
        # replayed (silent probe) with its put/take restored verbatim
        assert probe2 == [probe1[1]]
        assert traj2.puts[0] == traj1.puts[0]
        assert len(traj2.puts) == 2 and len(traj2._taken) == 2
        recs = []
        for ln in open(path):
            try:
                recs.append(json.loads(ln))
            except json.JSONDecodeError:
                pass                       # the torn crash line
        sched = [r["task"] for r in recs if r.get("event") == "scheduled"]
        # every task was scheduled exactly once across crash + restart:
        # cycle 0 / round 0 before the crash (their post-crash records were
        # truncated away would show a duplicate), cycle 1 / round 1 after
        assert sorted(sched) == ["ana.r0", "ana.r1", "prod.c0.m0",
                                 "prod.c0.m1", "prod.c1.m0", "prod.c1.m1"]


# -------------------------------------------------- incremental frontier

def test_frontier_tracks_deps_incrementally():
    g = TaskGraph()
    a = g.add(Task(name="a"))
    b = g.add(Task(name="b", deps=["a"]))
    c = g.add(Task(name="c", deps=["a", "b"]))
    assert [t.name for t in g.ready()] == ["a"]
    assert g.pop_ready() is a and g.pop_ready() is None
    a.state = TaskState.RUNNING
    a.state = TaskState.DONE
    assert g.pop_ready() is b
    b.state = TaskState.DONE               # c's last dep satisfied
    assert [t.name for t in g.ready()] == ["c"]
    assert not g.done()
    c.state = TaskState.CANCELED
    assert g.done()


def test_frontier_requeue_and_retry_reentry():
    g = TaskGraph()
    a = g.add(Task(name="a"))
    t = g.pop_ready()
    g.requeue(t)
    assert g.pop_ready() is a              # requeued, not lost
    a.state = TaskState.RUNNING
    assert g.pop_ready() is None
    a.state = TaskState.NEW                # retry path re-enters frontier
    assert g.pop_ready() is a


def test_frontier_dep_satisfied_before_dependent_added():
    g = TaskGraph()
    a = g.add(Task(name="a"))
    a.state = TaskState.DONE
    b = g.add(Task(name="b", deps=["a"]))  # dep already DONE at add()
    assert g.pop_ready() is b
    assert g.ready() == []


def test_frontier_matches_full_scan_under_random_schedules():
    rng = np.random.default_rng(7)
    g = TaskGraph()
    tasks = []
    for i in range(120):
        deps = [f"t{j}"
                for j in rng.choice(i, rng.integers(0, min(i, 3)), False)] \
            if i else []
        tasks.append(g.add(Task(name=f"t{i}", deps=deps)))
    done = set()
    while True:
        frontier = {t.name for t in g.ready()}
        scan = {t.name for t in tasks
                if t.state == TaskState.NEW
                and all(g.tasks[d].state == TaskState.DONE for d in t.deps)}
        assert frontier == scan
        if not frontier:
            break
        pick = sorted(frontier)[int(rng.integers(len(frontier)))]
        g.tasks[pick].state = TaskState.RUNNING
        g.tasks[pick].state = TaskState.DONE
        done.add(pick)
    assert len(done) == 120 and g.done()


def test_frontier_min_width_tracking():
    """The scheduler's fast-path signal: narrowest ready width, maintained
    through pops, requeues and completions."""
    g = TaskGraph()
    wide = g.add(Task(name="w", slots=4))
    g.add(Task(name="n", slots=1, deps=["w"]))
    assert g.frontier_min_width() == 4
    t = g.pop_ready()
    assert g.frontier_min_width() is None   # popped: out of the frontier
    g.requeue(t)
    assert g.frontier_min_width() == 4
    wide.state = TaskState.RUNNING
    wide.state = TaskState.DONE             # unblocks the narrow task
    assert g.frontier_min_width() == 1
    g.tasks["n"].state = TaskState.RUNNING
    assert g.frontier_min_width() is None


def test_real_mode_mixed_width_admits_narrow_behind_wide():
    """A narrow task queued (by tid) behind wide ones must still run while
    the wide ones wait for capacity."""
    import time as _time
    g = TaskGraph()
    for i in range(3):
        g.add(Task(name=f"wide{i}", slots=2,
                   run=lambda t: _time.sleep(0.05)))
    g.add(Task(name="narrow", slots=1, run=lambda t: 1))
    prof = PilotRuntime(slots=3, mode="real").run(g)
    assert prof.n_failed == 0
    assert all(t.state == TaskState.DONE for t in g.tasks.values())
    assert g.tasks["narrow"].result == 1


# -------------------------------------------------- elastic re-carving

def _topo(n_slots, per_slot):
    from repro.dist.topology import SlotTopology
    return SlotTopology(np.arange(n_slots * per_slot)
                        .reshape(n_slots, per_slot), ("model",))


def test_recarve_splits_slot_axis():
    topo = _topo(2, 4)
    fine = topo.recarve(4)
    assert fine.n_slots == 4
    assert fine.devices_per_slot == 2
    # halves stay contiguous: slot 0+1 together cover old slot 0
    np.testing.assert_array_equal(
        np.concatenate([fine.devices[0], fine.devices[1]]), topo.devices[0])
    assert fine.axis_names == topo.axis_names
    with pytest.raises(ValueError, match="multiple"):
        topo.recarve(3)
    with pytest.raises(ValueError, match="grow-only"):
        topo.recarve(1)


def test_runtime_grow_recarves_topology():
    rt = PilotRuntime(mode="sim", topology=_topo(2, 4))
    g = TaskGraph()
    for i in range(2):
        g.add(Task(name=f"w{i}", duration=10.0))
    for i in range(4):
        g.add(Task(name=f"n{i}", duration=10.0, deps=["w0", "w1"]))
    fired = []

    def grow(rt_, graph, vnow):
        if vnow is not None and vnow >= 10.0 and not fired:
            fired.append(vnow)
            rt_.resize(4)

    rt.on_schedule = grow
    prof = rt.run(g)
    # wave 1: 2 wide slots; after re-carve 2 pods -> 4 half-pods the four
    # narrow tasks run concurrently
    assert prof.ttc == 20.0
    assert rt.slots == 4 and rt.topology.n_slots == 4
    assert rt.topology.devices_per_slot == 2
    assert sorted(rt._free_ids) == list(range(4))
    for i in range(4):
        assert len(rt.topology.slot_devices(
            g.tasks[f"n{i}"].meta["slot_ids"]).ravel()) == 2


def test_recarve_defers_until_slots_free():
    """resize() past the carved count while tasks hold slot ids stays
    pending; capacity is unchanged until the holders drain."""
    rt = PilotRuntime(mode="sim", topology=_topo(2, 2))
    g = TaskGraph()
    g.add(Task(name="hold", duration=10.0))
    g.add(Task(name="a", duration=5.0))
    g.add(Task(name="later", duration=5.0, deps=["hold"]))

    def grow(rt_, graph, vnow):
        if vnow == 0.0:
            rt_.resize(4)      # requested while both slots are about to fill

    rt.on_schedule = grow
    prof = rt.run(g)
    assert rt.slots == 4 and rt.topology.n_slots == 4
    assert prof.ttc == 15.0
    assert sorted(rt._free_ids) == list(range(4))


# -------------------------------------------------- live adaptive strategy

def test_strategy_fed_per_pipeline_backlog_live():
    """The pilot grows INTO a backlog at a stage boundary mid-session and
    shrinks again when the queues drain — driven by per-pipeline depth,
    within one AppManager session (not between runs)."""
    seen = []

    class Spy(AdaptiveSlotStrategy):
        def apply(self, pilot, *, utilization, backlog, per_pipeline=None):
            seen.append(dict(per_pipeline or {}))
            return super().apply(pilot, utilization=utilization,
                                 backlog=backlog,
                                 per_pipeline=per_pipeline)

    rt = PilotRuntime(slots=2, mode="sim")
    strat = Spy(min_slots=2, max_slots=8)
    pipe = PipelineSpec(
        [Stage([TaskSpec(_k(5.0), name="seed")], name="s0"),
         Stage([TaskSpec(_k(10.0), name=f"wide{i}") for i in range(8)],
               name="s1")], name="p")
    am = AppManager(rt, strategy=strat)
    prof = am.run(pipe)
    assert prof.n_failed == 0
    # stage-0 completion saw the 8 queued wide tasks and grew 2 -> 4;
    # the wide stage then ran in two 4-task waves
    assert seen[0] == {"p": 8}
    assert prof.ttc == 5.0 + 20.0
    # final stage completion: no active pipelines, queues empty -> shrink
    assert seen[-1] == {}
    assert rt.slots == 2


def test_strategy_holds_width_on_unrecarvable_grow():
    """An adaptive grow decision the slot topology cannot grant (not a
    re-carvable multiple) must HOLD the current width, not crash the
    session from inside the completion callback."""
    rt = PilotRuntime(mode="sim", topology=_topo(2, 1))   # 2 unsplittable
    strat = AdaptiveSlotStrategy(min_slots=2, max_slots=16)
    pipe = PipelineSpec(
        [Stage([TaskSpec(_k(5.0), name="seed")], name="s0"),
         Stage([TaskSpec(_k(10.0), name=f"q{i}") for i in range(3)],
               name="s1")], name="p")
    prof = AppManager(rt, strategy=strat).run(pipe)
    assert prof.n_failed == 0
    # decide() wanted 3 slots (backlog 3 > 2); infeasible -> stayed at 2
    assert rt.slots == 2
    assert prof.ttc == 5.0 + 20.0


def test_blocked_pipeline_stays_blocked_across_runs():
    """A pipeline blocked when its session drained must NOT be woken into
    a later run's fresh session (its stage deps name dead tasks)."""
    ch = Channel("late")
    am = AppManager(PilotRuntime(slots=2, mode="sim"))
    consumer = PipelineSpec(
        [Stage([TaskSpec(_k(1.0))], name="s0"),
         Stage([TaskSpec(_k(1.0))], name="s1", inputs={"x": ch})],
        name="consumer")
    prof = am.run(consumer)
    assert prof.results["pipelines"]["consumer"]["state"] == "blocked"

    producer = PipelineSpec(
        [Stage([TaskSpec(_k(1.0))], name="s0", outputs=[ch])],
        name="producer")
    prof = am.run(producer)           # the put must not resurrect consumer
    assert prof.n_failed == 0
    assert prof.results["pipelines"]["producer"]["state"] == "done"
    assert prof.results["pipelines"]["consumer"]["state"] == "blocked"


# -------------------------------------------------- byte back-pressure

def test_channel_byte_accounting_unit():
    ch = Channel("u", capacity_bytes=10)
    ch.put("p0", 1, nbytes=4)
    ch.put("p1", 2, nbytes=5)
    assert ch.n_unconsumed_bytes() == 9
    assert ch.peak_unconsumed_bytes == 9
    ch.take("c")
    assert ch.n_unconsumed_bytes() == 5        # fifo retires put0's bytes
    ch.take("c")
    assert ch.n_unconsumed_bytes() == 0
    assert ch.peak_unconsumed_bytes == 9       # high-water mark sticks
    with pytest.raises(ValueError):
        Channel("bad", capacity_bytes=0)


def test_channel_byte_backpressure_parks_producer():
    """Channel(capacity_bytes=...): the producer parks once the declared
    unconsumed payload bytes would exceed the budget, and the budget
    bounds the channel's high-water mark for the whole run."""
    from repro.staging import LocalityMap, StagingLayer

    ch = Channel("bb", capacity_bytes=100)

    def put80(c):
        k = _k(1.0)
        k.output_nbytes = 80
        return Stage([TaskSpec(k, name=f"prod.c{c}")], name=f"c{c}",
                     outputs=[ch])

    prod = PipelineSpec([put80(c) for c in range(4)], name="producer")
    cons = PipelineSpec(
        [Stage([TaskSpec(_k(5.0), name=f"slow.r{c}")],
               name=f"r{c}", inputs={"q": ch}) for c in range(4)],
        name="slow")
    staging = StagingLayer(locality=LocalityMap(4, slots_per_pod=2))
    am = AppManager(PilotRuntime(slots=4, mode="sim", staging=staging))
    prof = am.run([prod, cons])
    assert prof.n_failed == 0
    pipes = prof.results["pipelines"]
    assert pipes["producer"]["state"] == "done"
    assert pipes["slow"]["state"] == "done"
    # 2 puts of 80B never sit unconsumed together: 80+80 > 100
    assert ch.peak_unconsumed_bytes <= 100
    assert ch.n_unconsumed_bytes() == 0
    g = am.session.graph
    # round 0's take retires put0's bytes at v=1, so c1 proceeds; c2
    # then parks behind put1's 80B until round 1 takes at v=6, c3
    # behind put2's until round 2 takes at v=11
    assert g.tasks["prod.c1"].v_started == 1.0
    assert g.tasks["prod.c2"].v_started == 6.0
    assert g.tasks["prod.c3"].v_started == 11.0


def test_channel_byte_backpressure_unfed_reports_blocked():
    from repro.staging import LocalityMap, StagingLayer

    ch = Channel("bfull", capacity_bytes=100)

    def put80(c):
        k = _k(1.0)
        k.output_nbytes = 80
        return Stage([TaskSpec(k, name=f"prod.c{c}")], name=f"c{c}",
                     outputs=[ch])

    prod = PipelineSpec([put80(c) for c in range(3)], name="producer")
    staging = StagingLayer(locality=LocalityMap(2, slots_per_pod=1))
    am = AppManager(PilotRuntime(slots=2, mode="sim", staging=staging))
    prof = am.run([prod], validate="off")       # W201+E106 by design here
    pipes = prof.results["pipelines"]
    assert pipes["producer"]["state"] == "blocked"
    assert pipes["producer"]["waiting_on"] == "channel_space:bfull"
    assert len(ch.puts) == 1
