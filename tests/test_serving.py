"""repro.serving: seedable traffic, the continuous-batching DES cost
model, SLA metrics reconstruction, and the real per-step admit/evict
BatchedServer (token identity vs a sequential reference)."""
import numpy as np
import pytest

from repro.core import AppManager
from repro.runtime.executor import PilotRuntime
from repro.serving import (CLASSES, TrafficModel, build_serving_app,
                           simulate_continuous, sla_class)


def _tiny_cfg(**over):
    from repro.configs.base import ModelConfig
    kw = dict(name="serve-test", family="dense", num_layers=2, d_model=32,
              num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
              vocab_size=64, layer_pattern=("global",))
    kw.update(over)
    return ModelConfig(**kw)


# ------------------------------------------------------------- traffic

def test_traffic_windows_deterministic_and_seeded():
    m = TrafficModel(seed=3, window_s=30.0)
    a = m.window(5)
    b = TrafficModel(seed=3, window_s=30.0).window(5)
    assert a == b                                     # pure fn of (seed, k)
    c = TrafficModel(seed=4, window_s=30.0).window(5)
    assert a != c
    # offsets sorted inside the window, rids globally unique, SLAs known
    offs = [r.offset_s for r in a]
    assert offs == sorted(offs)
    assert all(0.0 <= o < 30.0 for o in offs)
    rids = [r.rid for k in range(8) for r in m.window(k)]
    assert len(rids) == len(set(rids))
    assert all(r.sla in CLASSES for r in a)


def test_traffic_rate_is_diurnal_and_bounded():
    m = TrafficModel(base_rps=2.0, peak_rps=8.0, period_s=600.0,
                     window_s=30.0, burst_prob=0.0)
    rates = [m.rate(k) for k in range(20)]            # one full period
    assert all(2.0 - 1e-9 <= r <= 8.0 + 1e-9 for r in rates)
    assert max(rates) > 6.0 and min(rates) < 4.0      # actually swings


def test_traffic_class_split():
    m = TrafficModel(seed=1, latency_frac=0.25, base_rps=20.0,
                     peak_rps=20.0, burst_prob=0.0)
    reqs = [r for k in range(10) for r in m.window(k)]
    lat = [r for r in reqs if r.sla == "latency"]
    both = m.requests(0, "latency") + m.requests(0, "throughput")
    assert sorted(both, key=lambda r: r.rid) == m.window(0)
    assert 0.1 < len(lat) / len(reqs) < 0.4
    # latency requests decode fewer tokens than throughput ones
    assert max(r.max_new_tokens for r in lat) \
        <= min(r.max_new_tokens for r in reqs if r.sla == "throughput")


# ----------------------------------------------------- DES cost model

def test_simulate_continuous_properties():
    m = TrafficModel(seed=0)
    reqs = m.window(2)
    assert reqs
    sim = simulate_continuous(reqs, 4, step_cost_s=0.01,
                              prefill_cost_s=0.1)
    new = [r.max_new_tokens for r in reqs]
    assert max(new) <= sim.steps <= sum(new)
    assert 0.0 < sim.occupancy <= 1.0
    assert sim.prefills == -(-len(reqs) // 4)
    assert sim.makespan_s == pytest.approx(
        sim.steps * 0.01 + sim.prefills * 0.1)
    for r in reqs:
        assert 0.0 < sim.first_s[r.rid] <= sim.finish_s[r.rid]
        assert sim.finish_s[r.rid] <= sim.makespan_s + 1e-9


def test_simulate_continuous_empty():
    sim = simulate_continuous([], 8, step_cost_s=0.01)
    assert (sim.makespan_s, sim.steps, sim.prefills) == (0.0, 0, 0)


def test_simulate_single_slot_is_serial():
    m = TrafficModel(seed=0)
    reqs = m.window(1)
    sim = simulate_continuous(reqs, 1, step_cost_s=1.0)
    assert sim.steps == sum(r.max_new_tokens for r in reqs)
    assert sim.occupancy == pytest.approx(1.0)


# ------------------------------------------------- DES end-to-end app

def test_des_serving_app_collects_metrics():
    m = TrafficModel(seed=7, window_s=10.0, base_rps=3.0, peak_rps=9.0,
                     period_s=120.0)
    pipes, channels, metrics = build_serving_app(
        m, 6, decode_slots=4, step_cost_s=0.01,
        deadlines={"latency": 15.0, "throughput": 600.0})
    am = AppManager(PilotRuntime(slots=8, mode="sim", preempt=True))
    prof = am.run(pipes, validate="error")
    metrics.install(am, prof)
    s = prof.results["serving"]
    total = sum(len(m.window(k)) for k in range(6))
    assert sum(c["n"] for c in s["classes"].values()) == total
    for c in s["classes"].values():
        assert 0.0 < c["p50_latency_s"] <= c["p99_latency_s"]
        assert 0.0 < c["p50_ttft_s"] <= c["p50_latency_s"] + 1e-9
        assert 0.0 < c["occupancy"] <= 1.0
        assert c["dropped_windows"] == 0
    assert s["overall"]["tokens"] == sum(
        c["tokens"] for c in s["classes"].values())
    assert s["overall"]["goodput_tok_s"] <= \
        s["overall"]["throughput_tok_s"] + 1e-9
    # generous deadlines -> every token lands inside its budget
    assert s["classes"]["latency"]["met_tokens"] == \
        s["classes"]["latency"]["tokens"]
    for ch in channels.values():
        assert ch.n_unconsumed() == 0


def test_baseline_mode_strips_sla_annotations():
    m = TrafficModel(seed=7, window_s=10.0)
    pipes, _, _ = build_serving_app(m, 3, prioritize=False)
    specs = [sp for p in pipes for st in p.stages for sp in st.tasks]
    assert specs and all(sp.sla is None for sp in specs)
    pipes, _, _ = build_serving_app(m, 3, prioritize=True)
    slas = {sp.sla for p in pipes for st in p.stages for sp in st.tasks}
    assert slas == {"latency", "throughput"}


def test_sla_registry():
    assert sla_class("latency").priority > sla_class("throughput").priority
    assert sla_class("latency").preempts
    assert not sla_class("throughput").preempts
    with pytest.raises(KeyError):
        sla_class("gold")


# ------------------------------------------- real continuous batching

def test_continuous_batching_token_identity_and_backfill():
    """Per-step admit/evict serves the same tokens as a sequential
    B=1 reference, in fewer decode steps than wave scheduling."""
    jax = pytest.importorskip("jax")
    from repro.models import init_params
    from repro.serve import BatchedServer, Request

    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    S0, new = 4, [3, 5, 2, 4, 3]
    prompts = [rng.integers(0, cfg.vocab_size, S0) for _ in new]

    def serve(batch, reqs):
        srv = BatchedServer(cfg, params, batch=batch, prompt_len=S0,
                            max_len=S0 + max(new))
        srv.submit(reqs)
        return srv, {r.rid: r.out_tokens for r in srv.run()}

    srv, got = serve(2, [Request(rid=i, prompt=p, max_new_tokens=n)
                         for i, (p, n) in enumerate(zip(prompts, new))])
    assert srv.continuous
    # sequential reference: each request alone in a B=1 server
    for i, (p, n) in enumerate(zip(prompts, new)):
        _, ref = serve(1, [Request(rid=i, prompt=p, max_new_tokens=n)])
        assert got[i] == ref[i], f"rid {i} diverged from B=1 reference"
        assert len(got[i]) == n
    # backfill: steps bound is the continuous makespan, not wave sum
    waves_steps = 5 + 4 + 3                 # max-per-wave under B=2
    assert srv.stats["decode_steps"] < waves_steps
    assert srv.stats["decode_steps"] == simulate_continuous(
        [type("R", (), {"rid": i, "max_new_tokens": n})()
         for i, n in enumerate(new)], 2, step_cost_s=1.0).steps


def test_request_clock_stamps_and_submit_guard():
    jax = pytest.importorskip("jax")
    from repro.models import init_params
    from repro.serve import BatchedServer, Request

    cfg = _tiny_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tick = iter(range(100))
    srv = BatchedServer(cfg, params, batch=2, prompt_len=4, max_len=8,
                        clock=lambda: float(next(tick)))
    with pytest.raises(ValueError):
        srv.submit([Request(rid=9, prompt=np.zeros(4, int),
                            max_new_tokens=99)])
    reqs = [Request(rid=i, prompt=np.arange(4), max_new_tokens=2)
            for i in range(3)]
    srv.submit(reqs)
    done = srv.run()
    assert len(done) == 3
    for r in done:
        assert r.done_at > r.submitted_at >= 0.0   # session clock, ordered


def test_sliding_window_cfg_falls_back_to_waves():
    jax = pytest.importorskip("jax")
    from repro.models import init_params
    from repro.serve import BatchedServer

    cfg = _tiny_cfg(layer_pattern=("local", "global"), sliding_window=4)
    srv = BatchedServer(cfg, init_params(cfg, jax.random.PRNGKey(0)),
                        batch=2, prompt_len=4, max_len=8)
    assert not srv.continuous
