"""End-to-end system tests: the paper's five-step application flow with all
three patterns + real LM kernels + fused ensemble mode + serving."""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.core import (FusedEnsemble, Kernel, Pipeline, ReplicaExchange,
                        SimulationAnalysisLoop, SingleClusterEnvironment)


def test_paper_five_step_flow_charcount():
    """Paper Fig.1 steps 1-5 with the paper's own toy workload."""
    class CharCount(Pipeline):                       # step 1: pick pattern
        def stage_1(self, i):                        # step 2: kernels
            k = Kernel("misc.mkfile")
            k.arguments = {"bytes": 1 << 14, "seed": i}
            return k

        def stage_2(self, i):
            return Kernel("misc.ccount")

    cluster = SingleClusterEnvironment(               # step 3: resource
        resource="local.cpu", cores=8, walltime=5)
    cluster.allocate()
    prof = cluster.run(CharCount(stages=2, instances=16))   # step 4
    cluster.deallocate()                              # step 5
    assert prof.n_failed == 0
    assert prof.n_tasks == 32
    counts = [v for k, v in prof.results["tasks"].items()
              if k.endswith("stage2")]
    assert all(c["total"] == 1 << 14 for c in counts)
    assert prof.t_enmd_overhead > 0


def test_replica_exchange_with_lm_members():
    class PBT(ReplicaExchange):
        def __init__(self, cycles, replicas):
            super().__init__(cycles, replicas)
            self.temps = [3e-4 * 1.5 ** i for i in range(replicas)]
            self.temp_history = [list(self.temps)]

        def prepare_replica_for_md(self, r):
            k = Kernel("lm.train")
            k.arguments = {"arch": "reduced:gemma2-2b", "steps": 1,
                           "member": r.id, "ensemble": "systest_pbt",
                           "lr": self.temps[r.id], "batch": 2, "seq": 32}
            return k

        def prepare_exchange(self, replicas):
            k = Kernel("re.exchange")
            k.arguments = {"replicas": len(replicas),
                           "cycle": replicas[0].cycle,
                           "temps": self.temps, "ensemble": "systest_pbt"}
            return k

        def apply_exchange(self, result, replicas):
            self.temps = result["temps"]
            self.temp_history.append(list(self.temps))

    cl = SingleClusterEnvironment(cores=3)
    cl.allocate()
    app = PBT(cycles=2, replicas=3)
    prof = cl.run(app)
    cl.deallocate()
    assert prof.n_failed == 0
    assert len(app.temp_history) == 3
    # losses are real numbers from real training
    for c in range(2):
        assert all(np.isfinite(prof.results[f"exchange_{c}"]["losses"]))


def test_sal_convergence_with_lm():
    class TrainUntil(SimulationAnalysisLoop):
        def simulation_stage(self, it, i):
            k = Kernel("lm.train")
            k.arguments = {"arch": "reduced:gemma2-2b", "steps": 1,
                           "member": i, "ensemble": "systest_sal",
                           "batch": 2, "seq": 32}
            return k

        def analysis_stage(self, it, j):
            k = Kernel("lm.eval")
            k.arguments = {"arch": "reduced:gemma2-2b", "member": j,
                           "ensemble": "systest_sal", "batch": 2, "seq": 32}
            return k

        def should_continue(self, it, results):
            return results[0]["loss"] > 1.0 and it < 1

    cl = SingleClusterEnvironment(cores=2)
    cl.allocate()
    prof = cl.run(TrainUntil(maxiterations=5, simulation_instances=2,
                             analysis_instances=1))
    cl.deallocate()
    assert prof.n_failed == 0
    assert "analysis_0" in prof.results


def test_fused_ensemble_matches_task_semantics():
    """Fused SPMD ensemble runs, losses finite, temperatures permute."""
    cfg = reduced(get_config("gemma2-2b"))
    fe = FusedEnsemble(cfg, 4)
    ens, hist = fe.run(jax.random.PRNGKey(0), cycles=2, steps_per_cycle=1,
                       shape=ShapeSpec("t", "train", 32, 2))
    assert len(hist) == 2
    for h in hist:
        assert np.isfinite(h["losses"]).all()
        # temperature multiset preserved by swaps
        np.testing.assert_allclose(sorted(np.asarray(h["temps"])),
                                   sorted(np.asarray(fe.temps0)), rtol=1e-6)


def test_batched_serving():
    from repro.models import init_params
    from repro.serve import BatchedServer, Request
    cfg = reduced(get_config("gemma2-2b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, batch=2, prompt_len=8, max_len=16)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                    max_new_tokens=3) for i in range(5)]
    srv.submit(reqs)
    done = srv.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 3 for r in done)
    assert srv.stats["prefills"] == 3     # ceil(5/2) waves


def test_lm_checkpoint_kernel(tmp_path):
    class TrainThenSave(Pipeline):
        def stage_1(self, i):
            k = Kernel("lm.train")
            k.arguments = {"arch": "reduced:gemma2-2b", "steps": 1,
                           "member": i, "ensemble": "systest_ck",
                           "batch": 2, "seq": 32}
            return k

        def stage_2(self, i):
            k = Kernel("lm.checkpoint")
            k.arguments = {"dir": str(tmp_path / f"m{i}"), "member": i,
                           "ensemble": "systest_ck"}
            return k

    cl = SingleClusterEnvironment(cores=2)
    cl.allocate()
    prof = cl.run(TrainThenSave(stages=2, instances=2))
    cl.deallocate()
    assert prof.n_failed == 0
    assert (tmp_path / "m0").exists()
