"""Per-kernel validation: production paths vs pure-jnp oracles over
shape/dtype sweeps (+ gradients for attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba.ops import selective_scan
from repro.kernels.mamba.xla import selective_step_xla
from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.rglru.ops import linear_scan

RNG = np.random.default_rng(0)


def _qkv(B, Sq, Sk, H, KH, D, dtype):
    q = jnp.array(RNG.standard_normal((B, Sq, H, D)), dtype)
    k = jnp.array(RNG.standard_normal((B, Sk, KH, D)), dtype)
    v = jnp.array(RNG.standard_normal((B, Sk, KH, D)), dtype)
    return q, k, v


ATTN_CASES = [
    # B, Sq, Sk, H, KH, D, causal, window, softcap, q_offset
    (2, 128, 128, 4, 2, 16, True, 0, 0.0, 0),
    (1, 256, 256, 8, 1, 32, True, 64, 50.0, 0),
    (2, 64, 64, 4, 4, 16, False, 0, 0.0, 0),
    (1, 1, 512, 4, 2, 16, True, 0, 0.0, 511),
    (2, 128, 128, 6, 2, 16, True, 48, 30.0, 0),
    (1, 96, 96, 2, 2, 8, True, 32, 0.0, 0),   # non-pow2 seq -> ref fallback
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_attention_xla_matches_ref(case):
    B, Sq, Sk, H, KH, D, causal, window, cap, qoff = case
    q, k, v = _qkv(B, Sq, Sk, H, KH, D, jnp.float32)
    r = attention_ref(q, k, v, causal=causal, window=window, softcap=cap,
                      q_offset=qoff)
    x = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                        q_offset=qoff, impl="xla", q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(x), np.asarray(r),
                               atol=2e-5, rtol=2e-5)


def test_attention_bf16():
    q, k, v = _qkv(2, 128, 128, 4, 2, 32, jnp.bfloat16)
    r = attention_ref(q, k, v, causal=True)
    x = flash_attention(q, k, v, causal=True, impl="xla",
                        q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(x, np.float32),
                               np.asarray(r, np.float32), atol=3e-2)


def test_attention_segments():
    B, S, H, KH, D = 2, 128, 4, 2, 16
    q, k, v = _qkv(B, S, S, H, KH, D, jnp.float32)
    seg = jnp.sort(jnp.array(RNG.integers(0, 3, (B, S)), jnp.int32), axis=1)
    r = attention_ref(q, k, v, causal=True, seg_q=seg, seg_kv=seg)
    x = flash_attention(q, k, v, causal=True, seg_q=seg, seg_kv=seg,
                        impl="xla", q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(x), np.asarray(r), atol=2e-5)


def test_attention_grads_match_ref():
    q, k, v = _qkv(1, 128, 128, 4, 2, 16, jnp.float32)

    def loss(fn):
        return lambda q, k, v: (fn(q, k, v) ** 2).sum()

    ref_fn = loss(lambda q, k, v: attention_ref(
        q, k, v, causal=True, window=48, softcap=30.0))
    xla_fn = loss(lambda q, k, v: flash_attention(
        q, k, v, causal=True, window=48, softcap=30.0, impl="xla",
        q_chunk=64, kv_chunk=64))
    g_ref = jax.grad(ref_fn, argnums=(0, 1, 2))(q, k, v)
    g_xla = jax.grad(xla_fn, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_xla):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=5e-4)


@pytest.mark.parametrize("B,T,C,chunk", [(2, 256, 32, 64), (1, 128, 8, 128),
                                         (3, 64, 16, 16)])
def test_rglru_scan(B, T, C, chunk):
    x = jnp.array(RNG.standard_normal((B, T, C)), jnp.float32)
    a = jnp.array(RNG.uniform(0.5, 0.999, (B, T, C)), jnp.float32)
    h0 = jnp.array(RNG.standard_normal((B, C)), jnp.float32)
    yr, hr = linear_scan(x, a, h0, impl="ref")
    yx, hx = linear_scan(x, a, h0, impl="xla", chunk=chunk)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hr), atol=1e-4)


@pytest.mark.parametrize("B,T,d,n,chunk", [(2, 128, 16, 4, 32),
                                           (1, 64, 8, 8, 64),
                                           (2, 96, 4, 2, 32)])
def test_mamba_scan(B, T, d, n, chunk):
    x = jnp.array(RNG.standard_normal((B, T, d)), jnp.float32)
    dt = jnp.array(RNG.uniform(1e-3, 0.1, (B, T, d)), jnp.float32)
    A = jnp.array(-RNG.uniform(0.5, 2.0, (d, n)), jnp.float32)
    Bm = jnp.array(RNG.standard_normal((B, T, n)), jnp.float32)
    Cc = jnp.array(RNG.standard_normal((B, T, n)), jnp.float32)
    D = jnp.array(RNG.standard_normal((d,)), jnp.float32)
    h0 = jnp.zeros((B, d, n), jnp.float32)
    yr, hr = selective_scan(x, dt, A, Bm, Cc, D, h0, impl="ref")
    yx, hx = selective_scan(x, dt, A, Bm, Cc, D, h0, impl="xla", chunk=chunk)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hr), atol=1e-4)


def test_mamba_decode_step_matches_scan():
    B, T, d, n = 2, 8, 8, 4
    x = jnp.array(RNG.standard_normal((B, T, d)), jnp.float32)
    dt = jnp.array(RNG.uniform(1e-3, 0.1, (B, T, d)), jnp.float32)
    A = jnp.array(-RNG.uniform(0.5, 2.0, (d, n)), jnp.float32)
    Bm = jnp.array(RNG.standard_normal((B, T, n)), jnp.float32)
    Cc = jnp.array(RNG.standard_normal((B, T, n)), jnp.float32)
    D = jnp.array(RNG.standard_normal((d,)), jnp.float32)
    h0 = jnp.zeros((B, d, n), jnp.float32)
    y_scan, _ = selective_scan(x, dt, A, Bm, Cc, D, h0, impl="ref")
    h = h0
    ys = []
    for t in range(T):
        y1, h = selective_step_xla(x[:, t], dt[:, t], A, Bm[:, t], Cc[:, t],
                                   D, h)
        ys.append(y1)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_scan), atol=1e-4)


@pytest.mark.parametrize("E,C,D,F", [(4, 16, 8, 12), (8, 32, 16, 8)])
def test_moe_gmm(E, C, D, F):
    x = jnp.array(RNG.standard_normal((E, C, D)), jnp.float32)
    w = jnp.array(RNG.standard_normal((E, D, F)), jnp.float32)
    sizes = jnp.array(RNG.integers(0, C + 1, (E,)), jnp.int32)
    r = gmm_ref(x, w, sizes)
    y = gmm(x, w, sizes, impl="xla")
    # xla path computes padding rows too; compare only valid rows
    valid = np.arange(C)[None, :] < np.asarray(sizes)[:, None]
    np.testing.assert_allclose(np.asarray(y) * valid[..., None],
                               np.asarray(r), atol=1e-4)
