"""Pallas TPU kernels validated in interpret mode against the oracles,
swept over shapes and dtypes (the per-kernel allclose deliverable)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba.ops import selective_scan
from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.rglru.ops import linear_scan

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,KH,D,causal,window,cap,qoff",
    [(1, 256, 256, 4, 2, 32, True, 0, 0.0, 0),
     (2, 128, 128, 8, 4, 16, True, 64, 50.0, 0),
     (1, 256, 256, 2, 1, 32, False, 0, 0.0, 0),
     (1, 128, 384, 4, 2, 16, True, 0, 0.0, 256),
     (1, 128, 128, 6, 2, 64, True, 96, 30.0, 0)])
def test_flash_attention_pallas(B, Sq, Sk, H, KH, D, causal, window, cap,
                                qoff, dtype, atol):
    q = jnp.array(RNG.standard_normal((B, Sq, H, D)), dtype)
    k = jnp.array(RNG.standard_normal((B, Sk, KH, D)), dtype)
    v = jnp.array(RNG.standard_normal((B, Sk, KH, D)), dtype)
    r = attention_ref(q, k, v, causal=causal, window=window, softcap=cap,
                      q_offset=qoff)
    p = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                        q_offset=qoff, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(p, np.float32),
                               np.asarray(r, np.float32), atol=atol)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4),
                                        (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("B,T,C,block", [(2, 64, 256, 128), (1, 128, 128, 128),
                                         (3, 32, 512, 256)])
def test_rglru_pallas(B, T, C, block, dtype, atol):
    x = jnp.array(RNG.standard_normal((B, T, C)), dtype)
    a = jnp.array(RNG.uniform(0.5, 0.99, (B, T, C)), dtype)
    h0 = jnp.array(RNG.standard_normal((B, C)), jnp.float32)
    yr, hr = linear_scan(x, a, h0, impl="ref")
    yp, hp = linear_scan(x, a, h0, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(yp, np.float32),
                               np.asarray(yr, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), atol=atol)


@pytest.mark.parametrize("B,T,d,n", [(2, 32, 256, 8), (1, 64, 128, 16),
                                     (2, 16, 512, 4)])
def test_mamba_pallas(B, T, d, n):
    x = jnp.array(RNG.standard_normal((B, T, d)), jnp.float32)
    dt = jnp.array(RNG.uniform(1e-3, 0.1, (B, T, d)), jnp.float32)
    A = jnp.array(-RNG.uniform(0.5, 2.0, (d, n)), jnp.float32)
    Bm = jnp.array(RNG.standard_normal((B, T, n)), jnp.float32)
    Cc = jnp.array(RNG.standard_normal((B, T, n)), jnp.float32)
    D = jnp.array(RNG.standard_normal((d,)), jnp.float32)
    h0 = jnp.array(RNG.standard_normal((B, d, n)), jnp.float32)
    yr, hr = selective_scan(x, dt, A, Bm, Cc, D, h0, impl="ref")
    yp, hp = selective_scan(x, dt, A, Bm, Cc, D, h0,
                            impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), atol=1e-4)


@pytest.mark.parametrize("E,C,D,F", [(4, 256, 128, 256), (8, 128, 256, 128)])
def test_gmm_pallas_skips_padding(E, C, D, F):
    x = jnp.array(RNG.standard_normal((E, C, D)), jnp.float32)
    w = jnp.array(RNG.standard_normal((E, D, F)), jnp.float32)
    sizes = jnp.array(RNG.integers(0, C + 1, (E,)), jnp.int32)
    r = gmm_ref(x, w, sizes)
    p = gmm(x, w, sizes, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(p), np.asarray(r), atol=1e-3)
    # padded rows are exactly zero (skipped, not computed)
    valid = np.arange(C)[None, :] < np.asarray(sizes)[:, None]
    assert (np.asarray(p)[~valid] == 0).all()
