"""repro.staging: content-addressed store, locality-aware transfer
planning, staged channel refs, t_data accounting, and crash replay."""
import json
import os
import tempfile
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import AppManager, Channel, Kernel, PipelineSpec, Stage, \
    TaskSpec
from repro.dist.topology import SlotTopology
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal
from repro.staging import (LocalityMap, ObjectStore, StagedRef,
                           StagingLayer, TransferPlanner, decode_refs,
                           encode_refs, iter_refs)


def _echo(value=None, nbytes=None, sim_duration=None):
    k = Kernel("synthetic.echo")
    k.arguments = {"value": value}
    k.output_nbytes = nbytes
    k.sim_duration = sim_duration
    return k


def _noop(dur=0.0, nbytes=None):
    k = Kernel("synthetic.noop")
    k.sim_duration = dur
    k.output_nbytes = nbytes
    return k


# -------------------------------------------------- store: digests

def test_digest_stable_across_key_order_and_processes():
    s = ObjectStore()
    r1 = s.put({"b": 2, "a": [1, 2, 3]})
    r2 = s.put({"a": [1, 2, 3], "b": 2})        # same content, other order
    assert r1.digest == r2.digest
    assert r1.nbytes == r2.nbytes > 0
    assert s.stats["puts"] == 1 and s.stats["dedup_hits"] == 1
    r3 = s.put({"a": [1, 2, 3], "b": 3})
    assert r3.digest != r1.digest
    # non-JSON payloads hash via pickle and round-trip
    arr = np.arange(6, dtype=np.float32)
    ra = s.put(arr)
    np.testing.assert_array_equal(s.get(ra), arr)


def test_digest_is_type_faithful():
    """JSON-lossy values must NOT share digests with their JSON images,
    and must round-trip with their types intact on the fresh-decode
    (copy) path."""
    s = ObjectStore()
    a = s.put({1: "a"})                          # int key: lossy in JSON
    b = s.put({"1": "a"})
    assert a.digest != b.digest
    assert s.get(a, fresh=True) == {1: "a"}
    assert s.get(b, fresh=True) == {"1": "a"}
    t = s.put({"pair": (1, 2)})                  # tuple: lossy in JSON
    assert s.get(t, fresh=True) == {"pair": (1, 2)}


def test_refcount_released_after_last_consumer():
    s = ObjectStore()
    ref = s.put({"x": 1})                        # one hold (the put)
    s.retain(ref, 2)                             # two more consumers
    assert s.refcount(ref.digest) == 3
    s.release(ref)
    s.release(ref)
    assert s.has(ref.digest)                     # one hold left
    s.release(ref)                               # last consumer
    assert not s.has(ref.digest)
    with pytest.raises(KeyError):
        s.get(ref)
    s.release(ref)                               # over-release: no-op


def test_spill_round_trip():
    with tempfile.TemporaryDirectory() as d:
        s = ObjectStore(byte_budget=200, spill_dir=d)
        vals = [{"i": i, "pad": "x" * 120} for i in range(4)]
        refs = [s.put(v) for v in vals]
        # budget of ~1.5 blobs: older blobs spilled, bytes left memory
        assert s.stats["spills"] >= 2
        assert s.mem_bytes <= 200
        assert os.listdir(d)                     # write-through files
        for v, r in zip(vals, refs):
            assert s.get(r) == v                 # materializes as needed
        assert s.stats["materializations"] >= 2


def test_lru_refreshes_on_link_path():
    """A linked (cached-value) get is a use: under budget pressure the
    hot blob must stay resident and the cold one spill."""
    with tempfile.TemporaryDirectory() as d:
        s = ObjectStore(byte_budget=400, spill_dir=d)
        hot = s.put({"hot": "x" * 150})
        cold = s.put({"cold": "y" * 150})
        s.get(hot)                               # refresh recency
        s.put({"new": "z" * 150})                # forces one spill
        assert s.spilled(cold.digest) and s.in_memory(hot.digest)


def test_store_without_spill_dir_cannot_spill():
    s = ObjectStore(byte_budget=64)
    s.put({"pad": "y" * 200})
    assert s.stats["spills"] == 0 and s.stats["over_budget"] == 1


# -------------------------------------------------- ref encoding

def test_ref_json_round_trip_and_iteration():
    ref = StagedRef("abc123", 512, ("pod0", "pod1"))
    payload = {"member": 1, "loss": 0.5, "traj": ref,
               "list": [ref, {"deep": ref}]}
    enc = encode_refs(payload)
    assert json.loads(json.dumps(enc)) == enc    # JSONL-safe
    dec = decode_refs(enc)
    assert dec["traj"] == ref and dec["list"][1]["deep"] == ref
    assert len(list(iter_refs(payload))) == 3


# -------------------------------------------------- planner decisions

def _pod2x16x16_locality():
    """The pod2x16x16 production mesh: one slot per pod (2 pods)."""
    mesh = SimpleNamespace(devices=np.arange(2 * 16 * 16).reshape(2, 16, 16),
                           axis_names=("pod", "data", "model"))
    topo = SlotTopology.from_mesh(mesh)
    return LocalityMap.from_topology(topo, slots_per_pod=1)


def _pod16x16_locality(n_slots=4):
    """A single pod16x16 carved into submesh slots: every slot shares
    the pod."""
    topo = SlotTopology.even(np.arange(16 * 16), n_slots, ("model",))
    return LocalityMap.from_topology(topo, slots_per_pod=n_slots)


def test_planner_links_within_pod_copies_across():
    loc2 = _pod2x16x16_locality()
    assert loc2.n_pods == 2
    store = ObjectStore()
    planner = TransferPlanner(store, loc2)
    ref = store.put({"traj": list(range(50))}, location=loc2.pod_of(0))

    same = planner.plan(ref, loc2.pod_of(0))
    assert same.mode == "link" and same.cost_s == 0.0
    cross = planner.plan(ref, loc2.pod_of(1))
    assert cross.mode == "copy" and cross.cost_s > 0.0
    # executing the copy lands a replica: the next consumer in pod1 links
    planner.execute(cross)
    assert planner.plan(ref, loc2.pod_of(1)).mode == "link"

    # single-pod pod16x16: every slot shares the pod -> always link
    loc1 = _pod16x16_locality()
    assert loc1.n_pods == 1
    store1 = ObjectStore()
    planner1 = TransferPlanner(store1, loc1)
    r1 = store1.put({"x": 1}, location=loc1.pod_of(0))
    for slot in range(4):
        assert planner1.plan(r1, loc1.pod_of(slot)).mode == "link"


def test_planner_materializes_spilled_blob():
    with tempfile.TemporaryDirectory() as d:
        store = ObjectStore(spill_dir=d)
        planner = TransferPlanner(store, LocalityMap(2))
        ref = store.put({"big": "z" * 500}, location="pod0")
        assert store.spill(ref.digest)
        spec = planner.plan(ref, "pod0")
        assert spec.mode == "materialize" and spec.cost_s > 0
        assert planner.execute(spec) == {"big": "z" * 500}
        assert planner.plan(ref, "pod0").mode == "link"   # resident again


# -------------------------------------------------- staged channels (real)

def _staged_rt(mode="real", slots=4, slots_per_pod=2, **kw):
    lay = StagingLayer(locality=LocalityMap(slots,
                                            slots_per_pod=slots_per_pod),
                       threshold_bytes=64, **kw)
    return PilotRuntime(slots=slots, mode=mode, staging=lay), lay


def test_channel_put_staged_and_deref_into_inputs():
    rt, lay = _staged_rt()
    ch = Channel("data")
    big = {"payload": list(range(200))}
    prod = PipelineSpec([Stage([TaskSpec(_echo(big), name="p0")],
                               name="s", outputs=[ch])], name="P")
    cons = PipelineSpec([Stage([TaskSpec(_echo("c"), name="c0")],
                               name="a", inputs={"d": ch})], name="C")
    am = AppManager(rt)
    prof = am.run([prod, cons])
    assert prof.n_failed == 0
    # the channel moved a ref, the kernel saw the value
    assert isinstance(ch.puts[0][1], StagedRef)
    assert prof.results["tasks"]["c0"]["inputs"]["d"] == \
        {"p0": {"value": big}}
    # per-task t_data accounted and rolled up; the decoded payload is NOT
    # pinned on the finished task (that would defeat the byte budget)
    c0 = am.session.graph.tasks["c0"]
    assert c0.t_data > 0.0
    assert "staged_values" not in c0.meta
    assert prof.t_data > 0.0
    summ = prof.results["staging"]
    assert summ["transfers"]["n_transfers"] == 1
    # last consumer released the blob
    assert len(lay.store) == 0 and lay.store.stats["releases"] >= 1


def test_small_puts_keep_value_fast_path():
    rt, lay = _staged_rt()
    ch = Channel("small")
    prod = PipelineSpec([Stage([TaskSpec(_echo(1), name="sp")],
                               name="s", outputs=[ch])], name="P")
    cons = PipelineSpec([Stage([TaskSpec(_echo("c"), name="sc")],
                               name="a", inputs={"d": ch})], name="C")
    prof = AppManager(rt).run([prod, cons])
    assert prof.n_failed == 0
    assert not isinstance(ch.puts[0][1], StagedRef)
    assert lay.store.stats["puts"] == 0


def test_stage_in_declarations_dedup_across_members():
    """N member tasks declaring the same upload stage ONE blob (the
    paper's link semantics) and receive it as ctx['staged_inputs']."""
    rt, lay = _staged_rt()
    shared = {"weights": list(range(100))}
    seen = []

    def dl(res):
        seen.append(res)

    ks = []
    for m in range(3):
        k = _echo(m)
        k.upload_input_data = [shared]           # legacy directive
        k.download_output_data = [dl]
        ks.append(k)
    stage = Stage([TaskSpec(k, name=f"m{m}") for m, k in enumerate(ks)],
                  name="sim")
    prof = AppManager(rt).run(PipelineSpec([stage], name="E"))
    assert prof.n_failed == 0
    assert lay.store.stats["puts"] == 1          # one blob...
    assert lay.store.stats["dedup_hits"] == 2    # ...linked by the others
    assert len(seen) == 3                        # stage_out ran per task
    assert prof.t_data > 0.0
    assert len(lay.store) == 0                   # all members released


def test_locality_aware_placement_links():
    """The consumer is granted a slot in the producer's pod, so the
    transfer resolves to link (pod-local), not copy."""
    rt, lay = _staged_rt(slots=4, slots_per_pod=2)
    ch = Channel("t")
    big = {"traj": list(range(300))}
    prod = PipelineSpec([Stage([TaskSpec(_echo(big), name="lp")],
                               name="s", outputs=[ch])], name="P")
    cons = PipelineSpec([Stage([TaskSpec(_echo("c"), name="lc")],
                               name="a", inputs={"d": ch})], name="C")
    prof = AppManager(rt).run([prod, cons])
    assert prof.n_failed == 0
    tr = prof.results["staging"]["transfers"]
    assert tr["link"] == 1 and tr["copy"] == 0
    assert tr["locality_hit_rate"] == 1.0


def test_abstract_slot_ids_never_duplicated_by_shrink_then_grow():
    """Resizing a staging pilot (abstract slot ids) must never re-mint an
    id a task still holds or that is already free — duplicate ids would
    alias two tasks onto one locality domain."""
    from repro.runtime.states import Task, TaskGraph
    lay = StagingLayer(locality=LocalityMap(4))
    rt = PilotRuntime(slots=3, mode="sim", staging=lay)
    g = TaskGraph()
    g.add(Task(name="hold", duration=30.0))      # holds an id throughout
    g.add(Task(name="a", duration=10.0))
    g.add(Task(name="e", duration=12.0))
    g.add(Task(name="f", duration=5.0, deps=["a", "e"]))

    def schedule(rt_, graph, vnow):
        if vnow == 10.0:
            rt_.resize(2)                        # shrink: retire a free id
        elif vnow == 12.0:
            rt_.resize(3)                        # grow back under a holder
    rt.on_schedule = schedule
    prof = rt.run(g)
    assert prof.n_failed == 0 and prof.n_canceled == 0
    assert rt.slots == 3
    free = rt._free_ids
    assert len(free) == len(set(free)), f"duplicate slot ids: {free}"
    assert rt._minted == set(free)               # everything retired home


# -------------------------------------------------- DES-mode t_data

def test_sim_mode_models_t_data_from_declared_output_nbytes():
    """Virtual refs: no payload exists in DES mode, but declared output
    sizes charge t_data and extend occupancy on the virtual clock."""
    lay = StagingLayer(locality=LocalityMap(2, slots_per_pod=1),
                       threshold_bytes=1, prefer_local=False)
    rt = PilotRuntime(slots=2, mode="sim", staging=lay)
    ch = Channel("t")
    nbytes = 25 * (10 ** 9)                      # 1s at 25 GB/s
    prod = PipelineSpec([Stage([TaskSpec(_noop(4.0, nbytes), name="vp")],
                               name="s", outputs=[ch])], name="P")
    cons = PipelineSpec([Stage([TaskSpec(_noop(1.0), name="vc")],
                               name="a", inputs={"d": ch})], name="C")
    am = AppManager(rt)
    prof = am.run([prod, cons])
    assert prof.n_failed == 0
    vc = am.session.graph.tasks["vc"]
    assert vc.t_data == pytest.approx(1.0, rel=0.01)
    assert prof.t_data == pytest.approx(vc.t_data)
    # the transfer occupies the consumer on the virtual clock
    assert prof.ttc == pytest.approx(4.0 + 1.0 + vc.t_data, rel=0.01)
    assert prof.per_stage["a"]["t_data"] == pytest.approx(vc.t_data)


def test_sim_mode_pod_local_link_avoids_the_copy():
    """Same workload, but producer and consumer share the pod: the
    planner links and t_data collapses to ~0."""
    lay = StagingLayer(locality=LocalityMap(2, slots_per_pod=2),
                       threshold_bytes=1)
    rt = PilotRuntime(slots=2, mode="sim", staging=lay)
    ch = Channel("t")
    prod = PipelineSpec([Stage([TaskSpec(_noop(4.0, 25 * 10 ** 9),
                                         name="wp")],
                               name="s", outputs=[ch])], name="P")
    cons = PipelineSpec([Stage([TaskSpec(_noop(1.0), name="wc")],
                               name="a", inputs={"d": ch})], name="C")
    prof = AppManager(rt).run([prod, cons])
    assert prof.n_failed == 0
    assert prof.t_data == 0.0
    tr = prof.results["staging"]["transfers"]
    assert tr["link"] == 1 and tr["locality_hit_rate"] == 1.0


def test_sim_mode_skips_stage_out_callables():
    """DES tasks execute nothing: legacy download callables (defaulted
    into stage_out) must not fire on the None placeholder results."""
    probe = []
    k = _noop(1.0, nbytes=25 * 10 ** 9)
    k.download_output_data = [lambda res: probe.append(res["traj"])]
    lay = StagingLayer(locality=LocalityMap(2), threshold_bytes=1)
    rt = PilotRuntime(slots=2, mode="sim", staging=lay)
    prof = AppManager(rt).run(
        PipelineSpec([Stage([TaskSpec(k, name="dl")], name="s")],
                     name="P"))
    assert prof.n_failed == 0
    assert probe == []


def test_stage_level_declarations_require_staging_layer():
    """Stage.stage_in has no kernel-side fallback: running it on a plain
    pilot must fail loudly, not silently drop the declared inputs."""
    stage = Stage([TaskSpec(_echo(1), name="t")], name="s",
                  stage_in=[{"x": 1}])
    with pytest.raises(ValueError, match="no staging layer"):
        AppManager(PilotRuntime(slots=2, mode="real")).run(
            PipelineSpec([stage], name="P"))


def test_restart_without_spill_dir_replays_by_value():
    """No spill_dir -> a journaled ref's payload dies with the process,
    so the journal carries the payload itself and a restart replays by
    value (re-staging fresh) instead of failing the consumer."""
    with tempfile.TemporaryDirectory() as d:
        jp = os.path.join(d, "j.jsonl")
        big = {"payload": list(range(200))}

        def run(probe):
            lay = StagingLayer(locality=LocalityMap(4, slots_per_pod=2),
                               threshold_bytes=64)     # NO spill_dir
            rt = PilotRuntime(slots=4, mode="real",
                              journal=Journal(jp), staging=lay)
            ch = Channel("d")
            ak = Kernel("synthetic.echo")
            ak.arguments = {"value": "c"}
            ak.download_output_data = [
                lambda res: probe.append(res.get("inputs"))]
            prod = PipelineSpec([Stage([TaskSpec(_echo(big), name="np")],
                                       name="s", outputs=[ch])], name="P")
            cons = PipelineSpec([Stage([TaskSpec(ak, name="nc")],
                                       name="a", inputs={"d": ch})],
                                name="C")
            prof = AppManager(rt).run([prod, cons])
            rt.journal.close()
            return prof, lay

        p1, _ = run([])
        assert p1.n_failed == 0
        # crash before the consumer ran
        keep = [ln for ln in open(jp).read().splitlines()
                if "nc" not in ln
                and json.loads(ln).get("event") != "channel_take"]
        with open(jp, "w") as f:
            f.write("\n".join(keep) + "\n")
        probe2 = []
        p2, lay2 = run(probe2)
        assert p2.n_failed == 0                  # consumer replayed fine
        assert probe2 == [{"d": {"np": {"value": big}}}]
        # the journaled payload replays straight through the channel by
        # value — nothing to re-stage
        assert lay2.store.stats["puts"] == 0


def test_sim_restart_replays_virtual_refs():
    """A DES run journals virtual refs (digest + nbytes, no payload); a
    restarted consumer must re-register them from the ref metadata
    instead of crashing the drain with an unknown-blob KeyError."""
    with tempfile.TemporaryDirectory() as d:
        jp = os.path.join(d, "j.jsonl")

        def run():
            lay = StagingLayer(locality=LocalityMap(2, slots_per_pod=1),
                               threshold_bytes=1, prefer_local=False)
            rt = PilotRuntime(slots=2, mode="sim", journal=Journal(jp),
                              staging=lay)
            ch = Channel("t")
            prod = PipelineSpec(
                [Stage([TaskSpec(_noop(4.0, 25 * 10 ** 9), name="vp")],
                       name="s", outputs=[ch])], name="P")
            cons = PipelineSpec(
                [Stage([TaskSpec(_noop(1.0), name="vc")], name="a",
                       inputs={"d": ch})], name="C")
            am = AppManager(rt)
            prof = am.run([prod, cons])
            rt.journal.close()
            return prof, am

        run()
        # crash: the producer finished and its put was journaled, the
        # consumer never ran
        keep = [ln for ln in open(jp).read().splitlines()
                if "vc" not in ln
                and json.loads(ln).get("event") != "channel_take"]
        with open(jp, "w") as f:
            f.write("\n".join(keep) + "\n")
        prof2, am2 = run()
        assert prof2.n_failed == 0
        vc = am2.session.graph.tasks["vc"]
        assert vc.t_data == pytest.approx(1.0, rel=0.05)  # modeled copy
        assert prof2.t_data == pytest.approx(vc.t_data)


# -------------------------------------------------- journal replay

def _coupled_staged(journal_path, spill_dir, probe):
    lay = StagingLayer(locality=LocalityMap(4, slots_per_pod=2),
                       threshold_bytes=64, spill_dir=spill_dir)
    rt = PilotRuntime(slots=4, mode="real", journal=Journal(journal_path),
                      staging=lay)
    ch = Channel("traj")

    def ana_kernel(r):
        k = Kernel("synthetic.echo")
        k.arguments = {"value": f"round{r}"}
        k.download_output_data = [
            lambda res, _r=r: probe.append((_r, res.get("inputs")))]
        return k

    prod = PipelineSpec(
        [Stage([TaskSpec(_echo({"cycle": c, "pad": [c] * 200}),
                         name=f"prod.c{c}")],
               name=f"cycle{c}", outputs=[ch]) for c in range(2)],
        name="producer")
    ana = PipelineSpec(
        [Stage([TaskSpec(ana_kernel(r), name=f"ana.r{r}")],
               name=f"round{r}", inputs={"traj": ch}) for r in range(2)],
        name="analysis")
    prof = AppManager(rt).run([prod, ana])
    rt.journal.close()
    return prof, lay


def test_full_restart_replays_refs_with_zero_restaging():
    with tempfile.TemporaryDirectory() as d:
        jp, spill = os.path.join(d, "j.jsonl"), os.path.join(d, "blobs")
        probe1, probe2 = [], []
        prof1, lay1 = _coupled_staged(jp, spill, probe1)
        assert prof1.n_failed == 0 and len(probe1) == 2
        assert lay1.store.stats["puts"] == 2
        prof2, lay2 = _coupled_staged(jp, spill, probe2)
        assert prof2.n_failed == 0
        assert probe2 == []                      # nothing re-executed
        assert lay2.store.stats["puts"] == 0     # ZERO re-staging
        # journaled puts carry the digest of the staged blob
        recs = [json.loads(ln) for ln in open(jp)]
        puts = [r for r in recs if r.get("event") == "channel_put"]
        assert all("digest" in p and p["nbytes"] > 0 for p in puts)


def test_midtransfer_crash_materializes_from_spill():
    """Crash after the producer's put was journaled but before the
    consumer ran: the restart re-binds the journaled ref and pulls the
    payload from the content-addressed spill file — identical input,
    no re-staging of the producer's blob."""
    with tempfile.TemporaryDirectory() as d:
        jp, spill = os.path.join(d, "j.jsonl"), os.path.join(d, "blobs")
        probe1, probe2 = [], []
        prof1, _ = _coupled_staged(jp, spill, probe1)
        assert prof1.n_failed == 0

        keep = []
        for ln in open(jp).read().splitlines():
            rec = json.loads(ln)
            tag = rec.get("task", "") + rec.get("producer", "") \
                + rec.get("consumer", "")
            if ("c1" not in tag and "r1" not in tag and "0001" not in tag
                    and "ana" not in tag
                    and rec.get("event") != "channel_take"):
                keep.append(ln)
        with open(jp, "w") as f:                 # + torn crash line
            f.write("\n".join(keep) + '\n{"task": "prod.c1", "ev')

        prof2, lay2 = _coupled_staged(jp, spill, probe2)
        assert prof2.n_failed == 0
        # both analysis rounds re-executed; round 0 saw the IDENTICAL
        # payload, re-materialized from the spill file
        assert sorted(r for r, _ in probe2) == [0, 1]
        r0 = dict(probe2)[0]
        assert r0 == probe1[0][1]
        assert lay2.store.stats["materializations"] == 1
        assert lay2.store.stats["puts"] == 1     # only cycle1 re-staged
        recs = []
        for ln in open(jp):
            try:
                recs.append(json.loads(ln))
            except json.JSONDecodeError:
                pass
        sched = [r["task"] for r in recs if r.get("event") == "scheduled"]
        # ana records were truncated away (the crash), so each task shows
        # exactly one surviving scheduled record across crash + restart
        assert sorted(sched) == ["ana.r0", "ana.r1", "prod.c0", "prod.c1"]


# -------------------------------------------------- lazy nested refs

def test_nested_refs_stay_lazy_and_exchange_reports_avoided_bytes():
    """A kernel stages its bulk output explicitly (ctx['staging'].put);
    the exchange consumer reads only scalars, never pays for the bulk
    field, and reports the avoided traffic."""
    from repro.core.execution_plugin import get_plugin
    from repro.core.patterns import ReplicaExchange
    from repro.core.resource_handler import Pilot, ResourceSpec

    rt, lay = _staged_rt(slots=4, slots_per_pod=4)

    class RE(ReplicaExchange):
        def prepare_replica_for_md(self, r):
            k = Kernel("synthetic.member")
            k.arguments = {"member": r.id, "loss": 1.0 + r.id,
                           "bulk_n": 500}
            return k

        def prepare_exchange(self, replicas):
            k = Kernel("re.exchange")
            k.arguments = {"replicas": len(replicas),
                           "temps": [1.0 + 0.1 * r.id for r in replicas]}
            return k

        def apply_exchange(self, result, replicas):
            pass

    # a member kernel that stages a big trajectory and returns a ref
    from repro.core.kernel_plugin import _KERNEL_REGISTRY, register_kernel
    if "synthetic.member" not in _KERNEL_REGISTRY:
        @register_kernel("synthetic.member",
                         description="member result with staged bulk")
        def member(args, ctx):
            ref = ctx["staging"].put({"traj": [0.0] * args["bulk_n"]})
            return {"member": args["member"], "loss": args["loss"],
                    "traj": ref}

    pat = RE(cycles=1, replicas=4)
    pilot = Pilot(ResourceSpec(cores=4), rt)
    prof = get_plugin(pat, pilot).execute()
    assert prof.n_failed == 0
    xres = prof.results["exchange_0"]
    assert xres["losses"] == [1.0, 2.0, 3.0, 4.0]
    assert xres["staged_avoided_bytes"] > 4 * 500 * 3   # 4 bulk blobs
    # the exchange never dereferenced the trajectories
    assert lay.planner.stats["link"] == 0
    assert lay.planner.stats["copy"] == 0
