"""Runtime/scheduler invariants: unit tests + hypothesis property tests."""
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState


def _graph(durations, deps=None, slots=None):
    g = TaskGraph()
    for i, d in enumerate(durations):
        g.add(Task(name=f"t{i}", duration=float(d),
                   deps=[f"t{j}" for j in (deps or {}).get(i, [])],
                   slots=(slots or {}).get(i, 1), stage="s"))
    return g


# ---------------------------------------------------------------- property

@settings(max_examples=60, deadline=None)
@given(st.data())
def test_sim_scheduler_invariants(data):
    n = data.draw(st.integers(1, 24))
    slots = data.draw(st.integers(1, 8))
    durations = data.draw(st.lists(
        st.floats(0.0, 100.0, allow_nan=False), min_size=n, max_size=n))
    # random acyclic deps: edges only to earlier tasks
    deps = {}
    for i in range(n):
        if i and data.draw(st.booleans()):
            k = data.draw(st.integers(1, min(i, 3)))
            deps[i] = list(np.random.default_rng(i).choice(i, k, False))
    g = _graph(durations, deps)
    rt = PilotRuntime(slots=slots, mode="sim")
    prof = rt.run(g)

    # all tasks reach a terminal state, none failed (no failure injection)
    assert all(t.state == TaskState.DONE for t in g.tasks.values())
    # makespan bounds: >= critical path, <= serial sum (+eps)
    total = sum(durations)
    # critical path lower bound
    cp = {}
    for i in range(n):
        cp[i] = durations[i] + max((cp[j] for j in deps.get(i, [])),
                                   default=0.0)
    assert prof.ttc >= max(cp.values()) - 1e-6
    assert prof.ttc <= total + 1e-6
    # never oversubscribed: with 1-slot tasks, ttc >= total/slots
    assert prof.ttc >= total / slots - 1e-6
    # dependency order respected
    for i, t in enumerate(g.tasks.values()):
        for d in t.deps:
            assert g.tasks[d].v_finished <= t.v_started + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 30), st.integers(1, 6))
def test_sim_bag_exact_makespan(n, slots):
    g = _graph([10.0] * n)
    prof = PilotRuntime(slots=slots, mode="sim").run(g)
    assert abs(prof.ttc - 10.0 * int(np.ceil(n / slots))) < 1e-9


# ---------------------------------------------------------------- unit

def test_real_mode_runs_callables():
    calls = []
    g = TaskGraph()
    g.add(Task(name="a", run=lambda t: calls.append("a") or 1))
    g.add(Task(name="b", deps=["a"], run=lambda t: calls.append("b") or 2))
    prof = PilotRuntime(slots=2, mode="real").run(g)
    assert calls == ["a", "b"]
    assert g.tasks["b"].result == 2
    assert prof.n_failed == 0


def test_retry_then_fail_cancels_dependents():
    attempts = {"n": 0}

    def boom(t):
        attempts["n"] += 1
        raise RuntimeError("nope")

    g = TaskGraph()
    g.add(Task(name="a", run=boom))
    g.add(Task(name="b", deps=["a"], run=lambda t: 1))
    prof = PilotRuntime(slots=1, mode="real", max_retries=2).run(g)
    assert attempts["n"] == 3                        # 1 + 2 retries
    assert g.tasks["a"].state == TaskState.FAILED
    assert g.tasks["b"].state == TaskState.CANCELED
    assert prof.n_failed == 1


def test_straggler_speculation_cuts_makespan():
    g = _graph([10.0] * 15 + [100.0])
    rt = PilotRuntime(slots=8, mode="sim", straggler_factor=2.0)
    prof = rt.run(g)
    assert prof.n_speculative >= 1
    assert prof.ttc < 100.0
    assert all(t.state == TaskState.DONE for t in g.tasks.values())


def test_elastic_grow_and_shrink():
    rt = PilotRuntime(slots=2, mode="sim")
    rt.resize(4)
    prof = rt.run(_graph([10.0] * 8))
    assert prof.ttc == 20.0
    rt = PilotRuntime(slots=8, mode="sim")
    rt.resize(2)
    prof = rt.run(_graph([10.0] * 8))
    assert prof.ttc == 40.0


def test_journal_restart_skips_done():
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/j.jsonl"
        g1 = _graph([1.0] * 5)
        PilotRuntime(slots=2, mode="sim", journal=Journal(path)).run(g1)
        g2 = _graph([1.0] * 5)
        prof = PilotRuntime(slots=2, mode="sim",
                            journal=Journal(path)).run(g2)
        assert prof.ttc == 0.0          # everything replayed as DONE
        assert all(t.state == TaskState.DONE for t in g2.tasks.values())


def test_graph_cycle_detection():
    g = TaskGraph()
    g.add(Task(name="a", deps=["b"]))
    g.add(Task(name="b", deps=["a"]))
    with pytest.raises(ValueError, match="cycle"):
        g.validate()


def test_multislot_tasks_respect_capacity():
    g = _graph([10.0, 10.0, 10.0], slots={0: 2, 1: 2, 2: 2})
    prof = PilotRuntime(slots=4, mode="sim").run(g)
    assert prof.ttc == 20.0             # two fit concurrently, third waits


def test_metropolis_host_vs_device():
    import jax
    import jax.numpy as jnp
    from repro.core.ensemble import metropolis_swap_device
    from repro.plugins.re_exchange import metropolis_swaps
    losses = np.array([3.0, 2.5, 2.0, 1.5])
    temps = np.array([1.0, 2.0, 4.0, 8.0])
    # deterministic acceptance: huge energy gap -> always swap pair (0,1)
    newt, acc = metropolis_swaps([10.0, 0.0, 0.0, 0.0],
                                 [1.0, 10.0, 10.0, 10.0], cycle=0)
    assert (newt[0], newt[1]) == (10.0, 1.0)
    nt_dev, nacc = metropolis_swap_device(
        jnp.array([10.0, 0.0, 0.0, 0.0]), jnp.array([1.0, 10.0, 10.0, 10.0]),
        0, jax.random.PRNGKey(0))
    assert float(nt_dev[0]) == 10.0 and float(nt_dev[1]) == 1.0
