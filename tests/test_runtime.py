"""Runtime/scheduler invariants: unit tests + hypothesis property tests."""
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState


def _graph(durations, deps=None, slots=None):
    g = TaskGraph()
    for i, d in enumerate(durations):
        g.add(Task(name=f"t{i}", duration=float(d),
                   deps=[f"t{j}" for j in (deps or {}).get(i, [])],
                   slots=(slots or {}).get(i, 1), stage="s"))
    return g


# ---------------------------------------------------------------- property

@settings(max_examples=60, deadline=None)
@given(st.data())
def test_sim_scheduler_invariants(data):
    n = data.draw(st.integers(1, 24))
    slots = data.draw(st.integers(1, 8))
    durations = data.draw(st.lists(
        st.floats(0.0, 100.0, allow_nan=False), min_size=n, max_size=n))
    # random acyclic deps: edges only to earlier tasks
    deps = {}
    for i in range(n):
        if i and data.draw(st.booleans()):
            k = data.draw(st.integers(1, min(i, 3)))
            deps[i] = list(np.random.default_rng(i).choice(i, k, False))
    g = _graph(durations, deps)
    rt = PilotRuntime(slots=slots, mode="sim")
    prof = rt.run(g)

    # all tasks reach a terminal state, none failed (no failure injection)
    assert all(t.state == TaskState.DONE for t in g.tasks.values())
    # makespan bounds: >= critical path, <= serial sum (+eps)
    total = sum(durations)
    # critical path lower bound
    cp = {}
    for i in range(n):
        cp[i] = durations[i] + max((cp[j] for j in deps.get(i, [])),
                                   default=0.0)
    assert prof.ttc >= max(cp.values()) - 1e-6
    assert prof.ttc <= total + 1e-6
    # never oversubscribed: with 1-slot tasks, ttc >= total/slots
    assert prof.ttc >= total / slots - 1e-6
    # dependency order respected
    for i, t in enumerate(g.tasks.values()):
        for d in t.deps:
            assert g.tasks[d].v_finished <= t.v_started + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 30), st.integers(1, 6))
def test_sim_bag_exact_makespan(n, slots):
    g = _graph([10.0] * n)
    prof = PilotRuntime(slots=slots, mode="sim").run(g)
    assert abs(prof.ttc - 10.0 * int(np.ceil(n / slots))) < 1e-9


# ---------------------------------------------------------------- unit

def test_real_mode_runs_callables():
    calls = []
    g = TaskGraph()
    g.add(Task(name="a", run=lambda t: calls.append("a") or 1))
    g.add(Task(name="b", deps=["a"], run=lambda t: calls.append("b") or 2))
    prof = PilotRuntime(slots=2, mode="real").run(g)
    assert calls == ["a", "b"]
    assert g.tasks["b"].result == 2
    assert prof.n_failed == 0


def test_retry_then_fail_cancels_dependents():
    attempts = {"n": 0}

    def boom(t):
        attempts["n"] += 1
        raise RuntimeError("nope")

    g = TaskGraph()
    g.add(Task(name="a", run=boom))
    g.add(Task(name="b", deps=["a"], run=lambda t: 1))
    prof = PilotRuntime(slots=1, mode="real", max_retries=2).run(g)
    assert attempts["n"] == 3                        # 1 + 2 retries
    assert g.tasks["a"].state == TaskState.FAILED
    assert g.tasks["b"].state == TaskState.CANCELED
    assert prof.n_failed == 1


def test_straggler_speculation_cuts_makespan():
    g = _graph([10.0] * 15 + [100.0])
    rt = PilotRuntime(slots=8, mode="sim", straggler_factor=2.0)
    prof = rt.run(g)
    assert prof.n_speculative >= 1
    assert prof.ttc < 100.0
    assert all(t.state == TaskState.DONE for t in g.tasks.values())


def test_elastic_grow_and_shrink():
    rt = PilotRuntime(slots=2, mode="sim")
    rt.resize(4)
    prof = rt.run(_graph([10.0] * 8))
    assert prof.ttc == 20.0
    rt = PilotRuntime(slots=8, mode="sim")
    rt.resize(2)
    prof = rt.run(_graph([10.0] * 8))
    assert prof.ttc == 40.0


def test_journal_restart_skips_done():
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/j.jsonl"
        g1 = _graph([1.0] * 5)
        PilotRuntime(slots=2, mode="sim", journal=Journal(path)).run(g1)
        g2 = _graph([1.0] * 5)
        prof = PilotRuntime(slots=2, mode="sim",
                            journal=Journal(path)).run(g2)
        assert prof.ttc == 0.0          # everything replayed as DONE
        assert all(t.state == TaskState.DONE for t in g2.tasks.values())


def test_graph_cycle_detection():
    g = TaskGraph()
    g.add(Task(name="a", deps=["b"]))
    g.add(Task(name="b", deps=["a"]))
    with pytest.raises(ValueError, match="cycle"):
        g.validate()


def test_multislot_tasks_respect_capacity():
    g = _graph([10.0, 10.0, 10.0], slots={0: 2, 1: 2, 2: 2})
    prof = PilotRuntime(slots=4, mode="sim").run(g)
    assert prof.ttc == 20.0             # two fit concurrently, third waits


# ------------------------------------------------------- sim-mode edges

def _slot_topology(n):
    from repro.dist.topology import SlotTopology
    # slot accounting needs no real devices; any object array works
    return SlotTopology(np.arange(n).reshape(n, 1), ("model",))


def test_speculative_supersession_frees_slot_exactly_once():
    """Duplicate wins: the straggling original's slot is freed at
    supersession and must NOT be freed again when its stale finish event
    pops off the heap."""
    topo = _slot_topology(8)
    g = _graph([10.0] * 15 + [200.0])
    rt = PilotRuntime(mode="sim", straggler_factor=2.0, topology=topo)
    prof = rt.run(g)
    orig = g.tasks["t15"]
    assert prof.n_speculative == 1
    assert orig.state == TaskState.DONE
    assert orig.meta.get("slot_freed") is True          # superseded
    # duplicate launched at trigger=30 (2x median after start=10), runs the
    # median 10s: makespan 40, far below the 200s straggler
    assert prof.ttc == 40.0
    assert all(t.state == TaskState.DONE for t in g.tasks.values())
    # slot-id pool intact: a double free (or leak) would change its size
    assert sorted(rt._free_ids) == list(range(8))
    assert prof.slot_busy <= prof.ttc * 8 + 1e-9


def test_canceled_twin_bookkeeping():
    """Original wins: the speculative twin is CANCELED and contributes
    nothing to t_exec/slot_busy; its heap pop releases its slot."""
    topo = _slot_topology(8)
    g = _graph([10.0] * 15 + [25.0])
    rt = PilotRuntime(mode="sim", straggler_factor=2.0, topology=topo)
    prof = rt.run(g)
    # trigger 30 + median 10 = 40 > the original's finish at 35: orig wins
    assert prof.n_speculative == 1
    assert g.tasks["t15"].state == TaskState.DONE
    assert not g.tasks["t15"].meta.get("slot_freed")    # not superseded
    assert prof.ttc == 35.0
    assert prof.t_exec == 15 * 10.0 + 25.0              # twin excluded
    assert prof.slot_busy == prof.t_exec                # 1-slot tasks
    assert sorted(rt._free_ids) == list(range(8))       # twin's id returned


def test_resize_takes_effect_mid_run():
    """Elastic grow DURING a sim run (not between runs): the on_schedule
    hook fires resize() once the first wave finished; later waves run at
    the new width."""
    fired = []

    def grow(rt, graph, vnow):
        if vnow is not None and vnow >= 10.0 and not fired:
            fired.append(vnow)
            rt.resize(4)

    rt = PilotRuntime(slots=2, mode="sim", on_schedule=grow)
    g = _graph([10.0] * 8)
    prof = rt.run(g)
    # wave1: 2 tasks @[0,10); resize at v=10; then 4-wide: 4 @[10,20),
    # 2 @[20,30) -> makespan 30 (serial 2-wide would be 40)
    assert fired and fired[0] == 10.0
    assert prof.ttc == 30.0
    assert rt.slots == 4
    assert all(t.state == TaskState.DONE for t in g.tasks.values())


def test_resize_takes_effect_mid_run_real_mode():
    """Real-mode grow while a task is in flight: the freed capacity must
    reach the scheduler (two tasks rendezvous on a barrier that only
    passes if both run concurrently)."""
    import threading

    barrier = threading.Barrier(2, timeout=10)
    g = TaskGraph()
    g.add(Task(name="a", run=lambda t: barrier.wait()))
    g.add(Task(name="b", run=lambda t: barrier.wait()))
    grown = []

    def grow(rt, graph, vnow):
        if not grown and graph.tasks["a"].state == TaskState.RUNNING:
            grown.append(1)
            rt.resize(2)

    prof = PilotRuntime(slots=1, mode="real", on_schedule=grow).run(g)
    assert grown
    assert prof.n_failed == 0
    assert all(t.state == TaskState.DONE for t in g.tasks.values())


def test_real_mode_never_oversubscribes():
    """Regression: one scheduling pass admits several ready tasks and must
    re-check capacity per task (a stale snapshot launched 2 tasks on a
    1-slot pilot)."""
    import threading
    import time as _time

    lock = threading.Lock()
    concurrency = {"now": 0, "max": 0}

    def work(t):
        with lock:
            concurrency["now"] += 1
            concurrency["max"] = max(concurrency["max"], concurrency["now"])
        _time.sleep(0.05)
        with lock:
            concurrency["now"] -= 1

    g = TaskGraph()
    for i in range(4):
        g.add(Task(name=f"t{i}", run=work))
    prof = PilotRuntime(slots=2, mode="real").run(g)
    assert prof.n_failed == 0
    assert concurrency["max"] <= 2


def test_multislot_with_topology_grants_disjoint_submeshes():
    topo = _slot_topology(4)
    g = _graph([10.0, 10.0, 10.0], slots={0: 2, 1: 2, 2: 2})
    rt = PilotRuntime(mode="sim", topology=topo)
    prof = rt.run(g)
    assert prof.ttc == 20.0
    for t in g.tasks.values():
        assert len(t.meta["slot_ids"]) == 2
    # the two concurrent tasks held disjoint ids
    first_wave = [t for t in g.tasks.values() if t.v_started == 0.0]
    held = sum((t.meta["slot_ids"] for t in first_wave), [])
    assert len(held) == len(set(held)) == 4
    assert sorted(rt._free_ids) == list(range(4))


# ------------------------------------------------------- journal replay

def test_journal_partial_replay_skips_done():
    """Restart from a PARTIAL journal: only unjournaled tasks re-run, and
    the restarted profile still accounts for the full graph."""
    import json
    import os

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.jsonl")
        g1 = _graph([1.0] * 6)
        prof1 = PilotRuntime(slots=2, mode="sim",
                             journal=Journal(path)).run(g1)
        # crash simulation: keep the records of 3 tasks + one torn line
        keep = [ln for ln in open(path).read().splitlines()
                if json.loads(ln).get("task") in ("t0", "t1", "t2")]
        with open(path, "w") as f:
            f.write("\n".join(keep) + '\n{"task": "t3", "ev')
        g2 = _graph([1.0] * 6)
        prof2 = PilotRuntime(slots=2, mode="sim",
                             journal=Journal(path)).run(g2)
        assert prof2.n_tasks == prof1.n_tasks == 6
        assert {"event": "journal_skip", "n": 3} in \
            [{k: e[k] for k in ("event", "n")} for e in prof2.events
             if e.get("event") == "journal_skip"]
        assert prof2.t_exec == 3.0          # only t3..t5 executed
        assert all(t.state == TaskState.DONE for t in g2.tasks.values())


def test_metropolis_host_vs_device():
    import jax
    import jax.numpy as jnp
    from repro.core.ensemble import metropolis_swap_device
    from repro.plugins.re_exchange import metropolis_swaps
    losses = np.array([3.0, 2.5, 2.0, 1.5])
    temps = np.array([1.0, 2.0, 4.0, 8.0])
    # deterministic acceptance: huge energy gap -> always swap pair (0,1)
    newt, acc = metropolis_swaps([10.0, 0.0, 0.0, 0.0],
                                 [1.0, 10.0, 10.0, 10.0], cycle=0)
    assert (newt[0], newt[1]) == (10.0, 1.0)
    nt_dev, nacc = metropolis_swap_device(
        jnp.array([10.0, 0.0, 0.0, 0.0]), jnp.array([1.0, 10.0, 10.0, 10.0]),
        0, jax.random.PRNGKey(0))
    assert float(nt_dev[0]) == 10.0 and float(nt_dev[1]) == 1.0


# ---------------------------------------------------------------- preemption

def test_sim_preemption_evicts_lower_priority():
    """A ready high-priority task that cannot fit evicts a running
    priority-0 attempt: eviction is the abandon path (epoch nulled, state
    NEW), not a failure (no pod blame, no retry spent), and the victim
    reruns to completion afterwards."""
    g = TaskGraph()
    g.add(Task(name="starter", duration=1.0, stage="s"))
    g.add(Task(name="lowA", duration=50.0, stage="s"))
    g.add(Task(name="lowB", duration=50.0, stage="s"))
    g.add(Task(name="hi", duration=5.0, slots=2, priority=10,
               deps=["starter"], stage="s"))
    prof = PilotRuntime(slots=2, mode="sim", preempt=True).run(g)

    assert prof.n_preempted >= 1 and prof.n_failed == 0
    assert all(t.state == TaskState.DONE for t in g.tasks.values())
    hi, lowA = g.tasks["hi"], g.tasks["lowA"]
    # hi launched the moment it became ready, not after a 50s task
    assert hi.v_started == 1.0 and hi.v_finished == 6.0
    victims = [t for t in (g.tasks["lowA"], g.tasks["lowB"])
               if any(h["outcome"] == "preempted" for h in t.history)]
    assert victims
    for v in victims:
        assert v.attempts == 2              # evicted attempt + rerun
        assert not v.excluded_pods()        # preemption never blames a pod
        assert v.v_finished > hi.v_finished


def test_preempted_attempt_history_replays_from_journal():
    """The journal reconstructs a preempted task's attempt history, and
    the sanitizer accepts the preempt/requeue record stream."""
    from repro.analysis import sanitize_file

    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/j.jsonl"
        g = TaskGraph()
        g.add(Task(name="starter", duration=1.0, stage="s"))
        g.add(Task(name="lowA", duration=50.0, stage="s"))
        g.add(Task(name="hi", duration=5.0, slots=2, priority=10,
                   deps=["starter"], stage="s"))
        prof = PilotRuntime(slots=2, mode="sim", preempt=True,
                            journal=Journal(path)).run(g)
        assert prof.n_preempted == 1
        _, _, history = Journal(path).load_state()
        assert [h["outcome"] for h in history["lowA"]] == ["preempted"]
        assert history["lowA"][0]["attempt"] == 1
        assert sanitize_file(path).ok


def test_real_preemption_discards_zombie_result():
    """Real mode cannot stop the victim's worker thread; its eventual
    completion must be an inert zombie (epoch mismatch) while the
    requeued attempt's result is the one that lands."""
    import threading

    release = threading.Event()
    calls = []

    def low_run(t):
        n = len(calls)
        calls.append(n)
        release.wait(10.0)
        return f"low{n}"

    g = TaskGraph()
    g.add(Task(name="starter",
               run=lambda t: __import__("time").sleep(0.05), stage="s"))
    g.add(Task(name="lowA", run=low_run, stage="s"))
    g.add(Task(name="hi", deps=["starter"], priority=10, slots=2,
               run=lambda t: release.set() or "hi", stage="s"))
    prof = PilotRuntime(slots=2, mode="real", preempt=True).run(g)

    assert prof.n_preempted == 1 and prof.n_failed == 0
    assert all(t.state == TaskState.DONE for t in g.tasks.values())
    lowA = g.tasks["lowA"]
    assert len(calls) == 2                  # zombie attempt + rerun
    assert lowA.result == "low1"            # zombie's "low0" was discarded
    assert [h["outcome"] for h in lowA.history[:1]] == ["preempted"]
    assert not lowA.excluded_pods()
    assert g.tasks["hi"].result == "hi"
