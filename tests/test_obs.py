"""Flight recorder (repro.obs): span tracing, TTC decomposition, exports.

Covers the observability acceptance surface:

  - span pairing: every task attempt opens and closes exactly one span on
    the virtual clock; a clean drain ends with zero open task spans
  - the decomposition identity: per-slot TTC = t_exec + t_data + t_sched
    + t_block + t_idle (+ t_exec_lost) exactly, residual < 1e-6, as a
    property over random DAGs
  - fault/preemption runs: truncated attempts (pod_lost, preempted) end
    their span at the truncation time, never overlap the retry's span,
    and the lost exec time is attributed (t_exec_lost)
  - Chrome trace_event export is deterministic (byte-identical across
    loads) and schema-valid
  - critical path on a hand-built diamond journal, with per-link slack
  - journal sim-fidelity: every sim record carries wall ``t`` AND ``vt``;
    a hand-built same-slot overlap on vt trips the sanitizer's S306
  - metrics timelines land in prof.results["timeseries"] and stay
    bounded by adaptive decimation
"""
import json
import os
import tempfile

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs import (MetricsTimeline, Tracer, critical_path, decompose,
                       load_segments, to_chrome)
from repro.obs.tracer import TASK
from repro.runtime.executor import PilotRuntime
from repro.runtime.faults import FaultInjector
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph


def _bag(n, duration=1.0):
    g = TaskGraph()
    for i in range(n):
        g.add(Task(name=f"t{i:03d}", duration=duration, stage="s"))
    return g


# ------------------------------------------------------------ span pairing
def test_every_attempt_is_one_paired_span():
    tr = Tracer()
    g = _bag(40)
    prof = PilotRuntime(slots=4, mode="sim", tracer=tr).run(g)
    assert prof.n_tasks == 40 and prof.n_failed == 0
    assert tr.clock == "virtual"
    spans = [s for s in tr.spans if s["cat"] == TASK]
    assert len(spans) == 40
    assert {s["task"] for s in spans} == set(g.tasks)
    for s in spans:
        assert s["outcome"] == "done"
        assert s["attempt"] == 1
        assert s["t1"] - s["t0"] == pytest.approx(1.0)
    assert not [s for s in tr.unpaired() if s["cat"] == TASK]
    ts = tr.timeseries()
    assert ts["counters"]["attempts_done"] == 40
    assert ts["histograms"]["attempt_span"]["n"] == 40
    assert ts["n_samples"] > 0
    assert "frontier_depth" in ts["gauges"]
    assert "busy_slots" in ts["gauges"]


def test_unpaired_spans_are_reported():
    tr = Tracer()
    t = Task(name="orphan", duration=1.0, stage="s")
    t.attempts = 1
    tr.task_begin(t, 0.0)
    open_spans = tr.unpaired()
    assert len(open_spans) == 1
    assert open_spans[0]["task"] == "orphan" and open_spans[0]["t1"] is None
    assert tr.summary()["n_open"] == 1


# --------------------------------------------------- decomposition identity
def _random_dag(rng_deps, durations):
    g = TaskGraph()
    names = [f"t{i:03d}" for i in range(len(durations))]
    for i, (dur, dep_draw) in enumerate(zip(durations, rng_deps)):
        deps = [names[d % i] for d in dep_draw] if i else []
        g.add(Task(name=names[i], duration=dur, stage="s",
                   deps=sorted(set(deps))))
    return g


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(min_value=0.1, max_value=9.0),
                min_size=2, max_size=25),
       st.data())
def test_decomposition_identity_random_dags(durations, data):
    """TTC = t_exec + t_data + t_sched + t_block + t_idle per slot,
    exactly, for arbitrary DAG shapes."""
    deps = [data.draw(st.lists(st.integers(0, 1000), max_size=2),
                      label=f"deps{i}") for i in range(len(durations))]
    g = _random_dag(deps, durations)
    path = os.path.join(tempfile.mkdtemp(prefix="repro_obs_"), "run.jsonl")
    prof = PilotRuntime(slots=3, mode="sim", journal=Journal(path)).run(g)
    assert prof.n_failed == 0

    seg = load_segments(path)[-1]
    rep = decompose(seg)
    assert rep["n_open"] == 0
    assert rep["residual_max"] < 1e-6
    tot = rep["totals"]
    assert tot["t_exec"] == pytest.approx(sum(durations), abs=1e-6)
    span = seg.w1 - seg.w0
    budget = span * len(rep["slots"])
    spent = sum(tot[k] for k in
                ("t_exec", "t_data", "t_sched", "t_block", "t_idle",
                 "t_exec_lost"))
    assert spent == pytest.approx(budget, abs=1e-6)


# ------------------------------------------------ truncated spans: faults
def test_fault_run_truncated_spans_and_lost_time(tmp_path):
    """Pod loss truncates the running attempts' spans at the kill time;
    the decomposition attributes their exec time to t_exec_lost and the
    journal still balances to residual ~0."""
    tr = Tracer()
    path = str(tmp_path / "faults.jsonl")
    g = _bag(24, duration=2.0)
    faults = FaultInjector(kill_every=7.0, pods=["pod0", "pod1"],
                           max_kills=2, respawn_after=3.0)
    prof = PilotRuntime(slots=4, mode="sim", journal=Journal(path),
                        faults=faults, tracer=tr).run(g)
    assert prof.n_tasks == 24 and prof.n_failed == 0

    lost = [s for s in tr.spans if s["outcome"] == "pod_lost"]
    assert lost, "fault injection produced no truncated spans"
    by_task = {}
    for s in tr.spans:
        by_task.setdefault(s["task"], []).append(s)
    for s in lost:
        assert s["t1"] is not None and s["t1"] >= s["t0"]
        retries = [r for r in by_task[s["task"]]
                   if r["attempt"] > s["attempt"]]
        assert retries, f"{s['task']} lost its pod but never retried"
        # truncation keeps attempt spans disjoint per task
        assert all(r["t0"] >= s["t1"] - 1e-9 for r in retries)
    assert not [s for s in tr.unpaired() if s["cat"] == TASK]
    assert [e for e in tr.events if e["name"].startswith("pod_lost:")]

    rep = decompose(load_segments(path)[-1])
    assert rep["residual_max"] < 1e-6 and rep["n_open"] == 0
    assert rep["totals"]["t_exec_lost"] > 0
    assert tr.timeseries()["counters"]["attempts_pod_lost"] == len(lost)


def test_preempted_attempt_is_truncated_span():
    tr = Tracer()
    g = TaskGraph()
    g.add(Task(name="starter", duration=1.0, stage="s"))
    g.add(Task(name="lowA", duration=50.0, stage="s"))
    g.add(Task(name="lowB", duration=50.0, stage="s"))
    g.add(Task(name="hi", duration=5.0, slots=2, priority=10,
               deps=["starter"], stage="s"))
    prof = PilotRuntime(slots=2, mode="sim", preempt=True,
                        tracer=tr).run(g)
    assert prof.n_preempted >= 1 and prof.n_failed == 0

    evicted = [s for s in tr.spans if s["outcome"] == "preempted"]
    assert evicted
    for s in evicted:
        assert s["t1"] == pytest.approx(1.0)     # truncated when hi arrived
        rerun = [r for r in tr.spans if r["task"] == s["task"]
                 and r["outcome"] == "done"]
        assert len(rerun) == 1 and rerun[0]["t0"] >= s["t1"]
    assert not [s for s in tr.unpaired() if s["cat"] == TASK]


# --------------------------------------------------------- chrome export
def test_chrome_export_is_byte_identical_and_schema_valid(tmp_path):
    path = str(tmp_path / "run.jsonl")
    g = _random_dag([[], [0], [0], [1, 2]], [3.0, 1.0, 2.0, 1.0])
    PilotRuntime(slots=2, mode="sim", journal=Journal(path)).run(g)

    one = to_chrome([("run", s) for s in load_segments(path)])
    two = to_chrome([("run", s) for s in load_segments(path)])
    assert one == two                       # deterministic, byte for byte

    doc = json.loads(one)
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} <= {"M", "X", "i"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    assert any(e["name"] == "process_name" for e in evs if e["ph"] == "M")
    cats = {e["cat"] for e in xs}
    assert "exec" in cats and ("idle" in cats or "sched" in cats)


# --------------------------------------------------------- critical path
def _write_diamond(path):
    """A -> (B: 2s, C: 5s) -> D on two slots, by hand: C is critical."""
    recs = [
        {"t": 0.0, "event": "session_start", "vt": 0.0, "mode": "sim"},
        _sched("A", 1, 0.0), _fin("A", 1, 0.0, 1.0),
        _sched("B", 1, 1.0), _sched("C", 1, 1.0),
        _fin("B", 1, 1.0, 3.0), _fin("C", 1, 1.0, 6.0),
        _sched("D", 1, 6.0), _fin("D", 1, 6.0, 7.0),
    ]
    deps = {"B": ["A"], "C": ["A"], "D": ["B", "C"]}
    for r in recs:
        if r.get("event") == "scheduled":
            r["deps"] = deps.get(r["task"], [])
    with open(path, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")


def _sched(name, attempt, vt, **kw):
    return {"t": vt, "vt": vt, "task": name, "event": "scheduled",
            "state": "SCHEDULED", "attempts": attempt, **kw}


def _fin(name, attempt, v0, v1, **kw):
    return {"t": v1, "vt": v1, "task": name, "event": "finished",
            "state": "DONE", "attempts": attempt, "t_exec": v1 - v0,
            "t_data": 0.0, "v_started": v0, "v_finished": v1, **kw}


def test_critical_path_on_diamond(tmp_path):
    path = str(tmp_path / "diamond.jsonl")
    _write_diamond(path)
    seg = load_segments(path)[-1]

    chains = critical_path(seg, k=3)
    assert chains
    top = chains[0]
    assert [ln["task"] for ln in top["links"]] == ["A", "C", "D"]
    assert top["ttc"] == pytest.approx(7.0)
    # D starts the instant C finishes: zero slack on the critical edge
    assert top["links"][-1]["slack"] == pytest.approx(0.0)

    rep = decompose(seg)
    assert rep["residual_max"] < 1e-6
    assert rep["totals"]["t_exec"] == pytest.approx(1 + 2 + 5 + 1)


# ------------------------------------------------- journal sim fidelity
def test_sim_journal_records_carry_wall_and_virtual_time(tmp_path):
    path = str(tmp_path / "vt.jsonl")
    PilotRuntime(slots=2, mode="sim",
                 journal=Journal(path)).run(_bag(6, duration=2.0))
    recs = [json.loads(ln) for ln in open(path)]
    assert recs
    for r in recs:
        assert "t" in r and "vt" in r, f"record missing clocks: {r}"
    done = [r for r in recs if r["event"] == "finished"]
    assert done and all(r["vt"] == r["v_finished"] for r in done)


def test_sanitizer_s306_rejects_same_slot_overlap_on_vt(tmp_path):
    """Two attempts granted the same slot id with overlapping [v_started,
    v_finished) is a sim-fidelity violation the sanitizer must flag."""
    from repro.analysis.sanitizer import sanitize_file
    path = str(tmp_path / "overlap.jsonl")
    recs = [
        {"t": 0.0, "event": "session_start", "vt": 0.0, "mode": "sim"},
        _sched("a", 1, 0.0, slot_ids=[0]),
        _sched("b", 1, 1.0, slot_ids=[0]),       # slot 0 still held by a
        _fin("a", 1, 0.0, 3.0), _fin("b", 1, 1.0, 4.0),
    ]
    with open(path, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    report = sanitize_file(path)
    assert "S306" in report.codes(), report.format()


# ------------------------------------------------------- metrics timeline
def test_timeseries_lands_in_prof_results():
    from repro.core import AppManager, Kernel, PipelineSpec, Stage, TaskSpec
    k = Kernel("synthetic.noop")
    k.sim_duration = 1.0
    spec = PipelineSpec([Stage([TaskSpec(k, name=f"s.t{i}")
                                for i in range(4)], name="only")],
                        name="p")
    rt = PilotRuntime(slots=2, mode="sim", tracer=Tracer())
    prof = AppManager(rt).run([spec])
    ts = prof.results["timeseries"]
    assert ts["n_samples"] > 0
    assert ts["counters"]["attempts_done"] == 4
    assert len(ts["t"]) == ts["n_samples"]
    for series in ts["gauges"].values():
        assert len(series) == ts["n_samples"]
    assert prof.results["trace"]["n_open"] == 0


def test_metrics_decimation_keeps_timeline_bounded():
    m = MetricsTimeline(max_samples=16)
    m.gauge("x", lambda: 1.0)
    for i in range(10_000):
        m.maybe_sample(float(i))
    assert len(m.t) <= 16
    s = m.series()
    assert s["n_samples"] == len(s["t"]) == len(s["gauges"]["x"])
    # decimation keeps the earliest and tracks the latest region
    assert s["t"][0] == 0.0 and s["t"][-1] > 5_000
