"""Adaptive execution strategy (paper §5 future work) — decision rules +
end-to-end with the DES runtime."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.executor import PilotRuntime
from repro.runtime.states import Task, TaskGraph
from repro.runtime.strategy import AdaptiveSlotStrategy


def test_grows_on_backlog():
    s = AdaptiveSlotStrategy(min_slots=4, max_slots=64)
    assert s.decide(utilization=0.95, backlog=40, slots=8) == 16
    assert s.decide(utilization=0.95, backlog=200, slots=40) == 64  # capped


def test_shrinks_when_idle():
    s = AdaptiveSlotStrategy(min_slots=4, max_slots=64)
    assert s.decide(utilization=0.2, backlog=0, slots=32) == 16
    assert s.decide(utilization=0.1, backlog=0, slots=5) == 4       # floor


def test_holds_in_band():
    s = AdaptiveSlotStrategy(min_slots=4, max_slots=64)
    assert s.decide(utilization=0.7, backlog=2, slots=16) == 16


@settings(max_examples=50, deadline=None)
@given(st.floats(0, 1), st.integers(0, 500), st.integers(1, 128))
def test_decision_always_in_bounds(util, backlog, slots):
    s = AdaptiveSlotStrategy(min_slots=4, max_slots=64)
    out = s.decide(utilization=util, backlog=backlog, slots=slots)
    assert 4 <= out <= 64


def test_adaptive_resize_between_phases():
    """Two-phase workload: wide phase then narrow phase; the strategy grows
    then shrinks the pilot and the second phase runs at the smaller width."""
    rt = PilotRuntime(slots=8, mode="sim")
    strat = AdaptiveSlotStrategy(min_slots=2, max_slots=64)

    g1 = TaskGraph()
    for i in range(64):
        g1.add(Task(name=f"wide{i}", duration=10.0))
    # pretend phase-0 profiling saw full utilization and a 64-task backlog
    rt.resize(strat.decide(utilization=1.0, backlog=64, slots=rt.slots))
    p1 = rt.run(g1)
    assert p1.ttc == 10.0 * (64 // 16)     # grew 8 -> 16

    g2 = TaskGraph()
    for i in range(4):
        g2.add(Task(name=f"narrow{i}", duration=10.0))
    rt.resize(strat.decide(utilization=0.2, backlog=4, slots=rt.slots))
    p2 = rt.run(g2)
    assert p2.ttc == 10.0                  # shrank 16 -> 8, 4 tasks fit
