"""Fault-tolerant fleet: pod death, retry exclusion, epochs, spill GC.

Covers the failure half of the pilot runtime: sim-mode pod kills with
history-driven retries placed off the dead pod, capacity shrink vs respawn
vs topology shrink-recarve, real-mode worker-thread death and heartbeat
staleness, deterministic DES ordering under speculation, speculative twins
charging t_data through shared staging manifests, canceled twins settling
journal/staging state, spill-file GC at close, and journal replay of a run
crashed mid-retry.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import AppManager, Kernel, PipelineSpec, Stage, TaskSpec
from repro.dist.topology import SlotTopology
from repro.runtime.executor import PilotRuntime
from repro.runtime.faults import FaultInjector
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState
from repro.staging import LocalityMap, StagingLayer
from repro.staging.store import ObjectStore


def bag(n, dur=10.0, stage="w"):
    g = TaskGraph()
    for i in range(n):
        g.add(Task(name=f"t{i}", duration=dur, stage=stage))
    return g


# ---------------------------------------------------------------- sim kills

def test_sim_pod_kill_retries_off_dead_pod():
    faults = FaultInjector(kill_at=[(5.0, "pod2")])
    rt = PilotRuntime(slots=8, mode="sim", faults=faults, max_retries=2)
    g = bag(8)
    prof = rt.run(g)

    assert prof.n_failed == 0
    assert prof.n_pod_lost == 1 and prof.n_retries == 1
    victims = [t for t in g.tasks.values()
               if any(h["outcome"] == "pod_lost" for h in t.history)]
    assert len(victims) == 1
    t = victims[0]
    assert t.state == TaskState.DONE
    assert t.error is None                  # stale error cleared on retry
    assert t.attempts == 2
    hist = {h["attempt"]: h for h in t.history}
    assert hist[1]["outcome"] == "pod_lost" and hist[1]["pod"] == "pod2"
    assert hist[2]["outcome"] == "done" and hist[2]["pod"] != "pod2"
    # retry waited for a completion (v=10), then ran 10s on a live pod
    assert prof.ttc == 20.0
    # the dead pod's id is retired; every surviving id returned exactly once
    assert rt.slots == 7
    assert sorted(rt._free_ids) == [0, 1, 3, 4, 5, 6, 7]
    assert rt.dead_pods == {"pod2"}


def test_sim_pod_respawn_restores_capacity():
    faults = FaultInjector(kill_at=[(5.0, "pod2")], respawn_after=3.0)
    rt = PilotRuntime(slots=8, mode="sim", faults=faults)
    g = bag(8)
    prof = rt.run(g)

    assert prof.n_failed == 0
    # replacement pod joined: full capacity and id pool restored
    assert rt.slots == 8
    assert sorted(rt._free_ids) == list(range(8))
    assert not rt.dead_pods and not rt._dead_ids
    # retry launched the moment the replacement arrived (v=8), on the
    # revived pod — exclusion is a preference, availability wins
    t = next(t for t in g.tasks.values() if t.attempts == 2)
    assert t.history[-1]["outcome"] == "done"
    assert prof.ttc == 18.0
    events = [e["event"] for e in prof.events]
    assert "pod_lost" in events and "pod_revived" in events


def test_topology_shrink_recarve_after_pod_loss():
    topo = SlotTopology.even(np.arange(8), 8)
    faults = FaultInjector(kill_at=[(5.0, "pod3")])
    rt = PilotRuntime(topology=topo, mode="sim", faults=faults)
    g = bag(8)
    prof = rt.run(g)

    assert prof.n_failed == 0
    # the dead slot's devices left the fleet; ids renumbered compactly
    assert rt.topology.n_slots == 7
    assert rt.slots == 7
    assert not rt._dead_ids and not rt.dead_pods and not rt._drop_pending
    assert sorted(rt._free_ids) == list(range(7))
    # device 3 is gone from the compacted topology
    assert 3 not in rt.topology.devices.ravel().tolist()


# ---------------------------------------------------------------- real mode

@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_real_worker_thread_death_retries():
    calls = {"n": 0}

    def run(task):
        calls["n"] += 1
        if calls["n"] == 1:
            raise SystemExit("oom killed")   # escapes the except Exception
        return "ok"

    rt = PilotRuntime(slots=2, mode="real", max_retries=2)
    g = TaskGraph()
    g.add(Task(name="t0", run=run))
    prof = rt.run(g)

    t = g.tasks["t0"]
    assert t.state == TaskState.DONE and t.result == "ok"
    assert t.error is None
    assert prof.n_failed == 0 and prof.n_pod_lost == 1
    assert [h["outcome"] for h in t.history] == ["worker_died", "done"]


def test_real_heartbeat_timeout_retries_and_ignores_zombie():
    release = threading.Event()
    calls = {"n": 0}

    def run(task):
        calls["n"] += 1
        if calls["n"] == 1:
            release.wait(5.0)          # hung attempt: never beats again
            return "late"
        return "ok"

    rt = PilotRuntime(slots=2, mode="real", heartbeat_timeout=0.15,
                      max_retries=2)
    g = TaskGraph()
    g.add(Task(name="h0", run=run))
    prof = rt.run(g)
    release.set()

    t = g.tasks["h0"]
    assert t.state == TaskState.DONE and t.result == "ok"
    assert "heartbeat_timeout" in [h["outcome"] for h in t.history]
    assert prof.n_pod_lost >= 1 and prof.n_failed == 0
    # abandoned attempt's slot id credited exactly once
    assert sorted(rt._free_ids) == [0, 1]


def test_real_pod_kill_mid_run():
    started = threading.Event()

    def slow(task):
        started.set()
        import time as _t
        _t.sleep(0.3)
        return "v"

    faults = FaultInjector()
    rt = PilotRuntime(slots=4, mode="real", faults=faults, max_retries=2)
    g = TaskGraph()
    g.add(Task(name="r0", run=slow))

    def killer():
        started.wait(5.0)
        rt.inject_pod_failure()        # kills the busiest pod

    th = threading.Thread(target=killer)
    th.start()
    prof = rt.run(g)
    th.join()

    t = g.tasks["r0"]
    assert t.state == TaskState.DONE and t.result == "v"
    assert prof.n_failed == 0
    assert any(h["outcome"] == "pod_lost" for h in t.history)
    # the killed pod stays retired (no respawn configured)
    assert len(rt.dead_pods) == 1
    dead = next(iter(rt.dead_pods))
    assert t.history[-1]["pod"] != dead


# ---------------------------------------------------------------- DES order

def test_sim_speculation_is_deterministic():
    def run_once():
        tasks = [Task(name=f"t{i}",
                      duration=50.0 if i >= 10 else 10.0, stage="s")
                 for i in range(12)]
        rt = PilotRuntime(slots=6, mode="sim", straggler_factor=2.0)
        order = []
        sess = rt.session(
            on_task_done=lambda t, s: order.append((t.name, s.vnow)))
        sess.submit(tasks)
        prof = sess.drain()
        return order, prof.ttc, prof.n_speculative

    o1, ttc1, ns1 = run_once()
    o2, ttc2, ns2 = run_once()
    assert ns1 == ns2 and ns1 >= 1     # duplicates actually launched
    assert o1 == o2                    # identical completion sequence
    assert ttc1 == ttc2


# ------------------------------------------------------------ clone staging

COPY_COST = 1e-4 + 250_000_000 / (25.0 * 1e9)    # latency + nbytes/copy_gbps
# first consumer pulls the blob over the slow host link (tiered planner);
# later consumers copy pod->pod off the replica it left behind
HOST_COST = 1e-4 + 250_000_000 / (8.0 * 1e9)


def _staged_straggler(straggler_dur, tmp_path):
    layer = StagingLayer(locality=LocalityMap(8, slots_per_pod=1),
                         threshold_bytes=1024)
    jpath = str(tmp_path / "j.jsonl")
    rt = PilotRuntime(slots=8, mode="sim", staging=layer,
                      straggler_factor=2.0, journal=Journal(jpath))
    g = TaskGraph()
    for i in range(6):
        g.add(Task(name=f"w{i}", duration=10.0, stage="s"))
    s = Task(name="s0", duration=straggler_dur, stage="s")
    ref = layer.stage_virtual("blob", 250_000_000, [])   # lives at host
    layer.manifest_input(s, "x", ref)
    g.add(s)
    return layer, rt, g, ref, jpath


def test_speculative_clone_charges_t_data(tmp_path):
    layer, rt, g, ref, _ = _staged_straggler(100.0, tmp_path)
    prof = rt.run(g)

    assert prof.n_speculative == 1 and prof.n_failed == 0
    # the clone copied host -> its pod through the SHARED manifest; the
    # superseded original's charge is dropped, so the profile carries
    # exactly the winning clone's transfer — terms stay disjoint
    assert prof.t_data == pytest.approx(COPY_COST, rel=1e-6)
    assert layer.planner.stats["copy"] == 2      # original AND clone moved
    assert layer.store.refcount(ref.digest) == 0  # all holds released
    assert g.tasks["s0"].state == TaskState.DONE


def test_canceled_twin_settles_journal_staging_and_t_data(tmp_path):
    # original (25s) beats the clone (starts at 20, runs the 10s median)
    layer, rt, g, ref, jpath = _staged_straggler(25.0, tmp_path)
    prof = rt.run(g)

    assert prof.n_speculative == 1 and prof.n_failed == 0
    assert g.tasks["s0"].state == TaskState.DONE
    # both twins moved the blob; the canceled clone's t_data still counts
    # (host -> pod for the original, pod -> pod for the clone)
    assert prof.t_data == pytest.approx(HOST_COST + COPY_COST, rel=1e-6)
    assert layer.store.refcount(ref.digest) == 0  # clone's hold released
    recs = [json.loads(line) for line in open(jpath)]
    cancels = [r for r in recs
               if r.get("event") == "canceled" and r.get("by") == "original"]
    assert len(cancels) == 1 and cancels[0]["task"].startswith("s0.spec")
    # full slot pool back: no twin leaked its ids
    assert sorted(rt._free_ids) == list(range(8))


# ---------------------------------------------------------------- spill GC

def test_spill_gc_keeps_journaled_refs(tmp_path):
    spill = tmp_path / "spill"
    layer = StagingLayer(store=ObjectStore(spill_dir=str(spill)),
                         threshold_bytes=16)
    ta, tb = Task(name="a"), Task(name="b")
    keep_val = {"x": list(range(100))}
    r_keep = layer.acquire_stage_in(ta, keep_val)
    r_drop = layer.acquire_stage_in(tb, {"y": list(range(200))})
    j = Journal(str(tmp_path / "j.jsonl"))
    j.record_flow("channel_put", "ch", "p",
                  digest=r_keep.digest, nbytes=r_keep.nbytes)
    layer.finish(ta)
    layer.finish(tb)                    # both refcounts now 0

    assert layer.gc_spill(j, keep_durable=True) == 1
    names = {p.name for p in spill.glob("*.blob")}
    assert names == {f"{r_keep.digest}.blob"}

    # restartability: a fresh store re-materializes the journaled ref
    store2 = ObjectStore(spill_dir=str(spill))
    assert store2.get(r_keep.digest) == keep_val
    with pytest.raises(KeyError):
        store2.get(r_drop.digest)

    # keep_durable=False drops the journal keep-set too
    assert layer.gc_spill(j, keep_durable=False) == 1
    assert not list(spill.glob("*.blob"))
    j.close()


def test_runtime_close_runs_spill_gc(tmp_path):
    spill = tmp_path / "spill"
    layer = StagingLayer(store=ObjectStore(spill_dir=str(spill)),
                         threshold_bytes=16)
    rt = PilotRuntime(slots=2, mode="real", staging=layer,
                      journal=Journal(str(tmp_path / "j.jsonl")))
    t = Task(name="a")
    layer.acquire_stage_in(t, {"z": list(range(50))})
    layer.finish(t)
    assert len(list(spill.glob("*.blob"))) == 1
    assert rt.close() == 1              # unreferenced spill file reclaimed
    assert not list(spill.glob("*.blob"))
    assert rt.journal._fh is None       # journal closed too


# ------------------------------------------------------------ replay/retry

def test_journal_replay_resumes_mid_retry(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    j = Journal(jpath)
    crashed = Task(name="t0")
    crashed.attempts = 1
    crashed.meta["slot_ids"] = [2]
    j.record(crashed, "pod_lost", pod="pod2")
    crashed.attempts = 2
    crashed.meta["slot_ids"] = [1]
    j.record(crashed, "worker_died", pod="pod1")
    j.close()

    # restart: same journal; FaultInjector() turns on slot-id tracking so
    # the pod exclusion is observable
    rt = PilotRuntime(slots=4, mode="sim", journal=Journal(jpath),
                      faults=FaultInjector(), max_retries=3)
    g = TaskGraph()
    g.add(Task(name="t0", duration=5.0))
    g.add(Task(name="t1", duration=5.0))
    prof = rt.run(g)

    t = g.tasks["t0"]
    assert t.state == TaskState.DONE
    assert t.attempts == 3              # resumed at attempt 3, not 1
    assert prof.n_failed == 0
    blamed = {h["pod"] for h in t.history if h["outcome"] != "done"}
    assert blamed == {"pod1", "pod2"}
    done = [h for h in t.history if h["outcome"] == "done"]
    assert len(done) == 1 and done[0]["attempt"] == 3
    assert done[0]["pod"] not in blamed    # re-grant excluded both pods


def test_journal_replay_exhausted_retries_fail_fast(tmp_path):
    jpath = str(tmp_path / "j.jsonl")
    j = Journal(jpath)
    crashed = Task(name="t0")
    for i, pod in enumerate(("pod0", "pod1", "pod2"), start=1):
        crashed.attempts = i
        j.record(crashed, "pod_lost", pod=pod)
    j.close()

    rt = PilotRuntime(slots=4, mode="sim", journal=Journal(jpath),
                      faults=FaultInjector(), max_retries=3)
    g = TaskGraph()
    g.add(Task(name="t0", duration=5.0))
    prof = rt.run(g)
    # attempts resumed at 3: exactly one more try within the budget
    assert g.tasks["t0"].attempts == 4
    assert g.tasks["t0"].state == TaskState.DONE
    assert prof.n_failed == 0


# ------------------------------------------------------------ PST profiles

def test_pipeline_profile_reports_failure_counts():
    def member(dur):
        k = Kernel("synthetic.noop")
        k.sim_duration = dur
        return k

    staging = StagingLayer(locality=LocalityMap(4, slots_per_pod=1),
                           threshold_bytes=1 << 30)
    faults = FaultInjector(kill_at=[(5.0, "pod1")], respawn_after=2.0)
    rt = PilotRuntime(slots=4, mode="sim", staging=staging, faults=faults,
                      max_retries=2)
    am = AppManager(rt)
    pipes = [PipelineSpec(
        [Stage([TaskSpec(member(10.0), name=f"p{p}.m{m}")
                for m in range(2)], name="s0")], name=f"p{p}")
        for p in range(2)]
    prof = am.run(pipes)

    assert prof.n_failed == 0
    assert prof.n_pod_lost == 1
    rows = prof.results["pipelines"]
    assert set(rows) == {"p0", "p1"}
    assert sum(r["n_pod_lost"] for r in rows.values()) == 1
    assert sum(r["n_retries"] for r in rows.values()) == 1
    assert all(r["n_failed"] == 0 for r in rows.values())
