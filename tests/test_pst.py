"""PST workflow API: streaming AppManager semantics, legacy-pattern
equivalence through the PST compilation path, profile invariants, and the
on-device Metropolis swap properties."""
import json
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (AppManager, BagOfTasks, Kernel, PipelineSpec,
                        Pipeline, ReplicaExchange, SingleClusterEnvironment,
                        Stage, TaskSpec)
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskState


def _k(sim_duration=0.0, cores=1):
    k = Kernel("synthetic.noop")
    k.sim_duration = sim_duration
    k.cores = cores
    return k


def _re_pipeline(name, members, cycles, sim_dur, x_dur, events):
    """A replica-exchange ensemble written directly in PST: each exchange's
    on_done appends the next cycle's stages (adaptive extension)."""
    def cycle_stages(c):
        sims = Stage([TaskSpec(_k(sim_dur), name=f"{name}.c{c}.md{i}",
                               metadata={"instance": i, "iteration": c})
                      for i in range(members)], name="simulation")

        def on_x(stage, pipe):
            events.append((name, c))
            if c + 1 < cycles:
                pipe.extend(cycle_stages(c + 1))

        x = Stage([TaskSpec(_k(x_dur), name=f"{name}.c{c}.x",
                            metadata={"iteration": c})],
                  name="exchange", on_done=on_x)
        return [sims, x]

    return PipelineSpec(cycle_stages(0), name=name)


# -------------------------------------------------- streaming concurrency

def test_two_re_pipelines_interleave_out_of_order():
    """Ensemble A reaches cycle c+1 BEFORE ensemble B finishes cycle c
    under skewed sim durations — no global barrier across pipelines."""
    events = []
    A = _re_pipeline("A", members=2, cycles=3, sim_dur=1.0, x_dur=0.1,
                     events=events)
    B = _re_pipeline("B", members=2, cycles=3, sim_dur=50.0, x_dur=0.1,
                     events=events)
    am = AppManager(PilotRuntime(slots=4, mode="sim"))
    prof = am.run([A, B])

    g = am.session.graph
    a_c1_starts = [g.tasks[f"A.c1.md{i}"].v_started for i in range(2)]
    b_c0_finish = [g.tasks[f"B.c0.md{i}"].v_finished for i in range(2)]
    assert max(a_c1_starts) < min(b_c0_finish), \
        "A's cycle 1 must start while B still simulates cycle 0"
    # all three of A's exchanges complete before B's first
    assert events[:4] == [("A", 0), ("A", 1), ("A", 2), ("B", 0)]
    # makespan is B's chain alone; A rode along in the slack
    assert prof.ttc == pytest.approx(3 * 50.1)
    assert prof.n_tasks == 2 * 3 * 3
    assert prof.n_failed == 0
    assert prof.results["pipelines"]["A"]["state"] == "done"
    assert prof.results["pipelines"]["B"]["state"] == "done"


def test_real_mode_pipelines_interleave():
    """Real mode: a fast pipeline finishes all stages while a slow
    pipeline's first stage is still running."""
    events = []

    def tick(tag):
        def on_done(stage, pipe):
            events.append(tag)
        return on_done

    slow = Kernel("synthetic.sleep")
    slow.arguments = {"seconds": 0.4}
    A = PipelineSpec([Stage([TaskSpec(_k())], name="s0",
                            on_done=tick(("A", 0))),
                      Stage([TaskSpec(_k())], name="s1",
                            on_done=tick(("A", 1)))], name="A")
    B = PipelineSpec([Stage([TaskSpec(slow)], name="s0",
                            on_done=tick(("B", 0)))], name="B")
    prof = AppManager(PilotRuntime(slots=4, mode="real")).run([A, B])
    assert prof.n_failed == 0
    assert events.index(("A", 1)) < events.index(("B", 0))


# -------------------------------------------------- legacy equivalence

class _SimRE(ReplicaExchange):
    def prepare_replica_for_md(self, r):
        return _k(10.0)

    def prepare_exchange(self, replicas):
        return _k(1.0)


def test_legacy_re_profile_equivalent_through_pst():
    """SingleClusterEnvironment.run(pattern) now compiles to PST; the
    profile must match the legacy per-cycle-graph numbers exactly."""
    cl = SingleClusterEnvironment(cores=4, mode="sim")
    cl.allocate()
    prof = cl.run(_SimRE(cycles=3, replicas=4))
    cl.deallocate()
    # barrier per cycle: each cycle costs sim + exchange; 3 cycles chain
    assert prof.ttc == pytest.approx(3 * 11.0)
    assert prof.n_tasks == 3 * 5
    assert prof.n_failed == 0
    assert prof.per_stage["simulation"] == {"n": 12, "t_exec": 120.0}
    assert prof.per_stage["exchange"] == {"n": 3, "t_exec": 3.0}
    for c in range(3):
        assert f"exchange_{c}" in prof.results
    assert prof.t_exec == pytest.approx(123.0)
    assert prof.utilization == pytest.approx(123.0 / (33.0 * 4))


def test_legacy_pipeline_profile_equivalent_through_pst():
    class P(Pipeline):
        def stage_1(self, i):
            return _k(5.0)

        def stage_2(self, i):
            return _k(3.0)

    cl = SingleClusterEnvironment(cores=3, mode="sim")
    cl.allocate()
    prof = cl.run(P(stages=2, instances=3))
    cl.deallocate()
    assert prof.ttc == pytest.approx(8.0)
    assert prof.n_tasks == 6
    assert sorted(prof.results["tasks"]) == [
        f"pipe{p:05d}.stage{s}" for p in range(3) for s in (1, 2)]


def test_re_utilization_accumulates_across_cycles():
    """Regression for the per-cycle overwrite: utilization must cover ALL
    cycles, not just the last one."""
    class SkewRE(ReplicaExchange):
        durations = {0: [10.0, 10.0], 1: [4.0, 1.0]}

        def prepare_replica_for_md(self, r):
            return _k(self.durations[r.cycle][r.id])

        def prepare_exchange(self, replicas):
            return _k(0.0)

    cl = SingleClusterEnvironment(cores=2, mode="sim")
    cl.allocate()
    prof = cl.run(SkewRE(cycles=2, replicas=2))
    cl.deallocate()
    # busy = 20 (cycle0) + 5 (cycle1); ttc = 10 + 4; 2 slots
    assert prof.utilization == pytest.approx(25.0 / (14.0 * 2))
    # the old bug reported only cycle 1: 5 / (4 * 2)
    assert prof.utilization != pytest.approx(5.0 / 8.0)


def test_ttc_decomposition_invariant_sim():
    """Paper eq. (1): in sim mode on one slot the virtual makespan is
    exactly the execution time, and ttc ~ t_exec + t_enmd within the
    (real-clock, tiny) overhead tolerance."""
    class Bag(BagOfTasks):
        def task(self, i):
            return _k(2.0)

    cl = SingleClusterEnvironment(cores=1, mode="sim")
    cl.allocate()
    prof = cl.run(Bag(instances=5))
    cl.deallocate()
    assert prof.ttc == pytest.approx(prof.t_exec)
    assert prof.t_exec == pytest.approx(10.0)
    assert prof.t_enmd_overhead > 0.0
    assert abs(prof.ttc - (prof.t_exec + prof.t_enmd_overhead)) < 0.5


# -------------------------------------------------- adaptivity

def test_on_done_appends_stages_based_on_results():
    """The adaptivity hook: a stage's on_done inspects results and extends
    the pipeline until a convergence condition holds."""
    seen = []

    def make_stage(step):
        def on_done(stage, pipe):
            seen.append(step)
            if step < 3:                      # "not converged yet"
                pipe.add_stage(make_stage(step + 1))
        return Stage([TaskSpec(_k(1.0), name=f"refine{step}")],
                     name=f"refine{step}", on_done=on_done)

    prof = AppManager(PilotRuntime(slots=1, mode="sim")).run(
        PipelineSpec([make_stage(0)], name="adaptive"))
    assert seen == [0, 1, 2, 3]
    assert prof.n_tasks == 4
    assert prof.ttc == pytest.approx(4.0)


def test_unnamed_tasks_unique_across_repeated_stage_names():
    """The docstring's adaptive pattern: appended stages may REUSE a stage
    name; auto-generated task names must still be unique."""
    rounds = []

    def make_stage(r):
        def on_done(stage, pipe):
            rounds.append(r)
            if r < 2:
                pipe.add_stage(make_stage(r + 1))
        # same stage name every round, tasks left unnamed
        return Stage([TaskSpec(_k(1.0)), TaskSpec(_k(1.0))],
                     name="refine", on_done=on_done)

    prof = AppManager(PilotRuntime(slots=2, mode="sim")).run(
        PipelineSpec([make_stage(0)], name="p"))
    assert rounds == [0, 1, 2]
    assert prof.n_tasks == 6


def test_real_mode_cancels_never_fitting_task():
    """A task wider than the whole pilot must cancel, not hang the drain."""
    rt = PilotRuntime(slots=2, mode="real")
    sess = rt.session()
    sess.submit([Task(name="ok", run=lambda t: 1),
                 Task(name="wide", slots=5, run=lambda t: 2),
                 Task(name="after", deps=["wide"], run=lambda t: 3)])
    prof = sess.drain()
    g = sess.graph
    assert g.tasks["ok"].state == TaskState.DONE
    assert g.tasks["wide"].state == TaskState.CANCELED
    assert g.tasks["after"].state == TaskState.CANCELED
    assert prof.n_failed == 0
    assert prof.n_canceled == 2         # cancellation is visible in profile


def test_sim_mode_runs_narrow_task_behind_too_wide_one():
    """Sim deadlock handling must cancel ONLY the unsatisfiable wide task;
    an independent narrow task queued behind it still executes."""
    rt = PilotRuntime(slots=2, mode="sim")
    sess = rt.session()
    sess.submit([Task(name="wide", slots=4, duration=1.0),
                 Task(name="narrow", slots=1, duration=2.0)])
    prof = sess.drain()
    assert sess.graph.tasks["wide"].state == TaskState.CANCELED
    assert sess.graph.tasks["narrow"].state == TaskState.DONE
    assert prof.ttc == 2.0
    assert prof.n_canceled == 1


def test_app_manager_auto_names_survive_multiple_runs():
    am = AppManager(PilotRuntime(slots=1, mode="sim"))
    am.run(PipelineSpec([Stage([TaskSpec(_k(1.0))], name="s")]))
    prof = am.run(PipelineSpec([Stage([TaskSpec(_k(1.0))], name="s")]))
    assert sorted(prof.results["pipelines"]) == ["p0000", "p0001"]
    assert prof.n_tasks == 2            # cumulative across runs


def test_sal_should_continue_called_on_final_iteration():
    from repro.core import SimulationAnalysisLoop

    calls = []

    class SAL(SimulationAnalysisLoop):
        def simulation_stage(self, it, i):
            return _k(1.0)

        def analysis_stage(self, it, j):
            return _k(1.0)

        def should_continue(self, it, results):
            calls.append(it)
            return True

    cl = SingleClusterEnvironment(cores=2, mode="sim")
    cl.allocate()
    cl.run(SAL(maxiterations=3, simulation_instances=1,
               analysis_instances=1))
    cl.deallocate()
    assert calls == [0, 1, 2]       # legacy parity: final iteration included


def test_empty_control_stage_fires_on_done():
    fired = []
    ctrl = Stage([], name="ctrl",
                 on_done=lambda s, p: fired.append(1) or
                 [Stage([TaskSpec(_k(1.0))], name="work")])
    prof = AppManager(PilotRuntime(slots=1, mode="sim")).run(
        PipelineSpec([ctrl], name="p"))
    assert fired == [1]
    assert prof.n_tasks == 1


def test_failed_stage_halts_pipeline_only():
    """A failing task stops ITS pipeline; the sibling pipeline completes."""
    boom = Kernel("synthetic.fail")
    boom.arguments = {"fail_times": 99}
    bad = PipelineSpec([Stage([TaskSpec(boom)], name="s0"),
                        Stage([TaskSpec(_k())], name="s1")], name="bad")
    good = PipelineSpec([Stage([TaskSpec(_k())], name="s0"),
                         Stage([TaskSpec(_k())], name="s1")], name="good")
    prof = AppManager(PilotRuntime(slots=2, mode="real",
                                   max_retries=0)).run([bad, good])
    assert prof.results["pipelines"]["bad"]["state"] == "failed"
    assert prof.results["pipelines"]["good"]["state"] == "done"
    assert prof.n_failed == 1
    # the bad pipeline's stage 1 was never submitted (no global poisoning)
    assert prof.results["pipelines"]["bad"]["n_tasks"] == 1


# -------------------------------------------------- incremental session

def test_session_submit_drain_incremental():
    rt = PilotRuntime(slots=2, mode="sim")
    sess = rt.session()
    sess.submit(Task(name="a", duration=5.0))
    sess.drain()
    assert sess.vnow == 5.0
    sess.submit(Task(name="b", duration=3.0, deps=["a"]), dynamic=True)
    prof = sess.drain()
    assert sess.vnow == 8.0                   # the clock never reset
    assert prof.ttc == 8.0
    assert prof.n_tasks == 2
    with pytest.raises(ValueError, match="unknown dep"):
        sess.submit(Task(name="c", deps=["nope"]))


def test_session_journals_dynamic_injection_and_replays():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.jsonl")
        rt = PilotRuntime(slots=1, mode="sim", journal=Journal(path))
        sess = rt.session()
        sess.submit(Task(name="seed", duration=1.0))
        sess.drain()
        sess.submit(Task(name="injected", duration=1.0, deps=["seed"]),
                    dynamic=True)
        sess.drain()
        rt.journal.close()
        recs = [json.loads(ln) for ln in open(path)]
        sub = [r for r in recs if r["event"] == "submitted"]
        assert sub and sub[0]["task"] == "injected" and sub[0]["dynamic"]

        # restart: a fresh session replays both tasks (incl. the injected
        # one) from the journal and fires callbacks without re-running
        done = []
        rt2 = PilotRuntime(slots=1, mode="sim", journal=Journal(path))
        sess2 = rt2.session(on_task_done=lambda t, s: done.append(t.name))
        sess2.submit(Task(name="seed", duration=1.0))
        sess2.submit(Task(name="injected", duration=1.0, deps=["seed"]))
        prof = sess2.drain()
        assert prof.ttc == 0.0
        assert sorted(done) == ["injected", "seed"]


def test_journal_replays_results_to_callbacks():
    """Restart must hand callbacks the recorded RESULT, not None — pattern
    control flow (apply_exchange, should_continue) depends on it."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.jsonl")
        rt = PilotRuntime(slots=1, mode="real", journal=Journal(path))
        sess = rt.session()
        sess.submit(Task(name="a", run=lambda t: {"temps": [1.0, 2.0]}))
        sess.drain()
        rt.journal.close()

        got = []
        rt2 = PilotRuntime(slots=1, mode="real", journal=Journal(path))
        sess2 = rt2.session(on_task_done=lambda t, s: got.append(t.result))
        sess2.submit(Task(name="a", run=lambda t: {"temps": [9.0, 9.0]}))
        sess2.drain()
        assert got == [{"temps": [1.0, 2.0]}]   # replayed, not re-run


def test_device_swap_keeps_float64_temps_exact():
    """Non-float32-representable temperatures must come back bit-exact and
    unswapped pairs must not be reported accepted."""
    from repro.plugins.re_exchange import _device_swaps

    temps = [3e-4 * 1.3 ** i for i in range(4)]     # not f32-representable
    # equal losses: d = 0 -> log(u) < 0 always -> both pairs swap
    new_t, acc = _device_swaps([1.0, 1.0, 1.0, 1.0], temps, 0, 0, None)
    assert acc == [(0, 1), (2, 3)]
    assert list(new_t) == [temps[1], temps[0], temps[3], temps[2]]
    # huge gap favoring NO swap on (0,1): d = (0-10)*(1/t0-1/t1) << 0
    new_t, acc = _device_swaps([0.0, 10.0, 1.0, 1.0], temps, 0, 0, None)
    assert (0, 1) not in acc
    assert new_t[0] == temps[0] and new_t[1] == temps[1]   # bit-exact


# -------------------------------------------------- submesh placement

def test_exchange_kernel_swaps_on_granted_submesh():
    """Mesh-aware pilot: the PST task ctx carries submesh_for(task) and the
    re.exchange device path computes the swap on it."""
    import jax
    from repro.dist.topology import SlotTopology

    topo = SlotTopology.even(jax.devices(), 1, ("model",))
    rt = PilotRuntime(mode="real", topology=topo)
    xk = Kernel("re.exchange")
    temps = [1.0, 10.0, 20.0, 40.0]
    xk.arguments = {"replicas": 4, "cycle": 0, "temps": temps,
                    "losses": [10.0, 0.0, 0.0, 0.0], "device": True}
    prof = AppManager(rt).run(
        PipelineSpec([Stage([TaskSpec(xk, name="x")], name="exchange")],
                     name="re"))
    assert prof.n_failed == 0
    res = prof.results["tasks"]["x"]
    assert sorted(res["temps"]) == sorted(temps)
    # huge energy gap on pair (0, 1): deterministic accept
    assert res["temps"][0] == 10.0 and res["temps"][1] == 1.0
    assert (0, 1) in [tuple(p) for p in res["accepted"]]


# -------------------------------------------------- metropolis properties

@settings(max_examples=40, deadline=None)
@given(st.data())
def test_device_swap_preserves_temps_and_pair_symmetry(data):
    """A swap step permutes the temperature multiset and the decision is
    symmetric across pair orientation (right member mirrors left)."""
    import jax
    import jax.numpy as jnp
    from repro.core.ensemble import metropolis_swap_device

    n = data.draw(st.integers(2, 9))
    losses = jnp.array([data.draw(st.floats(0.0, 10.0)) for _ in range(n)],
                       jnp.float32)
    temps = jnp.array([data.draw(st.floats(0.1, 5.0)) for _ in range(n)],
                      jnp.float32)
    cycle = data.draw(st.integers(0, 3))
    key = jax.random.PRNGKey(data.draw(st.integers(0, 1000)))
    new_t, n_acc = metropolis_swap_device(losses, temps, cycle, key)
    new_t, temps = np.asarray(new_t), np.asarray(temps)

    # multiset preserved exactly (values only permute)
    np.testing.assert_array_equal(np.sort(new_t), np.sort(temps))
    # pairwise symmetry: each even/odd pair either swapped atomically or
    # stayed; members outside any pair never change
    start = cycle % 2
    paired = set()
    for i in range(start, n - 1, 2):
        j = i + 1
        paired |= {i, j}
        pair = (new_t[i], new_t[j])
        assert pair in ((temps[i], temps[j]), (temps[j], temps[i]))
    for i in set(range(n)) - paired:
        assert new_t[i] == temps[i]
    assert 0 <= int(n_acc) <= n // 2


# ------------------------------------------------------- SLA preemption

def test_sla_latency_preempts_throughput_through_pst():
    """TaskSpec(sla=...) plumbs priority/deadline onto the Task, and with
    PilotRuntime(preempt=True) a latency-class arrival evicts running
    throughput work instead of queueing behind it."""
    def app():
        bulk = PipelineSpec(
            [Stage([TaskSpec(_k(100.0), name=f"bulk{i}", sla="throughput")
                    for i in range(2)], name="work")], name="bulk")
        serve = PipelineSpec(
            [Stage([TaskSpec(_k(1.0), name="arrive")], name="arrive"),
             Stage([TaskSpec(_k(5.0, cores=2), name="lat", sla="latency")],
                   name="decode")], name="serve")
        return [serve, bulk]

    am = AppManager(PilotRuntime(slots=2, mode="sim", preempt=True))
    prof = am.run(app())
    g = am.session.graph
    lat = g.tasks["lat"]
    assert lat.priority == 10 and lat.meta["sla"] == "latency"
    assert lat.meta["deadline"] == pytest.approx(2.0)
    assert g.tasks["bulk0"].priority == 0
    assert prof.n_preempted == 1
    assert lat.v_started == 1.0 and lat.v_finished == 6.0
    victim = next(t for t in (g.tasks["bulk0"], g.tasks["bulk1"])
                  if any(h["outcome"] == "preempted" for h in t.history))
    assert victim.attempts == 2 and victim.state == TaskState.DONE
    assert prof.results["pipelines"]["bulk"]["state"] == "done"
    assert prof.n_failed == 0

    # baseline twin: same app, preemption off -> the latency task waits
    # out the full throughput occupancy (the p99 gap the bench measures)
    am2 = AppManager(PilotRuntime(slots=2, mode="sim", preempt=False))
    prof2 = am2.run(app())
    lat2 = am2.session.graph.tasks["lat"]
    assert prof2.n_preempted == 0
    assert lat2.v_started >= 100.0
    assert lat2.v_finished - 1.0 > 10 * (lat.v_finished - 1.0)


def test_explicit_priority_overrides_sla_class():
    p = PipelineSpec(
        [Stage([TaskSpec(_k(1.0), name="a", sla="throughput", priority=5,
                         deadline=9.0)], name="s0")], name="p")
    am = AppManager(PilotRuntime(slots=1, mode="sim"))
    am.run(p)
    t = am.session.graph.tasks["a"]
    assert t.priority == 5 and t.meta["deadline"] == 9.0
