"""Partition-rule validation WITHOUT devices: for every arch and both
production meshes, every param/cache/batch sharding must divide its array
(jit input shardings require exact divisibility)."""
import jax
import pytest

from repro.configs import SHAPES, get_config, list_configs
from repro.dist.sharding import (abstract_mesh, batch_shardings,
                                 cache_shardings, param_spec, state_shardings)

MESHES = {
    "pod16x16": abstract_mesh((16, 16), ("data", "model")),
    "pod2x16x16": abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    return n


def _check_tree(mesh, specs, shardings):
    flat_s, _ = jax.tree.flatten(shardings)
    flat_x = jax.tree.leaves(specs)
    assert len(flat_s) == len(flat_x)
    for x, s in zip(flat_x, flat_s):
        if s is None:
            continue
        spec = s.spec
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            n = _axis_size(mesh, entry)
            assert x.shape[d] % n == 0, \
                f"shape {x.shape} dim {d} not divisible by {entry}({n})"
        # no mesh axis used twice
        used = []
        for entry in spec:
            if entry is None:
                continue
            used += list(entry if isinstance(entry, tuple) else (entry,))
        assert len(used) == len(set(used)), f"axis reused in {spec}"


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", list(list_configs()))
def test_param_shardings_divide(arch, mesh_name):
    from repro.train import train_state_specs
    cfg = get_config(arch)
    mesh = MESHES[mesh_name]
    specs = train_state_specs(cfg)
    sh = state_shardings(cfg, mesh, specs)
    _check_tree(mesh, specs, sh)


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", list(list_configs()))
def test_cache_and_batch_shardings_divide(arch, mesh_name):
    from repro.configs import cell_applicable, input_specs
    from repro.serve import cache_specs
    cfg = get_config(arch).replace(param_dtype="bfloat16")
    mesh = MESHES[mesh_name]
    for shape in SHAPES.values():
        ok, _ = cell_applicable(cfg, shape)
        if not ok:
            continue
        b = input_specs(cfg, shape)
        _check_tree(mesh, b, batch_shardings(cfg, mesh, b, shape.kind))
        if shape.kind == "decode":
            c = cache_specs(cfg, shape.global_batch, shape.seq_len)
            _check_tree(mesh, c, cache_shardings(cfg, mesh, c))


def test_fsdp_spec_picks_divisible_dim():
    mesh = MESHES["pod16x16"]
    cfg = get_config("minicpm-2b")
    # vocab 122753 is indivisible -> embedding must fall back
    spec = param_spec(cfg, mesh, ("embed", "tok"), (122753, 2304))
    for d, entry in enumerate(spec):
        if entry is None:
            continue
        n = _axis_size(mesh, entry)
        assert (122753, 2304)[d] % n == 0
