"""Deterministic fallback for the tiny `hypothesis` subset these tests use.

The container may not ship hypothesis and installing packages is not an
option, so ``conftest.py`` installs this stub into ``sys.modules`` when the
real library is missing.  It implements just what the suite needs —
``given``, ``settings``, ``strategies.{integers,floats,booleans,lists,data}``
— drawing examples from a seeded numpy Generator, so runs are exactly
reproducible (no shrinking, no database).  With real hypothesis installed
this module is never imported.
"""
from __future__ import annotations


import sys
import types
import zlib

import numpy as np


class Strategy:
    def __init__(self, sample_fn, label="strategy"):
        self._sample = sample_fn
        self.label = label

    def sample(self, rng):
        return self._sample(rng)

    def __repr__(self):
        return self.label


def integers(min_value, max_value):
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)),
                    f"integers({min_value}, {max_value})")


def floats(min_value=0.0, max_value=1.0, allow_nan=None, allow_infinity=None,
           width=None):
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)),
                    f"floats({min_value}, {max_value})")


def booleans():
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


def lists(elements, min_size=0, max_size=10):
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return Strategy(sample, f"lists({elements!r}, {min_size}, {max_size})")


class _DataObject:
    """Interactive draw: ``data.draw(st.integers(0, 3))``."""

    def __init__(self, rng):
        self._rng = rng
        self.draws = []

    def draw(self, strategy, label=None):
        v = strategy.sample(self._rng)
        self.draws.append((label or strategy.label, v))
        return v


class _DataStrategy(Strategy):
    pass


def data():
    return _DataStrategy(lambda rng: rng, "data()")


_DEFAULT_MAX_EXAMPLES = 25


def given(*strategies):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the original one (it would treat drawn args as fixtures).
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}:{i}"
                                  .encode())
                rng = np.random.default_rng(seed)
                vals = [(_DataObject(rng) if isinstance(s, _DataStrategy)
                         else s.sample(rng)) for s in strategies]
                try:
                    fn(*vals)
                except Exception as e:
                    shown = [v.draws if isinstance(v, _DataObject) else v
                             for v in vals]
                    raise AssertionError(
                        f"falsifying example #{i} (seed {seed}): "
                        f"{fn.__name__}({shown})") from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # @settings may sit BELOW @given (applied first, tagging fn)
        wrapper._stub_max_examples = getattr(
            fn, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return deco


class settings:
    """Accepts and applies max_examples; ignores the rest (deadline etc.)."""

    _profiles: dict = {}

    def __init__(self, max_examples=None, **kwargs):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._stub_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        pass


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.filter_too_much, cls.data_too_large]


def install():
    """Register this stub as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = HealthCheck
    mod.__version__ = "0.0-repro-stub"
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "lists", "data"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
