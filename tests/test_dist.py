"""repro.dist unit tests: slot topology carving, executor integration with
real devices, and sharding-helper edge cases not covered by the
arch-sweep in test_sharding.py."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.sharding import (abstract_mesh, constrain_batch,
                                 constrain_like_params, constrain_logits,
                                 mesh_axis_sizes, param_spec)
from repro.dist.topology import SlotTopology

MESH = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


# ---------------------------------------------------------------- topology

def test_even_split_accounting():
    topo = SlotTopology.even(np.arange(12), 4, axis_names=("model",))
    assert topo.n_slots == 4
    assert topo.devices_per_slot == 3
    np.testing.assert_array_equal(topo.slot_devices([2])[0], [6, 7, 8])
    # multi-slot block is id-sorted regardless of request order
    np.testing.assert_array_equal(topo.slot_devices([3, 1]),
                                  [[3, 4, 5], [9, 10, 11]])


def test_even_split_rejects_indivisible():
    with pytest.raises(ValueError, match="not divisible"):
        SlotTopology.even(np.arange(10), 4)


def test_slot_devices_bounds():
    topo = SlotTopology.even(np.arange(8), 4)
    with pytest.raises(ValueError):
        topo.slot_devices([4])
    with pytest.raises(ValueError):
        topo.slot_devices([])


def test_from_mesh_pod_axis():
    # fake 2x4x4 device grid: one slot per pod, slot axes (data, model)
    class FakeMesh:
        devices = np.arange(32).reshape(2, 4, 4)
        axis_names = ("pod", "data", "model")

    topo = SlotTopology.from_mesh(FakeMesh())
    assert topo.n_slots == 2
    assert topo.axis_names == ("data", "model")
    assert topo.devices_per_slot == 16
    np.testing.assert_array_equal(topo.slot_devices([1])[0],
                                  np.arange(16, 32).reshape(4, 4))


def test_submesh_on_real_devices():
    devs = jax.devices()
    topo = SlotTopology.even(devs, len(devs))
    m = topo.submesh([0])
    assert m.devices.shape == (1,)
    assert m.axis_names == ("model",)


def test_runtime_submesh_for_task():
    from repro.runtime.executor import PilotRuntime
    from repro.runtime.states import Task, TaskGraph

    devs = jax.devices()
    rt = PilotRuntime(mode="real", topology=SlotTopology.even(devs, len(devs)))
    g = TaskGraph()
    seen = {}

    def run(task):
        m = rt.submesh_for(task)
        seen["axes"] = m.axis_names
        return m.devices.size

    g.add(Task(name="a", run=run))
    prof = rt.run(g)
    assert prof.n_failed == 0
    assert g.tasks["a"].result == 1
    assert seen["axes"] == ("model",)
    assert sorted(rt._free_ids) == list(range(len(devs)))


def test_runtime_rejects_unrecarvable_resize():
    """Growing past the carved submesh count re-carves (see the elastic
    tests below) — but only when the slot axis divides evenly; slots of a
    single device cannot split further."""
    from repro.runtime.executor import PilotRuntime
    rt = PilotRuntime(mode="sim", topology=SlotTopology.even(np.arange(4), 4))
    assert rt.slots == 4
    with pytest.raises(ValueError, match="cannot split"):
        rt.resize(8)
    with pytest.raises(ValueError, match="multiple"):
        rt.resize(6)        # 6 is not a multiple of the 4 carved slots


# ---------------------------------------------------------------- sharding

def test_param_spec_expert_parallel():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.sharding_profile == "tp_ep"
    spec = param_spec(cfg, MESH, ("blocks", "sub_0", "moe", "wi"),
                      (24, 128, 2048, 768))
    assert list(spec) == [None, "model", None, None]   # expert dim, not F


def test_param_spec_2d_fsdp():
    cfg = get_config("gemma2-2b")
    spec = param_spec(cfg, MESH, ("embed", "tok"), (256_000, 2304))
    sizes = mesh_axis_sizes(MESH)
    used = [a for e in spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    assert "model" in used and len(used) == len(set(used))
    for d, e in enumerate(spec):
        if e is None:
            continue
        n = int(np.prod([sizes[a] for a in
                         (e if isinstance(e, tuple) else (e,))]))
        assert (256_000, 2304)[d] % n == 0


def test_constrain_helpers_identity_without_mesh():
    cfg = get_config("gemma2-2b")
    x = jax.numpy.ones((2, 8, 4))
    assert constrain_batch(cfg, None, x, "train") is x
    assert constrain_logits(cfg, None, x) is x
    tree = {"embed": {"tok": x}}
    assert constrain_like_params(cfg, None, tree)["embed"]["tok"] is x


def test_abstract_mesh_helper_axes():
    m = abstract_mesh((4, 8), ("data", "model"))
    assert tuple(m.axis_names) == ("data", "model")
    assert mesh_axis_sizes(m) == {"data": 4, "model": 8}
