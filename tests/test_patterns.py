"""Pattern semantics: exact ordering constraints per pattern (paper §3.4)."""
import threading
import time

from repro.core import (BagOfTasks, Kernel, Pipeline, ReplicaExchange,
                        SimulationAnalysisLoop, SingleClusterEnvironment,
                        register_kernel)

EVENTS = []
_LOCK = threading.Lock()


@register_kernel("test.trace", description="record execution order")
def trace_kernel(args, ctx):
    with _LOCK:
        EVENTS.append((args["tag"], time.perf_counter()))
    return {"tag": args["tag"]}


def _trace(tag):
    k = Kernel("test.trace")
    k.arguments = {"tag": tag}
    return k


def _run(pattern, cores=8, **kw):
    cl = SingleClusterEnvironment(cores=cores, **kw)
    cl.allocate()
    prof = cl.run(pattern)
    cl.deallocate()
    return prof


def setup_function(fn):
    EVENTS.clear()


def test_pipeline_stage_ordering():
    class P(Pipeline):
        def stage_1(self, i):
            return _trace(("s1", i))

        def stage_2(self, i):
            return _trace(("s2", i))

    prof = _run(P(stages=2, instances=6))
    assert prof.n_failed == 0
    t = {tag: ts for tag, ts in EVENTS}
    for i in range(6):
        assert t[("s1", i)] <= t[("s2", i)], "stage i precedes i+1 per pipe"


def test_pipes_are_independent():
    """A slow pipe must not block other pipes' later stages."""
    class P(Pipeline):
        def stage_1(self, i):
            if i == 0:
                k = Kernel("synthetic.sleep")
                k.arguments = {"seconds": 0.3}
                return k
            return _trace(("s1", i))

        def stage_2(self, i):
            return _trace(("s2", i))

    _run(P(stages=2, instances=3), cores=3)
    done_tags = [tag for tag, _ in EVENTS]
    assert ("s2", 1) in done_tags and ("s2", 2) in done_tags


def test_re_exchange_is_barrier():
    class RE(ReplicaExchange):
        def prepare_replica_for_md(self, r):
            return _trace(("md", r.id, r.cycle))

        def prepare_exchange(self, replicas):
            return _trace(("x", replicas[0].cycle))

    prof = _run(RE(cycles=2, replicas=4))
    assert prof.n_failed == 0
    t = {tag: ts for tag, ts in EVENTS}
    for c in range(2):
        for r in range(4):
            assert t[("md", r, c)] <= t[("x", c)], "exchange after all sims"
    for r in range(4):
        assert t[("x", 0)] <= t[("md", r, 1)], "next cycle after exchange"


def test_re_replica_cycle_advances():
    seen = []

    class RE(ReplicaExchange):
        def prepare_replica_for_md(self, r):
            seen.append((r.id, r.cycle))
            return _trace(("md", r.id, r.cycle))

        def prepare_exchange(self, replicas):
            return _trace(("x", replicas[0].cycle))

    _run(RE(cycles=3, replicas=2))
    assert sorted(seen) == [(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]


def test_sal_phases_and_convergence():
    class SAL(SimulationAnalysisLoop):
        def pre_loop(self):
            return _trace(("pre",))

        def simulation_stage(self, it, i):
            return _trace(("sim", it, i))

        def analysis_stage(self, it, j):
            return _trace(("ana", it, j))

        def post_loop(self):
            return _trace(("post",))

        def should_continue(self, it, results):
            return it < 1      # stop after 2 iterations (0, 1)

    prof = _run(SAL(maxiterations=5, simulation_instances=3,
                    analysis_instances=2))
    assert prof.n_failed == 0
    t = {tag: ts for tag, ts in EVENTS}
    iters = {tag[1] for tag, _ in EVENTS if tag[0] == "sim"}
    assert iters == {0, 1}, "convergence hook stopped the loop"
    for it in range(2):
        for i in range(3):
            for j in range(2):
                assert t[("sim", it, i)] <= t[("ana", it, j)]
    assert t[("pre",)] <= min(ts for tag, ts in EVENTS if tag[0] == "sim")
    assert t[("post",)] >= max(ts for tag, ts in EVENTS if tag[0] == "ana")


def test_bag_of_tasks():
    class B(BagOfTasks):
        def task(self, i):
            return _trace(("t", i))

    prof = _run(B(instances=5))
    assert prof.n_failed == 0
    assert len([1 for tag, _ in EVENTS if tag[0] == "t"]) == 5


def test_pattern_overhead_accounted():
    class B(BagOfTasks):
        def task(self, i):
            return _trace(("t", i))

    prof = _run(B(instances=10))
    assert prof.t_pattern_overhead > 0
    assert prof.t_rts_overhead > 0
    assert prof.n_tasks == 10
