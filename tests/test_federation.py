"""repro.federation: fleet dispatch, recruiter, whole-pilot loss, replay.

Covers the federated runtime end to end: late-binding dispatch spreading a
bag over heterogeneous pilots, locality-aware pilot choice over one shared
store (cross-pilot fetches only when no local replica exists), whole-pilot
death mid-run with retries landing on survivors and the dead pilot's
replicas dropped, per-pilot journal replay reconstructing the fleet at the
right attempt counts, the recruiter's grow/shrink/hysteresis behavior, the
journal name-collision guard, sanitizer pilot-scoping, the AppManager
surface (same PST app, federated by swapping the runtime object), and the
static diagnostics E114/W205 with their clean twins.
"""
import json

import pytest

from repro.analysis import sanitize_file, validate_app
from repro.core import AppManager, Channel, Kernel, PipelineSpec, Stage, \
    TaskSpec
from repro.federation import Fleet, Recruiter, build_fleet, make_pilot
from repro.runtime.journal import Journal, journal_from_env
from repro.runtime.states import Task, TaskGraph, TaskState


def bag(n, dur=10.0, slots=1):
    g = TaskGraph()
    for i in range(n):
        g.add(Task(name=f"t{i}", duration=dur, slots=slots))
    return g


def _member(dur=1.0, nbytes=None, **attrs):
    k = Kernel("synthetic.noop")
    k.sim_duration = dur
    if nbytes is not None:
        k.output_nbytes = nbytes
    for name, v in attrs.items():
        setattr(k, name, v)
    return k


def _coupled(pipelines=2, cycles=4, members=4, nbytes=64 << 20):
    pipes = []
    for p in range(pipelines):
        ch = Channel(f"traj{p}")
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(nbytes=nbytes), name=f"p{p}.c{c}.m{m}")
                    for m in range(members)], name=f"cycle{c}", outputs=[ch])
             for c in range(cycles)], name=f"producer{p}"))
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(dur=0.5), name=f"a{p}.r{c}")],
                   name=f"round{c}", inputs={"traj": ch})
             for c in range(cycles)], name=f"analysis{p}"))
    return pipes


# ------------------------------------------------------------- dispatch

def test_fleet_spreads_bag_across_pilots():
    fleet = build_fleet(2, slots=4, staging=False)
    g = bag(40, dur=1.0)
    prof = fleet.run(g)
    assert prof.n_failed == 0
    assert prof.ttc == pytest.approx(5.0)      # 40 tasks / 8 slots, 1s each
    by = {}
    for t in g.tasks.values():
        by[t.meta["pilot"]] = by.get(t.meta["pilot"], 0) + 1
    assert by == {"p1": 20, "p2": 20}
    assert fleet.slots == 8 and fleet.summary()["n_active"] == 2


def test_fleet_respects_per_pilot_width():
    # 3 slots free fleet-wide is NOT 3 slots on one pilot: a 3-wide task
    # must wait for a single pilot that can host it
    fleet = Fleet({"a": make_pilot("a", slots=2), "b": make_pilot("b", slots=4)})
    g = TaskGraph()
    g.add(Task(name="wide", duration=1.0, slots=3))
    prof = fleet.run(g)
    assert prof.n_failed == 0
    assert g.tasks["wide"].meta["pilot"] == "b"


def test_task_wider_than_every_pilot_cancels_not_hangs():
    fleet = build_fleet(2, slots=4, staging=False)    # fleet sum = 8
    g = bag(1, dur=1.0, slots=6)                      # no single pilot fits
    prof = fleet.run(g)
    assert prof.n_canceled == 1
    assert g.tasks["t0"].state == TaskState.CANCELED


def test_locality_dispatch_avoids_cross_pilot_copies():
    fleet = build_fleet(2, slots=8, slots_per_pod=2, journal_base=None)
    am = AppManager(fleet)
    prof = am.run(_coupled())
    assert prof.n_failed == 0
    stats = fleet.staging.planner.stats
    # every analysis round late-binds to the pilot holding its inputs
    assert stats["cross_pilot"] == 0 and stats["bytes_cross_pilot"] == 0
    assert fleet.staging.planner.summary()["locality_hit_rate"] == 1.0
    fed = prof.results["federation"]
    assert fed["n_pilots"] == 2 and sum(fed["dispatch"].values()) == prof.n_tasks
    fleet.close()


def test_cross_pilot_fetch_when_only_remote_replica():
    # force the consumer onto the pilot WITHOUT the replica: the producer's
    # pilot is retired between runs, so stage-in must fetch pilot-to-pilot
    fleet = build_fleet(2, slots=4, slots_per_pod=2)
    am = AppManager(fleet)
    ch = Channel("traj")
    prod = PipelineSpec([Stage([TaskSpec(_member(nbytes=64 << 20),
                                         name="prod")],
                               name="s0", outputs=[ch])], name="P")
    assert am.run([prod]).n_failed == 0
    src = am.session.graph.tasks["prod"].meta["pilot"]
    fleet.retire_pilot(src)

    cons = PipelineSpec([Stage([TaskSpec(_member(), name="cons")],
                               name="r0", inputs={"traj": ch})], name="C")
    assert am.run([cons]).n_failed == 0
    t = am.session.graph.tasks["cons"]
    assert t.meta["pilot"] != src
    stats = fleet.staging.planner.stats
    assert stats["cross_pilot"] >= 1 and stats["bytes_cross_pilot"] > 0
    assert t.t_data > 0                    # the fetch was charged
    fleet.close()


# ---------------------------------------------------------- pilot failure

def test_whole_pilot_loss_mid_run():
    # staging on => slot-id tracking on, so pods are addressable to kill
    fleet = build_fleet(2, slots=4, max_retries=2)
    g = bag(16, dur=2.0)
    killed = {}

    def chaos(rt, graph, now):
        if now >= 2.0 and not killed:
            killed["t"] = now
            fleet.inject_pilot_failure("p2")
    for rt in fleet.pilots.values():
        rt.on_schedule = chaos
    prof = fleet.run(g)

    assert prof.n_failed == 0
    assert prof.n_pod_lost == 4 and prof.n_retries == 4
    assert fleet.pilots["p2"].slots == 0
    assert fleet.dead_pods == {f"p2:pod{i}" for i in range(4)}
    # every retry and every post-kill launch landed on the survivor
    for t in g.tasks.values():
        assert t.state == TaskState.DONE
        if any(h["outcome"] == "pod_lost" for h in t.history):
            assert t.history[-1]["pod"].startswith("p1:")


def test_pilot_loss_drops_its_replicas():
    fleet = build_fleet(2, slots=4, slots_per_pod=2)
    ch = Channel("out")
    prod = PipelineSpec(
        [Stage([TaskSpec(_member(dur=2.0, nbytes=16 << 20), name=f"w{c}.{m}")
                for m in range(4)], name=f"c{c}", outputs=[ch])
         for c in range(2)], name="P")
    killed = {}

    def chaos(rt, graph, now):
        if now >= 2.0 and not killed:
            killed["t"] = now
            fleet.inject_pilot_failure("p2")
    for rt in fleet.pilots.values():
        rt.on_schedule = chaos
    prof = AppManager(fleet).run([prod])
    assert prof.n_failed == 0 and killed
    store = fleet.staging.store
    locs = {loc for d in list(store._blobs) for loc in store.locations(d)}
    assert not any(loc.startswith("p2:") for loc in locs)
    fleet.close()


# ------------------------------------------------------------- journals

def test_journal_replay_resumes_fleet(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_JOURNAL_DIR", str(tmp_path))
    fleet = build_fleet(2, slots=4, staging=False, journal_base="run")
    prof = fleet.run(bag(8, dur=1.0))
    assert prof.n_failed == 0
    fleet.close()
    assert {p.name for p in tmp_path.glob("*.jsonl")} == \
        {"run-fleet.jsonl", "run-p1.jsonl", "run-p2.jsonl"}

    fleet2 = build_fleet(2, slots=4, staging=False, journal_base="run")
    g = bag(8, dur=1.0)
    g.add(Task(name="fresh", duration=1.0))
    prof2 = fleet2.run(g)
    assert {"event": "journal_skip", "n": 8} in prof2.events
    assert prof2.ttc == pytest.approx(1.0)     # only the fresh task ran
    assert g.tasks["fresh"].state == TaskState.DONE
    # replayed tasks are DONE without re-running (attempts untouched)
    assert all(g.tasks[f"t{i}"].state == TaskState.DONE
               and g.tasks[f"t{i}"].attempts == 0 for i in range(8))
    fleet2.close()


def test_journal_replay_resumes_mid_retry_on_any_pilot(tmp_path,
                                                       monkeypatch):
    # a crash recorded in PILOT journals (prefixed pods) must replay into
    # the fleet session: attempts resume, the dead pilot's pod stays blamed
    monkeypatch.setenv("REPRO_JOURNAL_DIR", str(tmp_path))
    j = Journal(str(tmp_path / "rep-p2.jsonl"), tag="p2")
    crashed = Task(name="t0")
    crashed.attempts = 1
    j.record(crashed, "pod_lost", pod="p2:pod0")
    j.close()

    fleet = build_fleet(2, slots=4, journal_base="rep")
    g = TaskGraph()
    g.add(Task(name="t0", duration=5.0))
    prof = fleet.run(g)
    t = g.tasks["t0"]
    assert prof.n_failed == 0 and t.state == TaskState.DONE
    assert t.attempts == 2                     # resumed, not restarted
    done = [h for h in t.history if h["outcome"] == "done"]
    assert done[-1]["pod"] != "p2:pod0"
    fleet.close()


def test_journal_name_collision_gets_suffix(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_JOURNAL_DIR", str(tmp_path))
    a = journal_from_env("twin", tag="p1")
    b = journal_from_env("twin", tag="p2")
    assert a.path != b.path and b.path.endswith("twin-2.jsonl")
    a.close(), b.close()
    # name freed at close: a fresh claim reuses the base name
    c = journal_from_env("twin")
    assert c.path.endswith("twin.jsonl")
    c.close()


def test_sanitizer_accepts_per_pilot_journals(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_JOURNAL_DIR", str(tmp_path))
    fleet = build_fleet(2, slots=4, journal_base="san")
    killed = {}

    def chaos(rt, graph, now):
        if now >= 1.0 and not killed:
            killed["t"] = now
            fleet.inject_pilot_failure("p2")
    for rt in fleet.pilots.values():
        rt.on_schedule = chaos
    fleet.run(bag(8, dur=2.0))
    fleet.close()
    for path in sorted(tmp_path.glob("*.jsonl")):
        report = sanitize_file(str(path))
        assert report.ok, f"{path.name}: {report.format()}"


def test_tagged_records_carry_pilot_field(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_JOURNAL_DIR", str(tmp_path))
    fleet = build_fleet(2, slots=4, staging=False, journal_base="tag")
    fleet.run(bag(4, dur=1.0))
    fleet.close()
    recs = [json.loads(line) for line in
            (tmp_path / "tag-p1.jsonl").read_text().splitlines()]
    assert recs and all(r.get("pilot") == "p1" for r in recs)


# ------------------------------------------------------------- recruiter

def test_recruiter_grows_fleet_to_backlog():
    rec = Recruiter(min_pilots=1, max_pilots=4, slots_per_pilot=4,
                    budget_slots=16, hysteresis_s=6.0, spinup_s=2.0)
    fleet = build_fleet(1, slots=4, staging=False, recruiter=rec)
    prof = fleet.run(bag(200, dur=1.0))
    assert prof.n_failed == 0
    s = rec.summary()
    assert s["n_spawned"] == 3 and s["n_joined"] == 3
    assert s["direction_flips"] == 0           # converged, no oscillation
    assert len(fleet.active()) == 4
    # static 4 slots would take 50s; elasticity lands well under that
    assert prof.ttc < 30.0


def test_recruiter_respects_slot_budget():
    rec = Recruiter(min_pilots=1, max_pilots=8, slots_per_pilot=4,
                    budget_slots=8, hysteresis_s=4.0, spinup_s=1.0)
    fleet = build_fleet(1, slots=4, staging=False, recruiter=rec)
    prof = fleet.run(bag(100, dur=1.0))
    assert prof.n_failed == 0
    assert fleet.slots <= 8                    # never exceeded the budget
    assert rec.summary()["n_spawned"] <= 1


def test_recruiter_shrinks_idle_fleet():
    rec = Recruiter(min_pilots=1, max_pilots=4, slots_per_pilot=4,
                    budget_slots=16, hysteresis_s=1.0, spinup_s=0.5)
    fleet = build_fleet(3, slots=4, staging=False, recruiter=rec)
    sess = fleet.session()
    g = sess.graph
    # a long straggler keeps the session alive after the bag drains
    g.add(Task(name="long", duration=40.0))
    for i in range(8):
        g.add(Task(name=f"s{i}", duration=1.0))
    prof = sess.drain()
    assert prof.n_failed == 0
    assert rec.summary()["n_retired"] >= 1
    assert len(fleet.active()) >= rec.min_pilots


def test_recruiter_hysteresis_spaces_decisions():
    rec = Recruiter(min_pilots=1, max_pilots=4, slots_per_pilot=4,
                    budget_slots=16, hysteresis_s=6.0, spinup_s=2.0)
    fleet = build_fleet(1, slots=4, staging=False, recruiter=rec)
    fleet.run(bag(200, dur=1.0))
    decisions = [e["t"] for e in rec.events
                 if e["action"] in ("spawn", "retire")]
    assert all(b - a >= rec.hysteresis_s
               for a, b in zip(decisions, decisions[1:]))


# ------------------------------------------------------------- real mode

def test_real_mode_federated_smoke():
    fleet = build_fleet(2, slots=2, mode="real", staging=False)
    g = TaskGraph()
    for i in range(6):
        g.add(Task(name=f"r{i}", run=lambda task: "ok"))
    prof = fleet.run(g)
    assert prof.n_failed == 0
    assert all(t.result == "ok" for t in g.tasks.values())
    assert {t.meta["pilot"] for t in g.tasks.values()} <= {"p1", "p2"}


# ------------------------------------------------------- static validator

def _fleet_pipes(cores):
    p = PipelineSpec([Stage([TaskSpec(_member(cores=cores))], name="s0")],
                     name="p")
    return [p]


def test_e114_fleet_slots_unsatisfiable():
    fleet = build_fleet(2, slots=4, staging=False)
    # 6 slots fits the fleet SUM but no pilot the fleet can ever field
    codes = validate_app(_fleet_pipes(6), runtime=fleet).codes()
    assert "E114" in codes
    # clean twin: same fleet, width one pilot can host
    assert validate_app(_fleet_pipes(4), runtime=fleet).ok


def test_e114_clean_when_recruiter_can_field_wider_pilot():
    rec = Recruiter(max_pilots=4, slots_per_pilot=8, budget_slots=16,
                    hysteresis_s=10.0, spinup_s=5.0)
    fleet = build_fleet(1, slots=4, staging=False, recruiter=rec)
    codes = validate_app(_fleet_pipes(6), runtime=fleet).codes()
    # no active pilot hosts 6 today, but the factory builds 8-slot pilots
    assert "E114" not in codes and "W202" in codes


def test_w205_recruiter_thrash_prone():
    rec = Recruiter(hysteresis_s=2.0, spinup_s=10.0)     # decides blind
    fleet = build_fleet(1, slots=4, staging=False, recruiter=rec)
    assert "W205" in validate_app(_fleet_pipes(1), runtime=fleet).codes()
    # clean twin: hysteresis covers the spin-up window
    rec2 = Recruiter(hysteresis_s=10.0, spinup_s=10.0)
    fleet2 = build_fleet(1, slots=4, staging=False, recruiter=rec2)
    assert validate_app(_fleet_pipes(1), runtime=fleet2).ok
