"""Training substrate: loss descent, microbatch equivalence, schedules,
fused chunked loss vs naive, checkpoint roundtrip."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLM
from repro.optim.schedules import cosine, wsd
from repro.train import (TrainHyper, build_train_step, make_train_state)
from repro.train.losses import chunked_softmax_xent

SHAPE = ShapeSpec("t", "train", 32, 4)


def test_memorization_descent():
    cfg = reduced(get_config("gemma2-2b"))
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(
        cfg, hyper=TrainHyper(base_lr=3e-3, warmup=2, total_steps=100)))
    batch = SyntheticLM(cfg, SHAPE).batch_at(0)
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0
    assert np.isfinite(losses).all()


def test_microbatch_equivalence():
    """grad-accum over 2 microbatches == single batch step (same data)."""
    cfg1 = reduced(get_config("minicpm-2b")).replace(microbatches=1)
    cfg2 = cfg1.replace(microbatches=2)
    s1 = make_train_state(cfg1, jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x, s1)
    batch = SyntheticLM(cfg1, SHAPE).batch_at(0)
    st1, m1 = jax.jit(build_train_step(cfg1))(s1, batch)
    st2, m2 = jax.jit(build_train_step(cfg2))(s2, batch)
    # losses averaged over microbatches differ only by batch-mean weighting
    # (equal-sized microbatches, equal token counts -> identical)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    p1 = jax.tree.leaves(st1["params"])
    p2 = jax.tree.leaves(st2["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_chunked_loss_matches_naive():
    cfg = reduced(get_config("gemma2-2b")).replace(loss_chunk=8)
    from repro.models import forward, init_params
    from repro.models.transformer import lm_logits
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    h = forward(cfg, params, tokens)["h"]
    loss, cnt = chunked_softmax_xent(cfg, params, h, labels)
    logits = lm_logits(cfg, params, h)
    lse = jax.nn.logsumexp(logits, -1)
    corr = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    naive = jnp.mean(lse - corr)
    assert abs(float(loss) - float(naive)) < 1e-4
    assert int(cnt) == B * S


def test_label_masking():
    cfg = reduced(get_config("gemma2-2b"))
    from repro.models import forward, init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    labels = jnp.where(jnp.arange(32)[None] < 16, tokens, -1)
    h = forward(cfg, params, tokens)["h"]
    loss, cnt = chunked_softmax_xent(cfg, params, h, labels)
    assert int(cnt) == 2 * 16
    assert np.isfinite(float(loss))


def test_wsd_schedule_shape():
    lr = [float(wsd(jnp.asarray(s), base_lr=1.0, warmup=10,
                    total_steps=100)) for s in range(100)]
    assert lr[0] < 0.2                      # warming up
    assert abs(lr[50] - 1.0) < 1e-6         # stable phase
    assert lr[99] < 0.2                     # decayed
    c = [float(cosine(jnp.asarray(s), base_lr=1.0, warmup=10,
                      total_steps=100)) for s in range(100)]
    assert c[50] < 1.0 and c[99] <= c[50]


def test_checkpoint_roundtrip_and_retention():
    cfg = reduced(get_config("gemma3-4b"))
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3):
            ck.save(state, s)
        assert ck.all_steps() == [2, 3]      # retention
        restored, step = ck.restore(jax.eval_shape(lambda: state))
        assert step == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async():
    cfg = reduced(get_config("gemma2-2b"))
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=1)
        ck.save(state, 7, blocking=False)
        ck.wait()
        assert ck.latest_step() == 7
