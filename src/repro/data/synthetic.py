"""Synthetic data pipeline: deterministic, host-sharded, double-buffered.

Produces LM batches matching ``input_specs`` for an (arch, shape) cell.  On a
real fleet each host generates only its addressable shard (the generator is
keyed by (seed, step, host)); here that structure is kept but runs single
host.  A background thread keeps one batch of lookahead (double buffering) so
host data generation overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, *, seed: int = 0,
                 batch_override: Optional[int] = None, shardings=None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.B = batch_override or shape.global_batch
        self.S = shape.seq_len
        self.shardings = shardings

    def batch_at(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng((self.seed, step))
        cfg = self.cfg
        toks = rng.integers(0, cfg.vocab_size,
                            (self.B, self.S + 1), dtype=np.int32)
        out = {"tokens": toks[:, :-1],
               "labels": toks[:, 1:].copy(),
               "seg_ids": np.zeros((self.B, self.S), np.int32)}
        if cfg.vision_tokens:
            out["vision_embeds"] = rng.standard_normal(
                (self.B, cfg.vision_tokens, cfg.d_model)).astype(np.float32) \
                .astype(np.dtype("bfloat16") if cfg.dtype == "bfloat16"
                        else np.float32) * 0.02
        if cfg.encoder_layers:
            out["enc_frames"] = (rng.standard_normal(
                (self.B, cfg.encoder_seq, cfg.d_model)) * 0.02).astype(
                np.dtype("bfloat16") if cfg.dtype == "bfloat16"
                else np.float32)
        if self.shardings is not None:
            out = {k: jax.device_put(v, self.shardings.get(k))
                   for k, v in out.items()}
        return out

    def batches(self, start: int = 0, prefetch: int = 1
                ) -> Iterator[Dict[str, Any]]:
        """Double-buffered iterator: generation overlaps consumption."""
        q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        stop = threading.Event()

        def producer():
            step = start
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
