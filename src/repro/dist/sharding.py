"""Partition specs for the production meshes (the distribution layer).

Mesh axis conventions
---------------------
Two production meshes are supported (see ``repro.launch.mesh``):

* ``pod16x16``   — axes ``("data", "model")``, 256 chips (one pod)
* ``pod2x16x16`` — axes ``("pod", "data", "model")``, 512 chips (two pods)

Axis roles:

* ``model`` — tensor-parallel axis.  Shards the hidden/ff/head/vocab dim of
  weight matrices (Megatron-style), the kv-head or head_dim of decode
  caches, and the vocab dim of logits.
* ``data`` — data-parallel axis.  Shards the batch dim of every input and
  cache; under the ``fsdp`` sharding profile it additionally shards one
  weight dim of each parameter (so parameters are gathered on use).
* ``pod`` — outermost data-parallel axis of the multi-pod mesh.  Batch and
  FSDP sharding use ``("pod", "data")`` combined when divisible.  It is
  also the natural slot axis for the ensemble layer: one replica-exchange
  member per pod (see ``repro.dist.topology``).
* ``slot`` — leading axis of a *multi-slot* submesh returned by
  ``SlotTopology.submesh``; treated as an additional (outermost)
  data-parallel axis, so a task spanning k slots gets k-fold wider batch
  sharding.

Per-arch behaviour is selected by ``cfg.sharding_profile``:

* ``fsdp``  — 2D: tensor-parallel over ``model`` + parameter sharding over
  the data axes (minicpm, gemma2/3, recurrentgemma, whisper).
* ``tp``    — tensor-parallel only; parameters replicated across the data
  axes (nemotron, internvl, falcon-mamba, grok's giant experts).
* ``tp_ep`` — like ``tp`` but MoE expert weights are sharded over ``model``
  on the *expert* dim (expert parallelism; qwen3-moe, E=128).

Divisibility-fallback rule
--------------------------
A dim is sharded over a mesh axis (or axis tuple) only when its size is
*exactly divisible* by the axis size — jit input shardings require it.
Every placement therefore tries an ordered list of candidate dims and axis
groups and takes the first exact fit; when nothing fits, the dim (or the
whole leaf) stays replicated.  Example: minicpm-2b's vocab 122753 is not
divisible by 16, so the vocab-parallel embedding falls back to sharding
d_model=2304 over ``model`` and leaves the vocab dim whole; long_500k's
batch of 1 leaves the batch dim unsharded.  No mesh axis is ever assigned
to two dims of the same array.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import AbstractMesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

MODEL_AXIS = "model"
# widest-first; "slot" is the leading axis of a multi-slot submesh built by
# repro.dist.topology.SlotTopology.submesh (extra data parallelism for tasks
# spanning several pilot slots)
DATA_AXES = ("slot", "pod", "data")

# Leaf names that are always replicated: norms/gains/biases and small
# per-channel vectors (gathering them is cheaper than the bookkeeping).
_REPLICATED_LEAVES = frozenset({
    "scale", "bias", "q_norm", "k_norm", "a_param", "dt_bias", "D",
    "conv_b", "router", "pos",
})


# ---------------------------------------------------------------- mesh utils

def abstract_mesh(shape: Sequence[int], axes: Sequence[str]) -> AbstractMesh:
    """Version-portable AbstractMesh((16, 16), ("data", "model"))."""
    try:
        return AbstractMesh(tuple(shape), tuple(axes))  # jax >= 0.5 signature
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))    # jax 0.4.x signature


def shardable_recarve_counts(topology) -> List[int]:
    """Slot counts reachable by ``SlotTopology.recarve`` that keep the
    sharding contract intact.

    ``recarve`` grows by splitting the FIRST slot axis.  When that axis is
    the tensor-parallel ``model`` axis, any split would change the axis
    size every weight matrix was sharded against — existing ``tp``/``fsdp``
    placements become invalid mid-run — so only the current count
    survives.  Splitting a data axis (``data``/``pod``/``slot``) only
    narrows batch parallelism, which the divisibility-fallback rule
    already tolerates, so every topologically reachable count is fine.
    The static validator (repro.analysis, E108) checks cores requests
    against THIS list, not the raw topological one."""
    counts = topology.reachable_slot_counts()
    if topology.axis_names and topology.axis_names[0] == MODEL_AXIS:
        return [topology.n_slots]
    return counts


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    """{axis name: size} for Mesh and AbstractMesh alike."""
    try:
        return dict(mesh.shape)
    except TypeError:
        return dict(zip(mesh.axis_names, mesh.axis_sizes))


def data_axis_groups(mesh) -> List[Tuple[str, ...]]:
    """Candidate data-parallel axis groups, widest first."""
    present = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    groups: List[Tuple[str, ...]] = []
    if len(present) > 1:
        groups.append(present)
    groups.extend((a,) for a in reversed(present))  # "data" before "pod"
    return groups


def _group_size(sizes: Dict[str, int], group: Tuple[str, ...]) -> int:
    return math.prod(sizes[a] for a in group)


def _entry(group: Tuple[str, ...]):
    return group[0] if len(group) == 1 else group


def _assign(entries: List[Any], used: set, shape: Tuple[int, ...],
            dims: Sequence[int], groups: Sequence[Tuple[str, ...]],
            sizes: Dict[str, int]) -> None:
    """Place the first group that exactly divides one of ``dims``.

    ``dims`` are tried in preference order; a dim that is already assigned
    or indivisible falls through to the next candidate (the fallback rule).
    """
    for d in dims:
        if d < 0 or d >= len(shape) or entries[d] is not None:
            continue
        for g in groups:
            if any(a in used for a in g):
                continue
            if shape[d] % _group_size(sizes, g) == 0:
                entries[d] = _entry(g)
                used.update(g)
                return


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "name",
                                               getattr(k, "idx", k))))
                 for k in path)


# ---------------------------------------------------------------- params

def _param_dim_prefs(cfg: ModelConfig, names: Tuple[str, ...],
                     shape: Tuple[int, ...]) -> Tuple[List[int], List[int]]:
    """(tensor-parallel dim candidates, fsdp dim candidates) for a leaf.

    Dims are counted from the RIGHT so scanned stacks — which carry a
    leading (num_groups,) dim from vmap/scan — use the same rules as
    unscanned blocks.
    """
    nd = len(shape)
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    if nd < 2 or leaf in _REPLICATED_LEAVES:
        return [], []
    r = lambda i: nd + i  # noqa: E731  (negative offset -> absolute dim)

    if leaf == "tok" or parent == "embed":       # (V, D): vocab-parallel
        return [r(-2), r(-1)], [r(-1), r(-2)]
    if leaf == "head":                           # (D, V)
        return [r(-1), r(-2)], [r(-2), r(-1)]
    if parent in ("attn", "xattn"):
        if leaf == "wo":                         # (q_dim, D)
            return [r(-2)], [r(-1)]
        return [r(-1)], [r(-2)]                  # wq/wk/wv: (D, out)
    if parent == "moe":
        if cfg.sharding_profile == "tp_ep":      # expert-parallel: (E, ·, ·)
            return [r(-3)], []
        if leaf == "wo":                         # (E, F, D): TP on F
            return [r(-2), r(-3)], [r(-1)]
        return [r(-1), r(-3)], [r(-2)]           # wi/wg: (E, D, F)
    if parent in ("mlp", "rec"):
        if leaf == "wo":                         # (F, D) / (W, D)
            return [r(-2)], [r(-1)]
        return [r(-1)], [r(-2)]                  # wi/wg/wx/wy/wa/wi_g/conv_w
    if parent == "mamba":
        if leaf in ("x_proj", "out_proj", "A_log"):   # (d_inner, ·)
            return [r(-2)], [r(-1)]
        return [r(-1)], [r(-2)]                  # in_proj/conv_w/dt_proj
    # unknown leaf: prefer the largest dims
    order = sorted(range(nd), key=lambda d: -shape[d])
    return order, list(order)


def param_spec(cfg: ModelConfig, mesh, path: Sequence[Any],
               shape: Sequence[int]) -> P:
    """PartitionSpec for one parameter/optimizer leaf.

    ``path`` is the pytree key path (or a tuple of names like
    ``("embed", "tok")``); rules key on the trailing two names so the same
    spec serves params, grads and Adam moments.
    """
    names = _path_names(path)
    shape = tuple(shape)
    sizes = mesh_axis_sizes(mesh)
    entries: List[Any] = [None] * len(shape)
    used: set = set()
    tp_dims, dp_dims = _param_dim_prefs(cfg, names, shape)
    if MODEL_AXIS in sizes and tp_dims:
        _assign(entries, used, shape, tp_dims, [(MODEL_AXIS,)], sizes)
    if cfg.sharding_profile == "fsdp" and dp_dims:
        _assign(entries, used, shape, dp_dims, data_axis_groups(mesh), sizes)
    return P(*entries)


def state_shardings(cfg: ModelConfig, mesh, specs):
    """NamedShardings for a params / train-state / opt-state pytree.

    ``specs`` is any pytree of arrays or ShapeDtypeStructs (e.g. the output
    of ``train_state_specs`` or ``jax.eval_shape(init_params)``).
    """
    def one(path, x):
        return NamedSharding(
            mesh, param_spec(cfg, mesh, _path_names(path), tuple(x.shape)))
    return jax.tree_util.tree_map_with_path(one, specs)


# ---------------------------------------------------------------- batches

def _batch_spec(mesh, shape: Tuple[int, ...],
                sizes: Optional[Dict[str, int]] = None) -> P:
    """Batch dim 0 over the widest divisible data-axis group; rest whole."""
    sizes = sizes or mesh_axis_sizes(mesh)
    entries: List[Any] = [None] * len(shape)
    if shape:
        _assign(entries, set(), shape, [0], data_axis_groups(mesh), sizes)
    return P(*entries)


def batch_shardings(cfg: ModelConfig, mesh, specs, kind: str = "train"):
    """NamedShardings for a model-input pytree (tokens/labels/positions/...).

    All input leaves are batch-major, so every leaf gets its batch dim
    sharded over the data axes when divisible (long_500k's batch of 1 stays
    replicated).  ``kind`` ("train" | "prefill" | "decode" | "serve") is
    accepted for future kind-specific layouts (e.g. sequence sharding).
    """
    del kind
    sizes = mesh_axis_sizes(mesh)

    def one(x):
        return NamedSharding(mesh, _batch_spec(mesh, tuple(x.shape), sizes))
    return jax.tree.map(one, specs)


# ---------------------------------------------------------------- caches

def cache_shardings(cfg: ModelConfig, mesh, specs):
    """NamedShardings for a decode-cache pytree (``repro.serve.cache_specs``).

    kv caches shard batch over the data axes and kv-heads over ``model``
    (falling back to head_dim when num_kv_heads is indivisible — GQA
    configs have few kv heads); recurrent/SSM states shard batch and the
    channel dim.  ``pos`` rings are replicated.
    """
    sizes = mesh_axis_sizes(mesh)

    def one(path, x):
        names = _path_names(path)
        leaf = names[-1]
        shape = tuple(x.shape)
        nd = len(shape)
        if leaf in ("k", "v", "xk", "xv") and nd >= 4:
            batch_dim, tp_dims = nd - 4, [nd - 2, nd - 1]
        elif leaf == "h" and cfg.ssm_state and nd >= 3:
            batch_dim, tp_dims = nd - 3, [nd - 2, nd - 1]   # (B, d_inner, n)
        elif leaf == "h" and not cfg.ssm_state and nd >= 2:
            batch_dim, tp_dims = nd - 2, [nd - 1]           # (B, lru_width)
        elif leaf == "conv" and nd >= 3:
            batch_dim, tp_dims = nd - 3, [nd - 1]           # (B, cw-1, C)
        else:
            return NamedSharding(mesh, P())
        entries: List[Any] = [None] * nd
        used: set = set()
        if MODEL_AXIS in sizes:
            _assign(entries, used, shape, tp_dims, [(MODEL_AXIS,)], sizes)
        _assign(entries, used, shape, [batch_dim], data_axis_groups(mesh),
                sizes)
        return NamedSharding(mesh, P(*entries))
    return jax.tree_util.tree_map_with_path(one, specs)


# ---------------------------------------------------- in-graph constraints

def _constrain(x, mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(cfg: ModelConfig, mesh, x, kind: str = "train"):
    """Constrain an activation (batch-major) to the data-parallel layout.

    Identity when ``mesh`` is None (single-device tests).  Divisibility is
    re-derived from the traced shape, so microbatched slices (B // nmb)
    resolve their own fallback.
    """
    del kind
    if mesh is None:
        return x
    return _constrain(x, mesh, _batch_spec(mesh, tuple(x.shape)))


def constrain_logits(cfg: ModelConfig, mesh, logits):
    """Constrain (..., V) logits: batch over data axes, vocab over model.

    The vocab dim falls back to replicated when V is indivisible
    (minicpm-2b's 122753).
    """
    if mesh is None:
        return logits
    shape = tuple(logits.shape)
    sizes = mesh_axis_sizes(mesh)
    entries: List[Any] = [None] * len(shape)
    used: set = set()
    if len(shape) >= 2 and MODEL_AXIS in sizes:
        _assign(entries, used, shape, [len(shape) - 1], [(MODEL_AXIS,)],
                sizes)
    _assign(entries, used, shape, [0], data_axis_groups(mesh), sizes)
    return _constrain(logits, mesh, P(*entries))


def constrain_like_params(cfg: ModelConfig, mesh, tree):
    """Constrain a params-shaped pytree (gradients) to the param layout."""
    if mesh is None:
        return tree

    def one(path, g):
        return _constrain(
            g, mesh, param_spec(cfg, mesh, _path_names(path), tuple(g.shape)))
    return jax.tree_util.tree_map_with_path(one, tree)
