"""Mesh-slot topology: the pilot's slots as device submeshes.

The paper's pilot holds N cores and a task occupies ``slots`` of them.  At
fleet scale the pilot holds a device *mesh* and a slot is a fixed block of
devices — e.g. one pod of the 2x16x16 multi-pod mesh, so each
replica-exchange member is itself a 256-chip SPMD program.  ``SlotTopology``
carves the mesh's device array into equal slots; ``PilotRuntime`` acquires
and releases slot ids, and a task builds a ``jax.sharding.Mesh`` over its
slots via :meth:`SlotTopology.submesh`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class SlotTopology:
    """Partition of a device array into equal pilot slots.

    ``devices``: array with leading dim = number of slots; ``axis_names``:
    mesh axes of ONE slot (matching ``devices.shape[1:]``).
    """
    devices: Any
    axis_names: Tuple[str, ...]

    def __post_init__(self):
        object.__setattr__(self, "devices", np.asarray(self.devices))
        if self.devices.ndim - 1 != len(self.axis_names):
            raise ValueError(
                f"slot shape {self.devices.shape[1:]} does not match "
                f"axis names {self.axis_names}")

    # ------------------------------------------------------------ builders
    @classmethod
    def from_mesh(cls, mesh, slot_axis: str | None = None) -> "SlotTopology":
        """One slot per index of ``slot_axis`` (default: outermost axis).

        ``from_mesh(pod_mesh)`` on the ("pod", "data", "model") mesh yields
        2 slots of shape ("data", "model") — one pod per slot.
        """
        names = tuple(mesh.axis_names)
        slot_axis = slot_axis or names[0]
        i = names.index(slot_axis)
        dev = np.moveaxis(np.asarray(mesh.devices), i, 0)
        return cls(devices=dev, axis_names=names[:i] + names[i + 1:])

    @classmethod
    def even(cls, devices: Sequence[Any], n_slots: int,
             axis_names: Tuple[str, ...] = ("model",)) -> "SlotTopology":
        """Split a flat device list into ``n_slots`` equal 1-axis slots."""
        arr = np.asarray(devices)
        if n_slots <= 0 or arr.size % n_slots:
            raise ValueError(f"{arr.size} devices not divisible into "
                             f"{n_slots} slots")
        return cls(devices=arr.reshape(n_slots, arr.size // n_slots),
                   axis_names=axis_names)

    def recarve(self, n_slots: int) -> "SlotTopology":
        """Re-carve into ``n_slots`` finer slots by splitting the leading
        slot axis (e.g. 2 pods of ("data", "model") 16x16 -> 4 half-pods of
        8x16).  Grow-only: ``n_slots`` must be a multiple of the current
        slot count and the split must divide the first slot axis evenly.
        """
        cur = self.n_slots
        if n_slots == cur:
            return self
        if n_slots < cur or n_slots % cur:
            raise ValueError(f"cannot re-carve {cur} slots into {n_slots}: "
                             "grow-only, must be an integer multiple")
        factor = n_slots // cur
        if self.devices.ndim < 2 or self.devices.shape[1] % factor:
            raise ValueError(
                f"cannot split slot axis {self.axis_names[:1]} of shape "
                f"{self.devices.shape[1:]} into {factor} parts")
        shape = self.devices.shape
        dev = self.devices.reshape(cur * factor, shape[1] // factor,
                                   *shape[2:])
        return SlotTopology(devices=dev, axis_names=self.axis_names)

    def drop(self, slot_ids: Sequence[int]) -> "SlotTopology":
        """Shrink-recarve: a new topology WITHOUT the given slots (pod
        loss — the dead pod's devices leave the fleet).  Slot ids
        renumber compactly, so the runtime applies this only at a
        quiescent point (no task holds a slot id) and replica locality
        keyed on the old pod names is reset by the caller.
        """
        dead = {int(i) for i in slot_ids}
        if not dead:
            return self
        bad = [i for i in dead if i < 0 or i >= self.n_slots]
        if bad:
            raise ValueError(f"slot ids {sorted(bad)} out of range "
                             f"0..{self.n_slots - 1}")
        keep = [i for i in range(self.n_slots) if i not in dead]
        if not keep:
            raise ValueError("cannot drop every slot of the topology")
        return SlotTopology(devices=self.devices[np.asarray(keep)],
                            axis_names=self.axis_names)

    # ------------------------------------------------------------ queries
    def reachable_slot_counts(self) -> list:
        """Every slot count some chain of grow-only :meth:`recarve` calls
        can reach from here: ``n_slots * f`` for each ``f`` dividing the
        first slot axis (splitting is single-axis, so composed recarves
        reach exactly the divisors).  Sorted ascending; the static
        validator (repro.analysis, E108/W202) uses this to decide whether
        a cores request can EVER be granted."""
        if self.devices.ndim < 2:
            return [self.n_slots]
        width = int(self.devices.shape[1])
        return sorted(self.n_slots * f for f in range(1, width + 1)
                      if width % f == 0)

    @property
    def n_slots(self) -> int:
        return int(self.devices.shape[0])

    @property
    def devices_per_slot(self) -> int:
        return int(np.prod(self.devices.shape[1:], dtype=np.int64))

    def slot_devices(self, slot_ids: Sequence[int]) -> np.ndarray:
        """(len(slot_ids), *slot_shape) device block, id-sorted."""
        ids = sorted(int(i) for i in slot_ids)
        if not ids:
            raise ValueError("empty slot id list")
        if ids[0] < 0 or ids[-1] >= self.n_slots:
            raise ValueError(f"slot ids {ids} out of range 0..{self.n_slots - 1}")
        return self.devices[np.asarray(ids)]

    def submesh(self, slot_ids: Sequence[int]):
        """Mesh over the devices of ``slot_ids``.

        One slot keeps the slot axes; several slots gain a leading "slot"
        axis (a wider data-parallel dim for multi-slot tasks).
        """
        from jax.sharding import Mesh
        block = self.slot_devices(slot_ids)
        if block.shape[0] == 1:
            return Mesh(block[0], self.axis_names)
        return Mesh(block, ("slot",) + tuple(self.axis_names))
