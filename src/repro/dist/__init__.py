"""Distribution layer: GSPMD partition specs and mesh-slot topology.

``repro.dist.sharding`` decides *how arrays are laid out* on a mesh
(params, optimizer state, batches, decode caches);
``repro.dist.topology`` decides *which devices a pilot slot owns*
(submesh carving for the ensemble executor).
"""
from repro.dist.sharding import (  # noqa: F401
    abstract_mesh,
    batch_shardings,
    cache_shardings,
    constrain_batch,
    constrain_like_params,
    constrain_logits,
    param_spec,
    state_shardings,
)
from repro.dist.topology import SlotTopology  # noqa: F401
