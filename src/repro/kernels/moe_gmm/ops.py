"""Public wrapper for the MoE grouped matmul with impl dispatch.

The XLA path is a plain batched matmul over the capacity layout (computes
padding rows — wasted FLOPs at low expert load).  The Pallas kernel skips
row-blocks past each group's size, recovering the padding waste on TPU.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro import flags
from repro.kernels.moe_gmm.ref import gmm_ref


@partial(jax.jit, static_argnames=("impl",))
def gmm(x, w, group_sizes, *, impl: Optional[str] = None):
    impl = flags.moe_impl(impl)
    if impl == "ref":
        return gmm_ref(x, w, group_sizes)
    if impl == "xla":
        return jnp.einsum("ecd,edf->ecf", x, w)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.moe_gmm.pallas_kernel import gmm_pallas
        return gmm_pallas(x, w, group_sizes,
                          interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown moe impl {impl!r}")
