"""Oracle for the capacity-layout grouped matmul (MoE expert FFN).

x: (E, C, D) expert-batched tokens (rows beyond group_sizes[e] are padding),
w: (E, D, F).  Returns (E, C, F) with padded rows zeroed.
"""
from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w, group_sizes):
    E, C, D = x.shape
    y = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w.astype(jnp.float32))
    valid = jnp.arange(C)[None, :] < group_sizes[:, None]
    return (y * valid[..., None]).astype(x.dtype)
