"""Pallas TPU grouped matmul for MoE expert FFNs (capacity layout).

Design: grid (E, C/block_c, F/block_f); ``group_sizes`` arrives via scalar
prefetch (SMEM) and row-blocks entirely past an expert's token count skip
their MXU work via ``pl.when`` — this recovers the padding FLOPs the plain
batched-matmul XLA path wastes at low expert load (the kernel-level win this
module exists for).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _gmm_kernel(sizes_ref, x_ref, w_ref, o_ref, *, block_c):
    e = pl.program_id(0)
    ic = pl.program_id(1)
    size = sizes_ref[e]
    live = ic * block_c < size

    @pl.when(live)
    def _compute():
        x = x_ref[0]                     # (block_c, D)
        w = w_ref[0]                     # (D, block_f)
        acc = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # zero padded rows inside a partially-filled block
        rows = ic * block_c + jax.lax.broadcasted_iota(
            jnp.int32, acc.shape, 0)
        acc = jnp.where(rows < size, acc, 0.0)
        o_ref[0] = acc.astype(o_ref.dtype)

    @pl.when(jnp.logical_not(live))
    def _skip():
        o_ref[0] = jnp.zeros_like(o_ref[0])


def gmm_pallas(x, w, group_sizes, *, block_c: int = 128, block_f: int = 128,
               interpret: bool = False):
    """x: (E, C, D); w: (E, D, F); group_sizes: (E,).  Returns (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    assert C % block_c == 0 and F % block_f == 0

    kernel = functools.partial(_gmm_kernel, block_c=block_c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(E, C // block_c, F // block_f),
        in_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, i, j, sz: (e, i, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, i, j, sz: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, sz: (e, i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(group_sizes.astype(jnp.int32), x, w)
