"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t h_{t-1} + x_t.

Design: grid (B, channel_blocks); each program holds its (T, block_c) tile of
x and a in VMEM and walks the time loop with the running state h in VREGs —
the recurrence is elementwise over channels (VPU work, no MXU), so the tile
is chosen lane-aligned (block_c multiple of 128).  Gate computation stays in
XLA (it is dense matmul work the MXU already handles well); the kernel owns
only the sequential hot loop that XLA would otherwise materialize as a long
unrolled chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import tpu_compiler_params


def _rglru_kernel(x_ref, a_ref, h0_ref, y_ref, hlast_ref, *, T):
    h = h0_ref[0].astype(jnp.float32)          # (block_c,)

    def body(t, h):
        h = a_ref[0, t].astype(jnp.float32) * h \
            + x_ref[0, t].astype(jnp.float32)
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, T, body, h)
    hlast_ref[0] = h.astype(hlast_ref.dtype)


def linear_scan_pallas(x, a, h0, *, block_c: int = 256,
                       interpret: bool = False):
    """x, a: (B, T, C); h0: (B, C).  Returns (y, h_last)."""
    B, T, C = x.shape
    block_c = min(block_c, C)
    assert C % block_c == 0, "channel dim must be block-aligned"
    nc = C // block_c

    kernel = functools.partial(_rglru_kernel, T=T)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, T, block_c), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, T, block_c), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, block_c), lambda b, c: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, block_c), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, block_c), lambda b, c: (b, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, C), x.dtype),
            jax.ShapeDtypeStruct((B, C), h0.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, a, h0)
    return y, h_last
