"""Public wrapper for the RG-LRU linear scan with impl dispatch."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro import flags
from repro.kernels.rglru.ref import linear_scan_ref
from repro.kernels.rglru.xla import linear_scan_xla


@partial(jax.jit, static_argnames=("impl", "chunk"))
def linear_scan(x, a, h0, *, impl: Optional[str] = None, chunk: int = 512):
    """h_t = a_t * h_{t-1} + x_t over axis 1.  x, a: (B,T,C); h0: (B,C)."""
    impl = flags.rglru_impl(impl)
    if impl == "ref":
        return linear_scan_ref(x, a, h0)
    if impl == "xla":
        return linear_scan_xla(x, a, h0, chunk=chunk)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.rglru.pallas_kernel import linear_scan_pallas
        return linear_scan_pallas(x, a, h0,
                                  interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown rglru impl {impl!r}")
