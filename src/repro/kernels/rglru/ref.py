"""Oracle for the RG-LRU linear recurrence: h_t = a_t * h_{t-1} + x_t.

All per-channel (diagonal) — shapes: x, a: (B, T, C); h0: (B, C).
Returns (y, h_last) with y[:, t] = h_t.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def linear_scan_ref(x, a, h0):
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        xt, at = inp
        h = at * h + xt
        return h, h

    h_last, ys = lax.scan(step, h0.astype(jnp.float32),
                          (xf.swapaxes(0, 1), af.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), h_last.astype(h0.dtype)
