"""XLA-native RG-LRU scan: time-chunked associative scan.

``lax.associative_scan`` over (a, b) pairs representing h -> a*h + b, chunked
over time so peak memory is O(B * chunk * C) regardless of T.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _assoc(l, r):
    al, bl = l
    ar, br = r
    return al * ar, ar * bl + br


def linear_scan_xla(x, a, h0, *, chunk: int = 512):
    B, T, C = x.shape
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    chunk = min(chunk, T)
    if T % chunk:
        from repro.kernels.rglru.ref import linear_scan_ref
        return linear_scan_ref(x, a, h0)
    n = T // chunk

    def do_chunk(h, inp):
        xc, ac = inp                      # (B, chunk, C)
        A, Bc = lax.associative_scan(_assoc, (ac, xc), axis=1)
        hs = A * h[:, None, :] + Bc       # (B, chunk, C)
        return hs[:, -1, :], hs

    xs = xf.reshape(B, n, chunk, C).swapaxes(0, 1)
    as_ = af.reshape(B, n, chunk, C).swapaxes(0, 1)
    # checkpoint: recompute chunk prefixes in the backward (no stacked
    # (n, B, chunk, C) residuals in HBM)
    h_last, ys = lax.scan(jax.checkpoint(do_chunk),
                          h0.astype(jnp.float32), (xs, as_))
    y = ys.swapaxes(0, 1).reshape(B, T, C)
    return y.astype(x.dtype), h_last.astype(h0.dtype)
