"""Pallas TPU flash attention (forward).

TPU-native design (not a CUDA port):
  * grid = (B*KH, num_q_blocks, num_kv_blocks) with the kv dimension
    innermost and "arbitrary" so the online-softmax scratch accumulators
    (VMEM-resident) persist across kv steps — the TPU idiom replacing the
    CUDA shared-memory loop;
  * GQA folded into the q block: the (G, block_q) rows of one kv-head group
    form a single (G*block_q, head_dim) MXU operand, so q-heads sharing a
    kv head share the k/v VMEM tiles;
  * block sizes default to 128 (MXU-aligned); causal / sliding-window masks
    are applied with 2-D iotas, and fully-masked kv blocks are skipped with
    ``pl.when`` (grid-level pruning is done by the XLA path at trace time;
    here predication skips the MXU work).

Validated in interpret mode on CPU against ref.py; used as the hot path on
real TPU (``--attn_impl=pallas``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, causal, window, softcap, q_offset, block_q, block_kv,
                 nkv, G):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = G * block_q
    # positions: row r of the folded block is q position (r % block_q)
    rpos = q_offset + iq * block_q + \
        jax.lax.broadcasted_iota(jnp.int32, (rows, block_kv), 0) % block_q
    cpos = ik * block_kv + \
        jax.lax.broadcasted_iota(jnp.int32, (rows, block_kv), 1)
    mask = jnp.ones((rows, block_kv), dtype=jnp.bool_)
    if causal:
        mask = mask & (cpos <= rpos)
    if window:
        mask = mask & (rpos - cpos < window)

    # skip fully-masked kv blocks (block-level predication)
    q_lo = q_offset + iq * block_q
    q_hi = q_lo + block_q - 1
    kv_lo = ik * block_kv
    kv_hi = kv_lo + block_kv - 1
    live = jnp.bool_(True)
    if causal:
        live = live & (kv_lo <= q_hi)
    if window:
        live = live & (kv_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].reshape(rows, q_ref.shape[-1])      # (G*Bq, D)
        k = k_ref[0]                                     # (Bkv, D)
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                 # (Bkv, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = out.reshape(G, block_q, o_ref.shape[-1]) \
            .astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0,
                           scale: Optional[float] = None, q_offset: int = 0,
                           seg_q=None, seg_kv=None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D).  Returns (B, Sq, H, D)."""
    if seg_q is not None:
        raise NotImplementedError("segment ids: use the xla path")
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    assert Sq % block_q == 0 and Sk % block_kv == 0, \
        "pallas path needs block-aligned sequence lengths"
    nq, nkv = Sq // block_q, Sk // block_kv

    # (B, Sq, KH, G, D) -> (B, KH, G, Sq, D); k/v -> (B, KH, Sk, D)
    qr = q.reshape(B, Sq, KH, G, D).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)
    qf = qr.reshape(B * KH, G, Sq, D)
    kf = kr.reshape(B * KH, Sk, D)
    vf = vr.reshape(B * KH, Sk, D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, block_q=block_q,
        block_kv=block_kv, nkv=nkv, G=G)

    rows = G * block_q
    out = pl.pallas_call(
        kernel,
        grid=(B * KH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, G, block_q, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, D),
                               lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KH, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # m
            pltpu.VMEM((rows, 1), jnp.float32),   # l
            pltpu.VMEM((rows, D), jnp.float32),   # acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)

    # (B*KH, G, Sq, D) -> (B, Sq, H, D)
    return out.reshape(B, KH, G, Sq, D).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, H, D)
