"""XLA-native chunked flash attention (production path on the CPU stand-in
backend and the implementation the dry-run lowers).

Online-softmax over kv chunks with a *statically pruned* chunk range per q
chunk: causal and sliding-window layers only visit the kv chunks that can
contain unmasked entries, so HLO FLOPs match the algorithmic FLOPs (this is
what keeps the roofline compute term honest).  The q-chunk loop is a Python
loop (static), the kv-chunk loop is a ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _chunk_attn(qf, ks, vs, koffs, *, causal, window, softcap, q_offset,
                qi, q_chunk, seg_q=None, seg_kvs=None, qf_dtype=None):
    """Online softmax over the stacked kv chunks ``ks``/``vs``.

    qf: (B, Cq, KH, G, D) fp32, pre-scaled.
    ks/vs: (nk, B, Ck, KH, D); koffs: (nk,) chunk start positions.
    Returns (B, Cq, KH, G, D) fp32 (unnormalized handled internally).
    """
    B, Cq, KH, G, D = qf.shape
    qf_dtype = qf_dtype or ks.dtype
    Ck = ks.shape[2]
    qpos = q_offset + qi * q_chunk + jnp.arange(Cq)

    def step(carry, inp):
        m, l, acc = carry
        if seg_kvs is not None:
            kc, vc, koff, seg_kv = inp
        else:
            kc, vc, koff = inp
            seg_kv = None
        # scores: (B, KH, G, Cq, Ck)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf.astype(kc.dtype), kc,
                       preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = koff + jnp.arange(Ck)
        mask = jnp.ones((Cq, Ck), dtype=bool)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window:
            mask = mask & (qpos[:, None] - kpos[None, :] < window)
        mask = mask[None, None, None]
        if seg_q is not None:
            segm = seg_q[:, :, None] == seg_kv[:, None, :]
            mask = mask & segm[:, None, None]
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # probabilities participate in the pv matmul at the input dtype
        # (bf16 for bf16 models): halves the p-tensor traffic at fusion
        # boundaries; accumulation stays f32
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qf_dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Cq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Cq, D), jnp.float32)
    xs = (ks, vs, koffs) if seg_kvs is None else (ks, vs, koffs, seg_kvs)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 3, 1, 2, 4))  # (B, Cq, KH, G, D)


def attention_xla(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: Optional[float] = None,
                  q_offset: int = 0, seg_q=None, seg_kv=None,
                  q_chunk: int = 512, kv_chunk: int = 512):
    """Chunked attention.  Layout: q (B,Sq,H,D), k/v (B,Sk,KH,D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = scale if scale is not None else D ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    if Sq % q_chunk or Sk % kv_chunk:
        from repro.kernels.flash_attention.ref import attention_ref
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, q_offset=q_offset,
                             seg_q=seg_q, seg_kv=seg_kv)

    nq, nk = Sq // q_chunk, Sk // kv_chunk
    k_ch = k.reshape(B, nk, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(B, nk, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    seg_kv_ch = (seg_kv.reshape(B, nk, kv_chunk).transpose(1, 0, 2)
                 if seg_kv is not None else None)
    koffs = jnp.arange(nk) * kv_chunk

    outs = []
    for qi in range(nq):
        qc = q[:, qi * q_chunk:(qi + 1) * q_chunk]
        qf = (qc.astype(jnp.float32) * scale).reshape(B, q_chunk, KH, G, D)
        sq = (seg_q[:, qi * q_chunk:(qi + 1) * q_chunk]
              if seg_q is not None else None)
        # static kv-chunk range pruning
        q_lo = q_offset + qi * q_chunk
        q_hi = q_lo + q_chunk - 1
        hi = min(nk, math.floor(q_hi / kv_chunk) + 1) if causal else nk
        lo = max(0, math.floor((q_lo - window + 1) / kv_chunk)) if window else 0
        hi = max(hi, lo + 1)
        # checkpoint: recompute the online-softmax in the backward pass
        # instead of saving per-(q,kv)-chunk probability residuals
        # (flash-attention-style backward on the XLA path)
        attn_fn = jax.checkpoint(
            lambda qf_, ks_, vs_, ko_, sq_, skv_: _chunk_attn(
                qf_, ks_, vs_, ko_, causal=causal, window=window,
                softcap=softcap, q_offset=q_offset, qi=qi,
                q_chunk=q_chunk, seg_q=sq_, seg_kvs=skv_),
            static_argnums=())
        o = attn_fn(qf, k_ch[lo:hi], v_ch[lo:hi], koffs[lo:hi], sq,
                    seg_kv_ch[lo:hi] if seg_kv_ch is not None else None)
        outs.append(o.reshape(B, q_chunk, H, D))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)
