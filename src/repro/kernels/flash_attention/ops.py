"""jit'd public wrapper for flash attention with impl dispatch.

impl:
  "xla"              - chunked online-softmax in pure jnp (CPU + dry-run path)
  "ref"              - naive oracle (tests only; O(Sq*Sk) memory)
  "pallas"           - Pallas TPU kernel (real-hardware hot path)
  "pallas_interpret" - Pallas kernel body interpreted on CPU (validation)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro import flags
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.xla import attention_xla


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "q_offset", "impl", "q_chunk", "kv_chunk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    q_offset: int = 0, seg_q=None, seg_kv=None,
                    impl: Optional[str] = None,
                    q_chunk: int = 512, kv_chunk: int = 512):
    impl = flags.attn_impl(impl)
    kw = dict(causal=causal, window=window, softcap=softcap, scale=scale,
              q_offset=q_offset, seg_q=seg_q, seg_kv=seg_kv)
    if impl == "ref":
        return attention_ref(q, k, v, **kw)
    if impl == "xla":
        return attention_xla(q, k, v, q_chunk=q_chunk, kv_chunk=kv_chunk, **kw)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention.pallas_kernel import flash_attention_pallas
        return flash_attention_pallas(q, k, v, interpret=(impl == "pallas_interpret"),
                                      **kw)
    raise ValueError(f"unknown attention impl {impl!r}")
