"""Pure-jnp oracle for flash attention (naive materialized softmax).

Shapes (GQA layout):
  q: (B, Sq, H, D)    with H = KH * G
  k: (B, Sk, KH, D)
  v: (B, Sk, KH, D)
Returns (B, Sq, H, D).

Masking: causal (q position i attends to kv position j <= i), optional
sliding window (i - j < window), optional segment ids (block-diagonal
packing), optional tanh logit softcap.  ``q_offset`` places the q block at
absolute positions offset..offset+Sq-1 against kv positions 0..Sk-1.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: Optional[float] = None,
                  q_offset: int = 0, seg_q=None, seg_kv=None):
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32).reshape(B, Sq, KH, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # scores: (B, KH, G, Sq, Sk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = q_offset + jnp.arange(Sq)[:, None]      # (Sq, 1)
    kpos = jnp.arange(Sk)[None, :]                 # (1, Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (qpos - kpos < window)
    mask = mask[None, None, None]
    if seg_q is not None:
        segm = seg_q[:, :, None] == seg_kv[:, None, :]   # (B, Sq, Sk)
        mask = mask & segm[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)

    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)         # fully-masked rows
    e = jnp.exp(s - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)
