"""Version portability for Pallas-TPU compiler params.

The TPU compiler-params dataclass was renamed ``TPUCompilerParams`` ->
``CompilerParams`` across JAX releases; kernels call this shim so the same
source runs on either (the container pins jax 0.4.x, production may not).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` under whichever name exists."""
    return _CLS(**kwargs)
