"""Oracle for the Mamba-1 selective SSM scan (sequential, materializes
nothing beyond the running state).

  h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t
  y_t = C_t . h_t + D * x_t

Shapes: x, dt: (B, T, d);  A: (d, n);  Bm, C: (B, T, n);  D: (d,);
h0: (B, d, n).  Returns y: (B, T, d) and h_last: (B, d, n).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def selective_scan_ref(x, dt, A, Bm, C, D, h0):
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,d) (B,d) (B,n) (B,n)
        da = jnp.exp(dtt[..., None] * Af[None])     # (B, d, n)
        db = (dtt * xt)[..., None] * bt[:, None, :] # (B, d, n)
        h = da * h + db
        y = jnp.einsum("bdn,bn->bd", h, ct) + Df[None] * xt
        return h, y

    inps = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
            Bf.swapaxes(0, 1), Cf.swapaxes(0, 1))
    h_last, ys = lax.scan(step, h0.astype(jnp.float32), inps)
    return ys.swapaxes(0, 1).astype(x.dtype), h_last.astype(h0.dtype)
