"""Public wrapper for the Mamba selective scan with impl dispatch."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro import flags
from repro.kernels.mamba.ref import selective_scan_ref
from repro.kernels.mamba.xla import selective_scan_xla, selective_step_xla


@partial(jax.jit, static_argnames=("impl", "chunk"))
def selective_scan(x, dt, A, Bm, C, D, h0, *, impl: Optional[str] = None,
                   chunk: int = 256):
    impl = flags.mamba_impl(impl)
    if impl == "ref":
        return selective_scan_ref(x, dt, A, Bm, C, D, h0)
    if impl == "xla":
        return selective_scan_xla(x, dt, A, Bm, C, D, h0, chunk=chunk)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.mamba.pallas_kernel import selective_scan_pallas
        return selective_scan_pallas(x, dt, A, Bm, C, D, h0,
                                     interpret=(impl == "pallas_interpret"))
    raise ValueError(f"unknown mamba impl {impl!r}")


selective_step = jax.jit(selective_step_xla)
