"""XLA-native selective scan: time-chunked associative scan.

The (B, T, d, n) da/db tensors are never materialized for the full T — only
per chunk — bounding peak memory at O(B * chunk * d * n) (the same insight as
the CUDA mamba kernel's SRAM blocking, restated for XLA/HBM).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def _assoc(l, r):
    al, bl = l
    ar, br = r
    return al * ar, ar * bl + br


def selective_scan_xla(x, dt, A, Bm, C, D, h0, *, chunk: int = 256):
    B, T, d = x.shape
    n = A.shape[1]
    chunk = min(chunk, T)
    if T % chunk:
        from repro.kernels.mamba.ref import selective_scan_ref
        return selective_scan_ref(x, dt, A, Bm, C, D, h0)
    nc = T // chunk

    xf = x.astype(jnp.float32).reshape(B, nc, chunk, d).swapaxes(0, 1)
    dtf = dt.astype(jnp.float32).reshape(B, nc, chunk, d).swapaxes(0, 1)
    Bf = Bm.astype(jnp.float32).reshape(B, nc, chunk, n).swapaxes(0, 1)
    Cf = C.astype(jnp.float32).reshape(B, nc, chunk, n).swapaxes(0, 1)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    def do_chunk(h, inp):
        xc, dtc, bc, cc = inp                              # (B,Tc,*) each
        da = jnp.exp(dtc[..., None] * Af[None, None])      # (B,Tc,d,n)
        db = (dtc * xc)[..., None] * bc[:, :, None, :]     # (B,Tc,d,n)
        Ap, Bp = lax.associative_scan(_assoc, (da, db), axis=1)
        hs = Ap * h[:, None] + Bp                          # (B,Tc,d,n)
        y = jnp.einsum("btdn,btn->btd", hs, cc) + Df[None, None] * xc
        return hs[:, -1], y

    # NOTE: an inner jax.checkpoint(do_chunk) was measured (dry-run HLO
    # accounting) to cost slightly MORE traffic than it saves once the block
    # level remat already recomputes the scan — hypothesis refuted, see
    # EXPERIMENTS.md §Perf falcon/step 3.
    h_last, ys = lax.scan(do_chunk, h0.astype(jnp.float32),
                          (xf, dtf, Bf, Cf))
    y = ys.swapaxes(0, 1).reshape(B, T, d)
    return y.astype(x.dtype), h_last.astype(h0.dtype)


def selective_step_xla(x, dt, A, Bm, C, D, h0):
    """Single-token decode step.  x, dt: (B, d); Bm, C: (B, n)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = jnp.exp(dtf[..., None] * A.astype(jnp.float32)[None])
    db = (dtf * xf)[..., None] * Bm.astype(jnp.float32)[:, None, :]
    h = da * h0.astype(jnp.float32) + db
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None] * xf
    return y.astype(x.dtype), h.astype(h0.dtype)
