"""Pallas TPU kernel for the Mamba-1 selective scan.

Design (the CUDA kernel's SRAM blocking, rethought for VMEM):
  grid (B, channel_blocks); the (block_d, N) state lives in VMEM/VREGs across
  the whole time loop; per step the kernel forms dA/dB on the fly from the
  (T, block_d) dt/x tiles and the shared (T, N) B/C tiles — the (B, T, d, N)
  tensors the naive formulation materializes in HBM never exist.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels.pallas_compat import tpu_compiler_params


def _mamba_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
                  y_ref, hlast_ref, *, T):
    A = A_ref[...].astype(jnp.float32)            # (block_d, N)
    Dw = D_ref[...].astype(jnp.float32)           # (block_d,)
    h = h0_ref[0].astype(jnp.float32)             # (block_d, N)

    def body(t, h):
        xt = x_ref[0, t].astype(jnp.float32)      # (block_d,)
        dtt = dt_ref[0, t].astype(jnp.float32)    # (block_d,)
        bt = B_ref[0, t].astype(jnp.float32)      # (N,)
        ct = C_ref[0, t].astype(jnp.float32)      # (N,)
        da = jnp.exp(dtt[:, None] * A)            # (block_d, N)
        db = (dtt * xt)[:, None] * bt[None, :]
        h = da * h + db
        y = jnp.sum(h * ct[None, :], axis=1) + Dw * xt
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, T, body, h)
    hlast_ref[0] = h.astype(hlast_ref.dtype)


def selective_scan_pallas(x, dt, A, Bm, C, D, h0, *, block_d: int = 256,
                          interpret: bool = False):
    """x, dt: (B,T,d); A: (d,n); Bm, C: (B,T,n); D: (d,); h0: (B,d,n)."""
    B, T, d = x.shape
    n = A.shape[1]
    block_d = min(block_d, d)
    assert d % block_d == 0, "channel dim must be block-aligned"
    nd = d // block_d

    kernel = functools.partial(_mamba_kernel, T=T)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, nd),
        in_specs=[
            pl.BlockSpec((1, T, block_d), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, T, block_d), lambda b, c: (b, 0, c)),
            pl.BlockSpec((block_d, n), lambda b, c: (c, 0)),
            pl.BlockSpec((1, T, n), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, T, n), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((block_d,), lambda b, c: (c,)),
            pl.BlockSpec((1, block_d, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, block_d), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, block_d, n), lambda b, c: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, d), x.dtype),
            jax.ShapeDtypeStruct((B, d, n), h0.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, dt, A, Bm, C, D, h0)
    return y, h_last
