from repro.train.losses import chunked_softmax_xent  # noqa: F401
from repro.train.step import (  # noqa: F401
    TrainHyper,
    build_eval_step,
    build_train_step,
    make_train_state,
    train_state_specs,
)
