"""Fused LM-head + softmax cross-entropy, chunked over the sequence.

The (B, S, V) logits tensor is never materialized: logits are computed per
seq-chunk in float32 from the final hidden states and reduced immediately.
With the vocab-parallel embedding (V sharded over "model") the per-chunk
logits stay sharded and the reductions are small GSPMD all-reduces.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain_batch, constrain_logits


def _head_weight(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"]["tok"], True      # (V, D), transpose at use
    return params["head"], False                 # (D, V)


def chunked_softmax_xent(cfg: ModelConfig, params, h, labels, *, mesh=None
                         ) -> Tuple[jax.Array, jax.Array]:
    """h: (B, S, D) final-normed; labels: (B, S) (-1 = masked).
    Returns (mean nll, token count)."""
    B, S, D = h.shape
    V = cfg.vocab_size
    w, transpose = _head_weight(cfg, params)
    wf = w.astype(jnp.float32)
    if mesh is not None:
        # vocab-parallel loss needs "model" free: reshard batch from the
        # (possibly fsdp-flat) training layout to ("pod","data") once, in
        # bf16, before the chunk scan.
        h = constrain_batch(cfg, mesh, h, "train")
        labels = constrain_batch(cfg, mesh, labels, "train")
    chunk = cfg.loss_chunk if (cfg.loss_chunk and S % cfg.loss_chunk == 0) \
        else S
    nc = S // chunk
    hs = h.reshape(B, nc, chunk, D).swapaxes(0, 1)      # (nc, B, c, D)
    ls = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    vocab_ids = jnp.arange(V, dtype=jnp.int32)

    def body(carry, xs):
        tot, cnt = carry
        hc, lc = xs
        logits = hc.astype(jnp.float32) @ (wf.T if transpose else wf)
        logits = constrain_logits(cfg, mesh, logits)
        if cfg.final_softcap:
            logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
        m = jnp.max(logits, axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        eq = lc[..., None] == vocab_ids[None, None, :]
        corr = jnp.sum(jnp.where(eq, logits, 0.0), axis=-1)
        mask = (lc >= 0).astype(jnp.float32)
        nll = (lse - corr) * mask
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    # checkpoint: recompute per-chunk logits in the backward pass instead of
    # saving (nc, B, c, V) residuals (flash-style fused head+loss)
    (tot, cnt), _ = lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls))
    return tot / jnp.maximum(cnt, 1.0), cnt
