"""Train-state + train-step builder: remat, gradient-accumulation
microbatching (lax.scan), global-norm clipping, AdamW, LR schedules.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import forward, init_params
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         make_schedule)
from repro.train.losses import chunked_softmax_xent

TrainState = Dict[str, Any]


@dataclass(frozen=True)
class TrainHyper:
    base_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"
    wd: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    clip: float = 1.0
    aux_weight: float = 0.01


def make_train_state(cfg: ModelConfig, key) -> TrainState:
    params = init_params(cfg, key)
    return {"params": params,
            "opt": adamw_init(params, cfg.optstate_dtype),
            "step": jnp.zeros((), jnp.int32)}


def train_state_specs(cfg: ModelConfig, key=None):
    """ShapeDtypeStructs of the train state (no allocation)."""
    k = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: make_train_state(cfg, k))


def compute_cast(cfg: ModelConfig, params):
    """Cast large matmul weights to the compute dtype on their *sharded*
    storage, so FSDP all-gathers move bf16 instead of f32 master bytes
    (halves gather traffic; EXPERIMENTS.md §Perf grok/step 1).  Small and
    1-D leaves (norms, gates, A_log, dt_bias) stay in master precision.
    MoE subtrees are excluded: converting params feeding the expert
    shard_map trips an XLA SPMD-partitioner CHECK ("invalid binary
    instruction opcode copy"); experts are cast inside the shard_map."""
    if cfg.dtype != "bfloat16":
        return params

    def one(path, p):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        if "moe" in names:
            return p
        if (p.ndim >= 2 and p.size > 1_000_000
                and p.dtype == jnp.float32):
            return p.astype(jnp.bfloat16)
        return p

    return jax.tree_util.tree_map_with_path(one, params)


def build_train_step(cfg: ModelConfig, mesh=None,
                     hyper: TrainHyper = TrainHyper()):
    sched = make_schedule(hyper.schedule, base_lr=hyper.base_lr,
                          warmup=hyper.warmup, total_steps=hyper.total_steps)
    remat = cfg.remat != "none"

    def loss_fn(params, mb):
        params = compute_cast(cfg, params)
        out = forward(cfg, params, mb["tokens"],
                      seg_ids=mb.get("seg_ids"),
                      vision_embeds=mb.get("vision_embeds"),
                      enc_frames=mb.get("enc_frames"),
                      mesh=mesh, remat=remat)
        loss, ntok = chunked_softmax_xent(cfg, params, out["h"],
                                          mb["labels"], mesh=mesh)
        total = loss + hyper.aux_weight * out["aux"]
        return total, {"loss": loss, "aux": out["aux"], "ntok": ntok}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    from repro.dist.sharding import constrain_like_params

    def train_step(state: TrainState, batch) -> tuple:
        params = state["params"]
        nmb = cfg.microbatches
        if nmb > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]),
                batch)

            def body(acc, mb):
                (l, aux), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   {"g": g, "loss": l, "aux": aux["aux"]})
                return acc, None

            zero = {"g": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params),
                    "loss": jnp.zeros((), jnp.float32),
                    "aux": jnp.zeros((), jnp.float32)}
            acc, _ = lax.scan(body, zero, mbs)
            grads = constrain_like_params(
                cfg, mesh, jax.tree.map(lambda g: g / nmb, acc["g"]))
            loss = acc["loss"] / nmb
            auxl = acc["aux"] / nmb
        else:
            (loss, auxd), grads = grad_fn(params, batch)
            grads = constrain_like_params(cfg, mesh, grads)
            auxl = auxd["aux"]

        grads, gnorm = clip_by_global_norm(grads, hyper.clip)
        lr = sched(state["step"])
        new_params, new_opt = adamw_update(
            grads, state["opt"], params, lr=lr, b1=hyper.b1, b2=hyper.b2,
            wd=hyper.wd)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "aux": auxl, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def build_eval_step(cfg: ModelConfig, mesh=None):
    def eval_step(params, batch):
        out = forward(cfg, params, batch["tokens"],
                      seg_ids=batch.get("seg_ids"),
                      vision_embeds=batch.get("vision_embeds"),
                      enc_frames=batch.get("enc_frames"),
                      mesh=mesh, remat=False)
        loss, ntok = chunked_softmax_xent(cfg, params, out["h"],
                                          batch["labels"], mesh=mesh)
        return {"loss": loss, "ntok": ntok}
    return eval_step
