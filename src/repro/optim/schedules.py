"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int):
    return jnp.minimum(1.0, (step + 1) / max(warmup, 1))


def cosine(step, *, base_lr: float, warmup: int, total_steps: int,
           min_ratio: float = 0.1):
    w = linear_warmup(step, warmup)
    t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
    c = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * w * c


def wsd(step, *, base_lr: float, warmup: int, total_steps: int,
        decay_frac: float = 0.1, min_ratio: float = 0.1):
    """Warmup-Stable-Decay [arXiv:2404.06395]: warmup, long flat stable
    phase, short (default 10%) exponential-ish decay to min_ratio."""
    w = linear_warmup(step, warmup)
    decay_steps = max(int(total_steps * decay_frac), 1)
    decay_start = total_steps - decay_steps
    t = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
    d = jnp.where(step < decay_start, 1.0, min_ratio ** t)
    return base_lr * w * d


def make_schedule(name: str, **kw):
    if name == "cosine":
        return lambda step: cosine(step, **kw)
    if name == "wsd":
        return lambda step: wsd(step, **kw)
    if name == "constant":
        return lambda step: kw["base_lr"] * linear_warmup(step, kw.get("warmup", 0))
    raise ValueError(name)
