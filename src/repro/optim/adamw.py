"""AdamW with dtype-configurable moments (bf16 moments for the huge archs —
halves optimizer-state HBM, the fleet-scale memory trick recorded in
DESIGN.md).  Master params stay in ``cfg.param_dtype``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

OptState = Dict[str, Any]


def adamw_init(params, moment_dtype: str = "float32") -> OptState:
    md = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, md)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt: OptState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1):
    count = opt["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        step = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay (skip 1-D params: norms, biases, gates)
        if p.ndim >= 2:
            step = step + wd * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm
