from repro.optim.adamw import (  # noqa: F401
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import cosine, make_schedule, wsd  # noqa: F401
