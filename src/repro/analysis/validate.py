"""Static pre-flight validator for PST applications.

``validate_app(pipelines)`` runs every check that is decidable from the
declared `PipelineSpec`/`Stage`/`TaskSpec` objects, their `core.flow` port
graph, and (when a runtime is provided) the pilot's topology, sharding
contract, staging budget, and retry policy — BEFORE any task launches.
Findings come back as a :class:`repro.analysis.diagnostics.Report` of
stable-coded diagnostics (the registry lives in ``diagnostics.CODES``; the
ROADMAP "Analysis & correctness tooling" section documents each code).

Two layers:

1.  A structural pass over the declarations (port well-formedness, kernel
    resolution, name collisions, dtype compatibility, slot feasibility,
    staging budgets).
2.  An *abstract executor*: a deterministic re-implementation of the
    ``AppManager``'s submission rules (channel availability, broadcast
    cursors, capacity back-pressure, future parking) that advances every
    pipeline to a fixpoint counting puts/takes only — no tasks, no pilot.
    Pipelines stuck at the fixpoint are classified into starvation (E105),
    capacity deadlock (E106), or wait-for cycles (E104) by root-causing
    the blocked-pipeline graph: secondary blockages (a pipeline starved
    only because its producer is stuck) are suppressed so one defect
    yields one diagnostic.

Adaptive ``on_done`` extensions are invisible statically; the validator
analyzes the declared stages, which is exactly the fail-early contract:
anything a callback appends later is validated by the runtime checks when
it is submitted.

Usage::

    report = validate_app(pipes, runtime=rt)
    report.raise_if_errors()          # or inspect report.diagnostics

``AppManager.run(..., validate="error"|"warn"|"off")`` wires this in, and
``python -m repro.analysis lint module:factory`` runs it from the CLI.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.analysis.diagnostics import Report
from repro.core import flow
from repro.core.flow import Channel, StageFuture
from repro.core.kernel_plugin import Kernel, kernel_names, kernel_registered

# ------------------------------------------------------------ small helpers


def _kernel_of(spec) -> Optional[Kernel]:
    """The spec's Kernel when already bound; None for (unresolved) names."""
    k = getattr(spec, "kernel", None)
    return k if isinstance(k, Kernel) else None


def _spec_sources(obj) -> Tuple[Dict[str, Any], Optional[str]]:
    """normalize_sources with the failure folded into the return value."""
    try:
        return flow.normalize_sources(obj.inputs), None
    except (TypeError, ValueError) as e:
        return {}, str(e)


def _spec_outputs(obj) -> Tuple[List[Channel], Optional[str]]:
    try:
        return flow.normalize_outputs(obj.outputs), None
    except (TypeError, ValueError) as e:
        return [], str(e)


class _AbstractChannel:
    """Counting model of one Channel: enough state to decide every
    availability / back-pressure question the AppManager's blocker asks —
    including the byte-denominated bound (``capacity_bytes``), mirrored
    with per-put byte sizes — pre-seeded from the live object so a second
    ``run()`` on one manager validates against traffic the first run left
    behind."""

    def __init__(self, ch: Channel):
        self.name = ch.name
        self.mode = ch.mode
        self.capacity = ch.capacity
        self.capacity_bytes = ch.capacity_bytes
        self.n_puts = len(ch.puts)
        self.n_taken = len(ch._taken)
        self.cursors: Dict[str, int] = dict(ch._cursors)
        # per-put declared sizes (abstract fifo consumes in order, so the
        # running byte totals stay exact against the declared traffic)
        self.put_bytes: List[int] = [
            ch._byte_prefix[i + 1] - ch._byte_prefix[i]
            for i in range(self.n_puts)]
        self.bytes_taken = ch._bytes_taken

    def available_fifo(self) -> int:
        return self.n_puts - self.n_taken

    def available_broadcast(self, stream: str) -> int:
        return self.n_puts - self.cursors.get(stream, 0)

    def n_unconsumed(self) -> int:
        if self.mode == "broadcast":
            low = min(self.cursors.values()) if self.cursors else 0
            return self.n_puts - low
        return self.n_puts - self.n_taken

    def n_unconsumed_bytes(self) -> int:
        total = sum(self.put_bytes)
        if self.mode == "broadcast":
            low = min(self.cursors.values()) if self.cursors else 0
            return total - sum(self.put_bytes[:low])
        return total - self.bytes_taken


class _AbstractRun:
    """Execution-time state of one pipeline under abstract execution."""

    def __init__(self, spec, name: str):
        self.spec = spec
        self.name = name
        self.idx = -1
        self.done = False
        self.invalid = False      # E113/E102-poisoned: excluded from exec
        self.blocker = None       # ("channel"|"channel_space"|"future", key)


# ------------------------------------------------------------ entry point


def validate_app(pipelines, *, runtime=None,
                 channels: Optional[Dict[str, Channel]] = None,
                 existing_pipelines: Iterable[str] = ()) -> Report:
    """Validate PST pipelines; returns a Report (never raises).

    ``runtime`` (a PilotRuntime, optional) enables the environment-aware
    checks: slot feasibility against the topology + sharding contract
    (E108/W202), staging byte budgets (E109/W204, real mode), and the
    retry/pod-exclusion interaction (W203).  ``channels`` and
    ``existing_pipelines`` carry an AppManager's state from prior runs so
    repeated ``run()`` calls validate against it (E110/E111 and channel
    pre-seeding).
    """
    report = Report()
    pipes = list(pipelines) if not hasattr(pipelines, "stages") \
        else [pipelines]
    runs: List[_AbstractRun] = []
    names_used = set(existing_pipelines)
    for p in pipes:
        name = p.name or f"p{len(runs) + len(set(existing_pipelines)):04d}"
        if name in names_used:
            report.add("E111", f"pipeline name {name!r} already used",
                       pipeline=name)
        names_used.add(name)
        runs.append(_AbstractRun(p, name))

    seen_channels: Dict[str, Channel] = dict(channels or {})
    stage_owner: Dict[int, Tuple[_AbstractRun, int]] = {}
    for r in runs:
        for si, stage in enumerate(r.spec.stages):
            stage_owner[id(stage)] = (r, si)

    _structural_pass(report, runs, seen_channels, runtime)
    _flow_pass(report, runs, seen_channels, stage_owner)
    return report


# ------------------------------------------------------------ layer 1


def _structural_pass(report: Report, runs, seen_channels, runtime):
    task_names: Dict[str, str] = {}       # explicit name -> "pipeline/stage"
    for r in runs:
        for si, stage in enumerate(r.spec.stages):
            _check_stage(report, r, si, stage, seen_channels, runtime,
                         task_names)
    _check_channel_bytes(report, seen_channels, runtime)
    _check_sla_priorities(report, runs)
    _check_retry_policy(report, runtime)
    _check_recruiter(report, runtime)


def _check_stage(report, r, si, stage, seen_channels, runtime, task_names):
    loc = {"pipeline": r.name, "stage": si}
    srcs, err = _spec_sources(stage)
    if err:
        report.add("E113", f"stage inputs: {err}", **loc)
        r.invalid = True
    outs, err = _spec_outputs(stage)
    if err:
        report.add("E113", f"stage outputs: {err}", **loc)
        r.invalid = True
    for port, src in srcs.items():
        if not isinstance(src, (Channel, StageFuture)):
            report.add("E113",
                       f"input port {port!r}: expected Channel or "
                       f"StageFuture, got {type(src).__name__}", **loc)
            r.invalid = True
        elif isinstance(src, Channel):
            _check_channel(report, src, seen_channels, loc)
    for ch in outs:
        _check_channel(report, ch, seen_channels, loc)

    for j, spec in enumerate(stage.tasks):
        tloc = dict(loc)
        tloc["task"] = spec.name or f"#{j}"
        k = getattr(spec, "kernel", None)
        if isinstance(k, str) and not kernel_registered(k):
            report.add("E107",
                       f"kernel {k!r} matches no registered plugin "
                       f"(available: {', '.join(kernel_names())})", **tloc)
        sla = getattr(spec, "sla", None)
        if sla is not None:
            from repro.serving.sla import CLASSES
            if sla not in CLASSES:
                report.add("E115",
                           f"unknown SLA class {sla!r} "
                           f"(known: {', '.join(sorted(CLASSES))})", **tloc)
        if spec.name:
            prev = task_names.get(spec.name)
            here = f"{r.name}/stage{si}"
            if prev is not None:
                report.add("E112",
                           f"task name {spec.name!r} already used at "
                           f"{prev}", **tloc)
            task_names[spec.name] = here
        tsrcs, err = _spec_sources(spec)
        if err:
            report.add("E113", f"task inputs: {err}", **tloc)
            r.invalid = True
        touts, err = _spec_outputs(spec)
        if err:
            report.add("E113", f"task outputs: {err}", **tloc)
            r.invalid = True
        for port, src in tsrcs.items():
            if not isinstance(src, (Channel, StageFuture)):
                report.add("E113",
                           f"input port {port!r}: expected Channel or "
                           f"StageFuture, got {type(src).__name__}", **tloc)
                r.invalid = True
            elif isinstance(src, Channel):
                _check_channel(report, src, seen_channels, tloc)
        for ch in touts:
            _check_channel(report, ch, seen_channels, tloc)
            _check_put_dtype(report, _kernel_of(spec), ch, tloc,
                             task_level=True)
        kernel = _kernel_of(spec)
        # stage-level outputs carry {task: result} dicts: every member's
        # declared result type must satisfy the channel
        for ch in outs:
            _check_put_dtype(report, kernel, ch, tloc, task_level=False)
        _check_placement(report, kernel, runtime, tloc)
        _check_staging(report, kernel, runtime, tloc)


def _check_channel(report, ch: Channel, seen: Dict[str, Channel], loc):
    cur = seen.get(ch.name)
    if cur is None:
        seen[ch.name] = ch
    elif cur is not ch:
        if not any(d.code == "E110" and d.channel == ch.name
                   for d in report.diagnostics):
            report.add("E110",
                       f"two distinct Channel objects named {ch.name!r} "
                       "in one application", channel=ch.name, **{
                           k: v for k, v in loc.items() if k != "channel"})


def _check_put_dtype(report, kernel: Optional[Kernel], ch: Channel, loc,
                     *, task_level: bool):
    if kernel is None or ch.dtype is None or kernel.output_dtype is None:
        return
    if not issubclass(kernel.output_dtype, ch.dtype):
        kind = "task-level" if task_level else "stage-level"
        report.add("E101",
                   f"kernel {kernel.name!r} declares output_dtype="
                   f"{kernel.output_dtype.__name__} but {kind} output "
                   f"channel {ch.name!r} expects {ch.dtype.__name__}",
                   channel=ch.name, **loc)


def _pilot_reachable_width(rt) -> int:
    """Widest slot count one pilot can ever field: its current slots, or
    the best grow-recarve its device topology admits."""
    topo = getattr(rt, "topology", None)
    if topo is None:
        return rt.slots
    from repro.dist.sharding import shardable_recarve_counts
    return max(shardable_recarve_counts(topo))


def _check_fleet_placement(report, kernel, fleet, cores, loc):
    """E114/W202 for a federated runtime: a task must fit inside ONE
    pilot (the fleet's summed slots are not co-schedulable), so the bound
    is the widest pilot any future of this fleet can field — active
    pilots at their reachable recarve widths, plus whatever the recruiter
    could still spin up within its slot budget."""
    retired = getattr(fleet, "retired", set())
    current = reachable = 0
    for name, rt in fleet.pilots.items():
        if name in retired:
            continue
        current = max(current, rt.slots)
        reachable = max(reachable, _pilot_reachable_width(rt))
    rec = getattr(fleet, "recruiter", None)
    if rec is not None and getattr(fleet, "pilot_factory", None) is not None \
            and rec.slots_per_pilot <= rec.budget_slots:
        reachable = max(reachable, int(rec.slots_per_pilot))
    if cores <= current:
        return
    if cores > reachable:
        report.add("E114",
                   f"kernel {kernel.name!r} wants {cores} slots but no "
                   f"pilot this fleet can ever field goes past {reachable} "
                   f"(widest active pilot: {current}; "
                   + (f"recruiter pilots: {rec.slots_per_pilot} slots"
                      if rec is not None else "no recruiter")
                   + "): the fleet slot budget is unsatisfiable", **loc)
    else:
        report.add("W202",
                   f"kernel {kernel.name!r} wants {cores} slots; no active "
                   f"pilot fields that width yet (widest: {current}) — the "
                   "task waits for a recarve or a recruited pilot", **loc)


def _check_placement(report, kernel: Optional[Kernel], runtime, loc):
    """E108/W202: can the pilot EVER grant this task's slot width?
    Federated runtimes route to the per-pilot rule (E114/W202) first —
    ``runtime.slots`` on a Fleet is the SUM over pilots, which a single
    task can never co-schedule."""
    if kernel is None or runtime is None:
        return
    cores = int(kernel.cores or 1)
    if getattr(runtime, "pilots", None) is not None:
        _check_fleet_placement(report, kernel, runtime, cores, loc)
        return
    if cores <= runtime.slots:
        return
    topo = getattr(runtime, "topology", None)
    if topo is None:
        # abstract pilots resize freely; a wide task just waits for a grow
        report.add("W202",
                   f"kernel {kernel.name!r} wants {cores} slots but the "
                   f"pilot has {runtime.slots}; it will wait for a "
                   "resize", **loc)
        return
    from repro.dist.sharding import shardable_recarve_counts
    reachable = shardable_recarve_counts(topo)
    best = max(reachable)
    if cores > best:
        report.add("E108",
                   f"kernel {kernel.name!r} wants {cores} slots but no "
                   f"recarve reaches past {best} "
                   f"(reachable slot counts: {reachable}; grow splits the "
                   f"leading slot axis {topo.axis_names[:1]})", **loc)
    else:
        report.add("W202",
                   f"kernel {kernel.name!r} wants {cores} slots; the "
                   f"pilot must recarve {runtime.slots} -> >= {cores} "
                   "before it can start", **loc)


def _check_staging(report, kernel: Optional[Kernel], runtime, loc):
    """E109/W204: declared puts vs the staging byte budget.  Real mode
    only — DES stages *virtual* blobs that never occupy memory, so a sim
    run with large declared nbytes is fine by construction."""
    if kernel is None or runtime is None or not kernel.output_nbytes:
        return
    staging = getattr(runtime, "staging", None)
    if staging is None or runtime.mode != "real":
        return
    nbytes = int(kernel.output_nbytes)
    store = staging.store
    if nbytes < staging.threshold_bytes or nbytes <= store.byte_budget:
        return
    if store.spill_dir is None:
        report.add("E109",
                   f"kernel {kernel.name!r} declares output_nbytes="
                   f"{nbytes} > byte_budget={store.byte_budget} with no "
                   "spill_dir: the put cannot be held or spilled", **loc)
    else:
        report.add("W204",
                   f"kernel {kernel.name!r} declares output_nbytes="
                   f"{nbytes} > byte_budget={store.byte_budget}: every "
                   "put will go through the spill path", **loc)


def _check_channel_bytes(report, seen_channels, runtime):
    """E115: a ``capacity_bytes`` bound only engages when a staging layer
    supplies byte sizes for puts — without one, every put meters 0 bytes
    and the declared bound silently never parks anybody."""
    if runtime is None:
        return
    if getattr(runtime, "staging", None) is not None:
        return
    pilots = getattr(runtime, "pilots", None)
    if pilots and any(getattr(rt, "staging", None) is not None
                      for rt in pilots.values()):
        return            # some pilot of the fleet meters bytes
    for name in sorted(seen_channels):
        ch = seen_channels[name]
        if getattr(ch, "capacity_bytes", None) is not None:
            report.add("E115",
                       f"channel {name!r} declares capacity_bytes="
                       f"{ch.capacity_bytes} but the pilot has no staging "
                       "layer: puts carry no byte sizes, so the bound can "
                       "never engage", channel=name)


def _check_sla_priorities(report, runs):
    """W206: a preempting SLA class (latency) with nothing below it.  If
    no task in the whole app has a lower effective priority, there is
    nothing to evict — under saturation the latency class queues exactly
    like everything else and its deadline budget is fiction."""
    from repro.serving.sla import CLASSES

    def effective(spec) -> int:
        if getattr(spec, "priority", None) is not None:
            return int(spec.priority)
        c = CLASSES.get(getattr(spec, "sla", None) or "")
        return c.priority if c is not None else 0

    preempting = []                        # (priority, loc) of latency specs
    priorities = []
    for r in runs:
        for si, stage in enumerate(r.spec.stages):
            for spec in stage.tasks:
                p = effective(spec)
                priorities.append(p)
                c = CLASSES.get(getattr(spec, "sla", None) or "")
                if c is not None and c.preempts:
                    preempting.append(
                        (p, {"pipeline": r.name, "stage": si,
                             "task": spec.name or None}))
    if not preempting:
        return
    floor = min(p for p, _ in preempting)
    if all(p >= floor for p in priorities):
        _, loc = min(preempting, key=lambda e: e[0])
        report.add("W206",
                   f"latency-class tasks (priority {floor}) have no "
                   "lower-priority task anywhere in the app: nothing is "
                   "preemptable, so under saturation the latency class "
                   "queues like everything else", **loc)


def _check_retry_policy(report, runtime):
    """W203: more retries than distinct pods means the pod-exclusion
    preference must repeat a previously-blamed pod on late attempts."""
    if runtime is None:
        return
    try:
        pods = runtime.live_pods()
    except Exception:
        return
    if not pods:
        return            # no slot-id tracking: no pod exclusion either
    budget = int(runtime.max_retries) + 1
    if budget > len(pods):
        report.add("W203",
                   f"max_retries={runtime.max_retries} allows {budget} "
                   f"attempts but only {len(pods)} pods exist: attempts "
                   f"beyond {len(pods)} re-use previously-blamed pods")


def _check_recruiter(report, runtime):
    """W205: a recruiter that re-decides faster than its pilots arrive
    sees the backlog it already ordered capacity for and orders again —
    the classic autoscaler thrash.  Hysteresis must cover spin-up."""
    rec = getattr(runtime, "recruiter", None)
    if rec is None:
        return
    if rec.hysteresis_s < rec.spinup_s:
        report.add("W205",
                   f"recruiter hysteresis_s={rec.hysteresis_s:g} is "
                   f"shorter than spinup_s={rec.spinup_s:g}: the fleet "
                   "can re-decide before the pilot it just ordered "
                   "arrives — size oscillation is likely")


# ------------------------------------------------------------ layer 2


def _flow_pass(report, runs, seen_channels, stage_owner):
    """Abstract execution to a fixpoint + root-cause classification."""
    chans: Dict[str, _AbstractChannel] = {
        name: _AbstractChannel(ch) for name, ch in seen_channels.items()}

    # --- static producer/consumer maps over ALL declared stages
    producers: Dict[str, List[Tuple[_AbstractRun, int]]] = {}
    consumers: Dict[str, List[Tuple[_AbstractRun, int]]] = {}
    for r in runs:
        if r.invalid:
            continue
        for si, stage in enumerate(r.spec.stages):
            for ch in _all_outputs(stage):
                producers.setdefault(ch.name, []).append((r, si))
            for _ck, _stream, _port, src, _j in _bindings(stage, r, si):
                if isinstance(src, Channel):
                    consumers.setdefault(src.name, []).append((r, si))
                elif isinstance(src, StageFuture):
                    if id(src.stage) not in stage_owner \
                            and not src.submitted:
                        sname = getattr(src.stage, "name", "?")
                        report.add(
                            "E103",
                            f"StageFuture references stage {sname!r} "
                            "which is in no submitted pipeline",
                            pipeline=r.name, stage=si)
                        r.invalid = True

    no_producer = set()
    for cname, users in consumers.items():
        ach = chans.get(cname)
        preseeded = ach is not None and ach.n_puts > 0
        if cname not in producers and not preseeded:
            r, si = users[0]
            no_producer.add(cname)
            report.add("E102",
                       f"channel {cname!r} is consumed but nothing "
                       "produces to it and it holds no prior puts",
                       channel=cname, pipeline=r.name, stage=si)
    for cname in producers:
        ach = chans.get(cname)
        if ach is not None and ach.mode == "broadcast":
            continue
        if cname not in consumers:
            r, si = producers[cname][0]
            report.add("W201",
                       f"fifo channel {cname!r} is produced but never "
                       "consumed", channel=cname, pipeline=r.name,
                       stage=si)

    # --- run the abstract machine to a fixpoint
    live = [r for r in runs if not r.invalid]
    progress = True
    while progress:
        progress = False
        for r in live:
            if r.done:
                continue
            if _advance(r, chans, stage_owner):
                progress = True

    blocked = [r for r in live if not r.done]
    if not blocked:
        return
    _classify_blocked(report, blocked, chans, stage_owner, producers,
                      consumers, no_producer)


def _all_outputs(stage) -> List[Channel]:
    outs, err = _spec_outputs(stage)
    if err:
        return []
    for spec in stage.tasks:
        touts, terr = _spec_outputs(spec)
        if not terr:
            outs.extend(touts)
    return outs


def _stage_emissions(stage) -> Tuple[Dict[str, int], Dict[str, int],
                                     List[Tuple[Channel, int]]]:
    """What this stage will put, mirrored from the AppManager: per-channel
    put counts, per-channel declared byte totals, and the individual puts
    in emission order (a stage-level output is ONE {task: result} put
    carrying every member's declared bytes; a task-level output is one put
    per spec carrying that kernel's bytes)."""
    emits: Dict[str, int] = {}
    emit_bytes: Dict[str, int] = {}
    puts: List[Tuple[Channel, int]] = []
    stage_outs, err = _spec_outputs(stage)
    stage_nbytes = sum(
        int(getattr(_kernel_of(s), "output_nbytes", 0) or 0)
        for s in stage.tasks if _kernel_of(s) is not None)
    for ch in (stage_outs if not err else []):
        emits[ch.name] = emits.get(ch.name, 0) + 1
        emit_bytes[ch.name] = emit_bytes.get(ch.name, 0) + stage_nbytes
        puts.append((ch, stage_nbytes))
    for spec in stage.tasks:
        touts, terr = _spec_outputs(spec)
        k = _kernel_of(spec)
        kb = int(getattr(k, "output_nbytes", 0) or 0) if k is not None \
            else 0
        for ch in (touts if not terr else []):
            emits[ch.name] = emits.get(ch.name, 0) + 1
            emit_bytes[ch.name] = emit_bytes.get(ch.name, 0) + kb
            puts.append((ch, kb))
    return emits, emit_bytes, puts


def _bindings(stage, r, si):
    """Mirror of AppManager._iter_bindings over abstract runs."""
    srcs, err = _spec_sources(stage)
    if not err:
        for port, src in srcs.items():
            yield (f"{r.name}:{si:04d}:{port}", f"{r.name}:{port}",
                   port, src, None)
    for j, spec in enumerate(stage.tasks):
        tsrcs, terr = _spec_sources(spec)
        if terr:
            continue
        for port, src in tsrcs.items():
            yield (f"{r.name}:{si:04d}:{j:05d}:{port}",
                   f"{r.name}:{j:05d}:{port}", port, src, j)


def _blocker(r, stage, si, chans, stage_owner):
    """Abstract mirror of AppManager._input_blocker: the first
    unsatisfiable input or full output channel, else None."""
    fresh: Dict[str, int] = {}
    own_takes: Dict[str, int] = {}
    for ck, stream, _port, src, _j in _bindings(stage, r, si):
        if isinstance(src, Channel):
            ach = chans.setdefault(src.name, _AbstractChannel(src))
            if ach.mode == "broadcast":
                ach.cursors.setdefault(stream, 0)
            own_takes[src.name] = own_takes.get(src.name, 0) + 1
            if ach.mode == "broadcast":
                if ach.available_broadcast(stream) < 1:
                    return ("channel", src.name)
            else:
                fresh[src.name] = fresh.get(src.name, 0) + 1
        elif isinstance(src, StageFuture):
            owner = stage_owner.get(id(src.stage))
            if src.submitted:
                continue
            if owner is None:
                return ("future", id(src.stage))
            pr, psi = owner
            if pr.idx < psi:        # producer stage not yet submitted
                return ("future", id(src.stage))
    for cname, n in fresh.items():
        if chans[cname].available_fifo() < n:
            return ("channel", cname)
    emits, emit_bytes, _puts = _stage_emissions(stage)
    for ch in _all_outputs(stage):
        chans.setdefault(ch.name, _AbstractChannel(ch))
    for cname, n_emit in emits.items():
        ach = chans[cname]
        if ach.capacity is not None:
            backlog = ach.n_unconsumed() - own_takes.get(cname, 0)
            if backlog > 0 and backlog + n_emit > ach.capacity:
                return ("channel_space", cname)
        if ach.capacity_bytes is not None:
            # own-take byte credit: the fifo puts this stage itself will
            # consume drain before its emission lands (broadcast takes
            # free no bytes — other streams may still need them)
            credit = 0
            if ach.mode != "broadcast":
                lo = ach.n_taken
                hi = min(lo + own_takes.get(cname, 0), len(ach.put_bytes))
                credit = sum(ach.put_bytes[lo:hi])
            backlog_b = ach.n_unconsumed_bytes() - credit
            if backlog_b > 0 and \
                    backlog_b + emit_bytes[cname] > ach.capacity_bytes:
                return ("channel_space", cname)
    return None


def _advance(r, chans, stage_owner) -> bool:
    """Advance one pipeline as far as it can go; True if any stage ran."""
    ran = False
    while True:
        nxt = r.idx + 1
        if nxt >= len(r.spec.stages):
            r.done = True
            r.blocker = None
            return ran
        stage = r.spec.stages[nxt]
        b = _blocker(r, stage, nxt, chans, stage_owner)
        if b is not None:
            r.blocker = b
            return ran
        # run it: consume takes (retiring their bytes), emit puts
        for ck, stream, _port, src, _j in _bindings(stage, r, nxt):
            if isinstance(src, Channel):
                ach = chans[src.name]
                if ach.mode == "broadcast":
                    cur = ach.cursors.get(stream, 0)
                    ach.cursors[stream] = cur + 1
                else:
                    if ach.n_taken < len(ach.put_bytes):
                        ach.bytes_taken += ach.put_bytes[ach.n_taken]
                    ach.n_taken += 1
        for ch, nbytes in _stage_emissions(stage)[2]:
            ach = chans.setdefault(ch.name, _AbstractChannel(ch))
            ach.n_puts += 1
            ach.put_bytes.append(nbytes)
        r.idx = nxt
        r.blocker = None
        ran = True


def _classify_blocked(report, blocked, chans, stage_owner, producers,
                      consumers, no_producer):
    """Root-cause the fixpoint: who is stuck on a resource nobody can
    ever provide (E105/E106), who is in a genuine wait-for cycle
    (E104/E106)?  Pipelines blocked only downstream of a root cause are
    suppressed."""
    # helpers: the pipelines that could still unblock r
    def candidates(r):
        kind, key = r.blocker
        out = []
        if kind == "channel":
            for (pr, psi) in producers.get(key, []):
                if not pr.done and pr.idx < psi and pr is not r:
                    out.append(pr)
        elif kind == "channel_space":
            for (pr, psi) in consumers.get(key, []):
                if not pr.done and pr.idx < psi and pr is not r:
                    out.append(pr)
        elif kind == "future":
            owner = stage_owner.get(key)
            if owner is not None and not owner[0].done \
                    and owner[0] is not r:
                out.append(owner[0])
        return out

    cand = {r.name: candidates(r) for r in blocked}
    roots = [r for r in blocked if not cand[r.name]]
    for r in roots:
        kind, key = r.blocker
        si = r.idx + 1
        if kind == "channel":
            if key in no_producer:
                continue          # E102 already names the defect
            report.add("E105",
                       f"stage waits on channel {key!r} but every "
                       "producer has already run: the remaining takes "
                       "can never be satisfied", channel=key,
                       pipeline=r.name, stage=si)
        elif kind == "channel_space":
            report.add("E106",
                       f"bounded channel {key!r} is full and no "
                       "remaining stage consumes it: the producer is "
                       "wedged forever", channel=key, pipeline=r.name,
                       stage=si)
        else:
            sname = getattr(
                stage_owner.get(key, (None, None))[0], "name", "?")
            report.add("E103",
                       f"stage waits on a StageFuture whose producer "
                       f"({sname}) can never be submitted",
                       pipeline=r.name, stage=si)

    # cycles among the remaining blocked pipelines (every non-root has at
    # least one candidate, all of which are blocked, so any residue not
    # explained by a root must contain a cycle)
    root_names = {r.name for r in roots}
    index = {r.name: r for r in blocked}
    sccs = _sccs({r.name: [c.name for c in cand[r.name]]
                  for r in blocked if r.name not in root_names})
    reported = set()
    for comp in sccs:
        if len(comp) == 1:
            n = comp[0]
            if n not in [c.name for c in cand[n]]:
                continue              # not even a self-loop: secondary
        names = sorted(comp)
        key = tuple(names)
        if key in reported:
            continue
        reported.add(key)
        kinds = {index[n].blocker[0] for n in comp}
        chan_names = sorted({index[n].blocker[1] for n in comp
                             if index[n].blocker[0] != "future"})
        via = f" via channels {chan_names}" if chan_names else ""
        if "channel_space" in kinds:
            report.add("E106",
                       f"capacity deadlock: pipelines {names} block each "
                       f"other{via}; at least one is parked on "
                       "channel_space that only the others could free",
                       pipeline=names[0])
        else:
            report.add("E104",
                       f"pipelines {names} wait on each other in a "
                       f"cycle{via}: the DAG-of-ensembles has no "
                       "topological order", pipeline=names[0])


def _sccs(graph: Dict[str, List[str]]) -> List[List[str]]:
    """Tarjan SCCs of {node: [successors]}; successors outside the graph
    are ignored (they are roots, classified separately)."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on: Dict[str, bool] = {}
    stack: List[str] = []
    out: List[List[str]] = []
    counter = [0]

    def strong(v):
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on[v] = True
        for w in graph.get(v, ()):
            if w not in graph:
                continue
            if w not in index:
                strong(w)
                low[v] = min(low[v], low[w])
            elif on.get(w):
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            comp = []
            while True:
                w = stack.pop()
                on[w] = False
                comp.append(w)
                if w == v:
                    break
            out.append(comp)

    for v in list(graph):
        if v not in index:
            strong(v)
    return out
