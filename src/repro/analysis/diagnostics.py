"""Structured diagnostics for the static linter and journal sanitizer.

Every check in :mod:`repro.analysis` emits :class:`Diagnostic` records with
a stable code.  ``E###`` codes are errors (the spec cannot run correctly),
``W###`` are warnings (the spec runs but something is probably not what the
author meant), ``S###`` are journal-invariant violations found by the
sanitizer.  The full registry:

Static validator — errors
  E101  port-type-mismatch: a producer's declared ``output_dtype`` is not
        acceptable for the typed ``Channel`` it feeds.
  E102  channel-no-producer: a consumed channel has no producing stage in
        any submitted pipeline and no pre-seeded puts.
  E103  future-unknown-stage: a ``StageFuture`` references a pipeline/stage
        that is not part of this run and was never previously submitted.
  E104  ensemble-cycle: pipelines wait on each other in a cycle — the
        DAG-of-ensembles has no topological order.
  E105  channel-starved: a stage blocks on a channel whose producers have
        all run; the puts that exist can never satisfy the takes needed.
  E106  capacity-deadlock: a bounded-capacity channel wedges its producer
        while every consumer that could drain it is itself blocked.
  E107  unknown-kernel: a ``TaskSpec`` names a kernel no plugin registered.
  E108  slots-unsatisfiable: a task wants more cores than any reachable
        ``SlotTopology.recarve`` (respecting sharding divisibility) grants.
  E109  staging-overflow: a declared ``output_nbytes`` exceeds the staging
        store's ``byte_budget`` with no spill directory configured.
  E110  duplicate-channel: two distinct ``Channel`` objects share a name.
  E111  duplicate-pipeline: two pipelines (or a pipeline and an already-run
        one on the same ``AppManager``) share a name.
  E112  duplicate-task: two explicit ``TaskSpec.name``s collide.
  E113  invalid-ports: a stage/task ``inputs``/``outputs`` declaration is
        structurally malformed.

Static validator — federation (runtime is a repro.federation.Fleet)
  E114  fleet-slots-unsatisfiable: a task wants more cores than any pilot
        the fleet can EVER field — wider than every active pilot's
        reachable width and wider than anything the recruiter's slot
        budget could spin up.
  E115  invalid-sla: a ``TaskSpec.sla`` names no known serving SLA class,
        or a ``Channel(capacity_bytes=...)`` runs on a pilot with no
        staging layer (puts carry no byte sizes, so the byte bound could
        never engage).

Static validator — warnings
  W201  channel-unconsumed: a fifo channel is produced but never consumed.
  W202  task-wider-than-pilot: a task needs a recarve (grow) before any
        slot can host it — feasible, but startup will stall until granted.
  W203  retries-exceed-pods: ``max_retries`` exceeds what pod-exclusion
        preferences can honor — late retries reuse previously-blamed pods.
  W204  spill-guaranteed: a declared put must exceed ``byte_budget`` and
        will always hit the spill path.
  W205  recruiter-thrash: the recruiter's hysteresis window is shorter
        than its pilot spin-up time, so it can re-decide before the pilot
        it just ordered arrives — fleet size can oscillate.
  W206  latency-starvation-risk: latency-class tasks are declared but no
        task in the app has a lower effective priority — nothing is
        preemptable, so under saturation the latency class queues exactly
        like everything else.

Journal sanitizer
  S301  epoch-regression: ``scheduled`` launch epochs not strictly
        increasing for a task within one session segment.
  S302  zombie-clobber: a result was assigned by an attempt whose epoch had
        been nulled (abandoned) — the PR-6 zombie guard failed.
  S303  release-imbalance: a staged ref was released more than once, or a
        terminal task with staged inputs never released them.
  S304  flow-binding: a ``channel_take`` names a put that does not exist
        (yet), or a fifo put was consumed by two distinct consumers.
  S305  attempt-gap: per-task attempt history skips a number within one
        session segment — an attempt left no record.
  S306  time-overlap: ``t_exec``/``t_data`` accounting is not disjoint —
        their sum exceeds the wall interval of the attempt.

``python -m repro.analysis codes`` prints this table.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

#: code -> (slug, one-line description); the single source of truth used by
#: the CLI, ROADMAP, and tests (every code must have a triggering fixture).
CODES = {
    "E101": ("port-type-mismatch",
             "producer output dtype incompatible with typed channel"),
    "E102": ("channel-no-producer",
             "consumed channel has no producer and no pre-seeded puts"),
    "E103": ("future-unknown-stage",
             "StageFuture references a stage in no known pipeline"),
    "E104": ("ensemble-cycle",
             "pipelines wait on each other in a cycle"),
    "E105": ("channel-starved",
             "all producers run; remaining takes can never be satisfied"),
    "E106": ("capacity-deadlock",
             "bounded channel wedges producer with no live consumer"),
    "E107": ("unknown-kernel",
             "TaskSpec kernel name matches no registered plugin"),
    "E108": ("slots-unsatisfiable",
             "cores request exceeds every reachable recarve slot width"),
    "E109": ("staging-overflow",
             "declared output_nbytes exceeds byte_budget with no spill_dir"),
    "E110": ("duplicate-channel",
             "two distinct Channel objects share one name"),
    "E111": ("duplicate-pipeline",
             "pipeline name already used in this AppManager"),
    "E112": ("duplicate-task",
             "two explicit TaskSpec names collide"),
    "E113": ("invalid-ports",
             "malformed inputs/outputs declaration"),
    "E114": ("fleet-slots-unsatisfiable",
             "cores request exceeds every pilot the fleet can ever field"),
    "E115": ("invalid-sla",
             "unknown SLA class, or capacity_bytes without a staging layer"),
    "W201": ("channel-unconsumed",
             "fifo channel produced but never consumed"),
    "W202": ("task-wider-than-pilot",
             "task needs a grow-recarve before any slot fits it"),
    "W203": ("retries-exceed-pods",
             "max_retries exceeds distinct pods; exclusions will repeat"),
    "W204": ("spill-guaranteed",
             "declared put exceeds byte_budget; always spills"),
    "W205": ("recruiter-thrash",
             "hysteresis shorter than pilot spin-up; size can oscillate"),
    "W206": ("latency-starvation-risk",
             "latency class declared but nothing lower-priority to preempt"),
    "S301": ("epoch-regression",
             "scheduled launch epochs not strictly increasing"),
    "S302": ("zombie-clobber",
             "result assigned by an abandoned (nulled-epoch) attempt"),
    "S303": ("release-imbalance",
             "staged refs not released exactly once per terminal task"),
    "S304": ("flow-binding",
             "take references a missing put, or fifo put double-consumed"),
    "S305": ("attempt-gap",
             "attempt history skips a number within a session segment"),
    "S306": ("time-overlap",
             "t_exec + t_data exceeds the attempt's wall interval"),
}


@dataclass
class Diagnostic:
    """One finding: a stable code plus enough location to act on it."""
    code: str
    message: str
    pipeline: Optional[str] = None
    stage: Optional[int] = None
    task: Optional[str] = None
    channel: Optional[str] = None

    @property
    def severity(self) -> str:
        return {"E": "error", "W": "warning", "S": "violation"}[self.code[0]]

    @property
    def slug(self) -> str:
        return CODES.get(self.code, ("?", "?"))[0]

    def __str__(self) -> str:
        loc = []
        if self.pipeline is not None:
            loc.append(f"pipeline={self.pipeline}")
        if self.stage is not None:
            loc.append(f"stage={self.stage}")
        if self.task is not None:
            loc.append(f"task={self.task}")
        if self.channel is not None:
            loc.append(f"channel={self.channel}")
        where = f" [{' '.join(loc)}]" if loc else ""
        return f"{self.code} {self.slug}{where}: {self.message}"


class DiagnosticError(RuntimeError):
    """Raised by ``validate='error'`` / strict sanitizing; carries the
    structured findings so callers need not re-parse the message."""

    def __init__(self, diagnostics: List[Diagnostic]):
        self.diagnostics = list(diagnostics)
        super().__init__("\n".join(str(d) for d in self.diagnostics))


@dataclass
class Report:
    """Ordered collection of diagnostics from one validator/sanitizer run."""
    diagnostics: List[Diagnostic] = field(default_factory=list)

    def add(self, code: str, message: str, **loc) -> Diagnostic:
        assert code in CODES, f"unregistered diagnostic code {code}"
        d = Diagnostic(code, message, **loc)
        self.diagnostics.append(d)
        return d

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.code[0] in "ES"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.code[0] == "W"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> List[str]:
        return [d.code for d in self.diagnostics]

    def format(self) -> str:
        if not self.diagnostics:
            return "no findings"
        return "\n".join(str(d) for d in self.diagnostics)

    def raise_if_errors(self):
        if self.errors:
            raise DiagnosticError(self.errors)
        return self

    def extend(self, other: "Report"):
        self.diagnostics.extend(other.diagnostics)
        return self
