"""Journal invariant sanitizer: happens-before checking over run records.

The runtime's deepest invariants (PR 6's launch epochs, staging refcount
balance, flow replay bindings, the TTC decomposition's t_exec/t_data
disjointness) all leave a trace in the journal.  :class:`JournalSanitizer`
replays that trace and checks every invariant **incrementally** — each
``observe(rec)`` call digests one record — so the same checker runs

* post-hoc over any journal file: ``sanitize_file(path)`` (the CLI
  ``python -m repro.analysis sanitize`` and the CI gate over the smoke-run
  journals), and
* live inside a running pilot: ``PilotRuntime(sanitize=True)`` attaches
  ``observe`` as the journal's observer and raises
  :class:`~repro.analysis.diagnostics.DiagnosticError` at the exact record
  that breaks an invariant (strict mode).

Session segments: a crash-restart legitimately re-runs tasks from attempt
one, so per-task epoch state resets at every ``session_start`` record
(written by each ``RuntimeSession``).  Channel traffic, by contrast,
survives restarts by design (replayed puts/takes), so the flow-binding
state is global across segments.

Pilot scoping: federated runs stamp every record with a ``pilot`` tag
(Journal.tag) and a FederatedSession writes a ``session_start`` into EACH
pilot's journal.  A *tagged* session_start therefore resets only that
pilot's task segments — otherwise one pilot's restart would wipe the
epoch state of every other pilot sharing the observer (or a merged
journal) and zombie clobbers would go unseen.  Untagged session_start
records keep the old reset-everything behavior.

Checked invariants (codes in ``diagnostics.CODES``):

  S301  epoch monotonicity: ``scheduled`` records for one task carry
        strictly increasing attempt epochs within a segment.
  S302  zombie clobber: a ``finished``/DONE record (not a speculative
        supersession) must not reuse an epoch that an abandonment record
        (pod_lost/worker_died/heartbeat_timeout/canceled/preempted)
        already nulled.
  S303  staged-ref release balance: at most one ``staged_release`` per
        task per segment, and a task whose ``scheduled`` record listed
        staged inputs must release them by its terminal record.
  S304  flow bindings: every ``channel_take`` names a put that exists,
        and a fifo put is consumed by at most one distinct consumer.
  S305  attempt contiguity: epochs within a segment never skip a number
        (every attempt leaves a record).
  S306  time disjointness: sim — ``v_finished - v_started`` equals
        ``t_exec + t_data`` to 1e-6; real — ``t_exec + t_data_kernel``
        never exceeds the attempt's wall interval (1 ms tolerance).
        When records carry the virtual-clock stamp ``vt`` (PR 10) the
        check extends to SLOTS: two attempts holding the same
        (pilot, slot_id) must not have overlapping [scheduled.vt,
        close.vt] windows — the slot timeline the TTC decomposition
        partitions must be single-occupancy.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.diagnostics import DiagnosticError, Report

_ABANDON_EVENTS = ("pod_lost", "worker_died", "heartbeat_timeout",
                   "canceled", "preempted")
_SIM_TOL = 1e-6
_REAL_TOL = 1e-3


class _TaskSeg:
    """Per-task state within one session segment."""
    __slots__ = ("last_epoch", "abandoned", "staged", "releases",
                 "terminal", "pilot", "held")

    def __init__(self):
        self.last_epoch: Optional[int] = None
        self.abandoned: Set[int] = set()
        self.staged: List[str] = []       # digests on the last scheduled
        self.releases = 0
        self.terminal = False
        self.pilot: Optional[str] = None  # owning pilot (tagged journals)
        self.held: List[Tuple[Optional[str], int]] = []  # open attempt's slots


class JournalSanitizer:
    """Incremental happens-before checker over journal records.

    ``strict=True`` raises :class:`DiagnosticError` at the first
    violation (the live ``PilotRuntime(sanitize=True)`` mode); otherwise
    violations accumulate in :attr:`report` (the post-hoc mode).
    """

    def __init__(self, *, strict: bool = False):
        self.strict = strict
        self.report = Report()
        self.n_records = 0
        self._tasks: Dict[str, _TaskSeg] = {}
        self._segment = 0
        # flow state is global (channel replay crosses restarts)
        self._puts: Set[Tuple[str, str]] = set()
        self._chan_mode: Dict[str, str] = {}
        self._fifo_consumer: Dict[Tuple[str, str], str] = {}
        # slot occupancy on the vt clock: (pilot, slot_id) -> holder /
        # latest release time.  Only fed by records carrying ``vt``.
        self._slot_open: Dict[Tuple[Optional[str], int], str] = {}
        self._slot_free_at: Dict[Tuple[Optional[str], int], float] = {}

    # ------------------------------------------------------------ plumbing
    def _seg(self, task: str) -> _TaskSeg:
        seg = self._tasks.get(task)
        if seg is None:
            seg = self._tasks[task] = _TaskSeg()
        return seg

    def _violation(self, code: str, message: str, **loc):
        d = self.report.add(code, message, **loc)
        if self.strict:
            raise DiagnosticError([d])

    def prime(self, path: Optional[str]):
        """Digest an existing journal file to seed state (puts, epochs)
        WITHOUT reporting or raising on its historical content — a live
        sanitizer attached to an appended journal must know about prior
        segments' puts or every replayed take would look unbound."""
        if not path or not os.path.exists(path):
            return
        strict, self.strict = self.strict, False
        try:
            with open(path) as f:
                for line in f:
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue          # torn crash line
                    self.observe(rec)
        finally:
            self.strict = strict
            self.report = Report()        # historical findings discarded

    # ------------------------------------------------------------ observe
    def observe(self, rec: dict):
        """Digest one journal record (the Journal.observer hook)."""
        self.n_records += 1
        ev = rec.get("event")
        if ev == "session_start":
            self._segment += 1
            tag = rec.get("pilot")
            if tag is None:
                self._tasks = {}           # single-runtime journal: reset all
            else:
                # a pilot's restart resets ONLY that pilot's task segments;
                # other pilots' epoch state must not bleed away
                self._tasks = {k: s for k, s in self._tasks.items()
                               if s.pilot != tag}
            if tag is None:
                self._slot_open = {}
                self._slot_free_at = {}
            else:
                self._slot_open = {k: v for k, v in self._slot_open.items()
                                   if k[0] != tag}
                self._slot_free_at = {
                    k: v for k, v in self._slot_free_at.items()
                    if k[0] != tag}
            return
        if ev == "channel_put":
            self._on_put(rec)
            return
        if ev == "channel_take":
            self._on_take(rec)
            return
        task = rec.get("task")
        if task is None:
            return                         # run-level event (pod_lost, ...)
        if ev == "scheduled":
            self._on_scheduled(task, rec)
            self._slot_acquire(task, rec)
        elif ev == "staged_release":
            self._on_release(task, rec)
        elif ev in _ABANDON_EVENTS:
            seg = self._seg(task)
            seg.abandoned.add(int(rec.get("attempts", 0)))
            self._slot_release(task, rec)
        elif ev == "finished":
            self._on_finished(task, rec)
            self._slot_release(task, rec)
        elif ev == "failed":
            if rec.get("state") == "FAILED":
                self._on_terminal(task)
            self._slot_release(task, rec)

    # ------------------------------------------------------------ checks
    def _on_scheduled(self, task: str, rec: dict):
        seg = self._seg(task)
        if rec.get("pilot") is not None:
            seg.pilot = rec["pilot"]      # task (re)binds to this pilot
        epoch = int(rec.get("attempts", 0))
        if seg.last_epoch is not None:
            if epoch <= seg.last_epoch:
                self._violation(
                    "S301",
                    f"scheduled epoch {epoch} after epoch "
                    f"{seg.last_epoch} in the same segment", task=task)
            elif epoch > seg.last_epoch + 1:
                self._violation(
                    "S305",
                    f"attempt history jumps {seg.last_epoch} -> {epoch}: "
                    "an attempt left no record", task=task)
        seg.last_epoch = max(epoch, seg.last_epoch or 0)
        staged = rec.get("staged")
        if staged:
            seg.staged = list(staged)

    def _on_finished(self, task: str, rec: dict):
        if rec.get("by") is not None:
            return            # supersession record: epoch legally nulled
        if rec.get("state") != "DONE":
            return
        seg = self._seg(task)
        epoch = int(rec.get("attempts", 0))
        if epoch in seg.abandoned:
            self._violation(
                "S302",
                f"result assigned by abandoned attempt {epoch} (its "
                "epoch was nulled): the zombie guard failed", task=task)
        self._check_times(task, rec)
        self._on_terminal(task)

    def _check_times(self, task: str, rec: dict):
        t_exec = rec.get("t_exec")
        if t_exec is None:
            return            # pre-analysis journal: no timing fields
        if "v_started" in rec and "v_finished" in rec:
            span = float(rec["v_finished"]) - float(rec["v_started"])
            total = float(t_exec) + float(rec.get("t_data", 0.0))
            if abs(span - total) > _SIM_TOL:
                self._violation(
                    "S306",
                    f"virtual interval {span:g} != t_exec + t_data "
                    f"= {total:g}: the TTC decomposition is not "
                    "disjoint", task=task)
        elif "wall" in rec:
            overlap = (float(t_exec) + float(rec.get("t_data_kernel", 0.0))
                       - float(rec["wall"]))
            if overlap > _REAL_TOL:
                self._violation(
                    "S306",
                    f"t_exec + t_data_kernel exceeds the wall interval "
                    f"by {overlap:g}s: exec and data windows overlap",
                    task=task)

    def _slot_acquire(self, task: str, rec: dict):
        """Slot single-occupancy on the vt clock (records without ``vt``
        or ``slot_ids`` — real mode, pre-PR-10 journals — are skipped)."""
        vt = rec.get("vt")
        slot_ids = rec.get("slot_ids")
        if vt is None or not slot_ids:
            return
        seg = self._seg(task)
        pilot = rec.get("pilot")
        for sid in slot_ids:
            key = (pilot, int(sid))
            holder = self._slot_open.get(key)
            if holder is not None and holder != task:
                self._violation(
                    "S306",
                    f"slot {key[1]} scheduled to {task!r} at vt={vt:g} "
                    f"while still held by {holder!r}: slot occupancy "
                    "overlaps", task=task)
            elif float(vt) < self._slot_free_at.get(key,
                                                    float("-inf")) - _SIM_TOL:
                self._violation(
                    "S306",
                    f"slot {key[1]} scheduled to {task!r} at vt={vt:g} "
                    f"before its previous attempt released it at "
                    f"vt={self._slot_free_at[key]:g}", task=task)
            self._slot_open[key] = task
        seg.held = [(pilot, int(s)) for s in slot_ids]

    def _slot_release(self, task: str, rec: dict):
        vt = rec.get("vt")
        seg = self._tasks.get(task)
        if vt is None or seg is None or not seg.held:
            return
        for key in seg.held:
            if self._slot_open.get(key) == task:
                del self._slot_open[key]
            prev = self._slot_free_at.get(key, float("-inf"))
            self._slot_free_at[key] = max(prev, float(vt))
        seg.held = []

    def _on_release(self, task: str, rec: dict):
        seg = self._seg(task)
        seg.releases += 1
        if seg.releases > 1:
            self._violation(
                "S303",
                f"staged refs released {seg.releases} times "
                "(must be exactly once)", task=task)

    def _on_terminal(self, task: str):
        # release-balance closure is checked in finalize(): the runtime
        # journals the terminal record BEFORE the release record, so a
        # missing release is only decidable once the whole file is read
        self._seg(task).terminal = True

    def _on_put(self, rec: dict):
        ch, pk = rec.get("channel"), rec.get("producer")
        if ch is None or pk is None:
            return
        self._puts.add((ch, pk))
        mode = rec.get("mode")
        if mode:
            self._chan_mode[ch] = mode

    def _on_take(self, rec: dict):
        ch, pk = rec.get("channel"), rec.get("producer")
        consumer = rec.get("consumer")
        if ch is None or pk is None:
            return
        if (ch, pk) not in self._puts:
            self._violation(
                "S304",
                f"take by {consumer!r} references put {pk!r} on channel "
                f"{ch!r} which does not exist (yet)", channel=ch)
            return
        if consumer is None or self._chan_mode.get(ch) != "fifo":
            return            # broadcast / unknown mode: fan-out is legal
        prev = self._fifo_consumer.setdefault((ch, pk), consumer)
        if prev != consumer:
            self._violation(
                "S304",
                f"fifo put {pk!r} on channel {ch!r} consumed by both "
                f"{prev!r} and {consumer!r}", channel=ch)

    # ------------------------------------------------------------ results
    def finalize(self) -> Report:
        """Post-hoc closing checks (release balance needs to know the run
        ended); returns the report.  Live mode never calls this — a live
        run cannot know a task will not release later."""
        for task, seg in self._tasks.items():
            if seg.terminal and seg.staged and seg.releases == 0:
                self._violation(
                    "S303",
                    f"task reached a terminal state holding "
                    f"{len(seg.staged)} staged refs it never released",
                    task=task)
        return self.report


def sanitize_file(path: str) -> Report:
    """Check every invariant over one journal file; returns the Report
    (empty when the journal is clean).  Torn trailing lines — the normal
    crash artifact — are skipped, exactly as the replay parsers do."""
    san = JournalSanitizer(strict=False)
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                san.observe(rec)
    return san.finalize()
