"""CLI for repro.analysis.

Subcommands::

    python -m repro.analysis lint <module[:factory]> [...]
        Import each module, call its factory (default ``build``) to get
        pipelines (a PipelineSpec, a list of them, or a tuple whose first
        element is one), validate, print diagnostics.  Exit 1 on errors.

    python -m repro.analysis sanitize <path|dir> [...]
        Check journal invariants over each ``.jsonl`` file (directories
        expand to every ``*.jsonl`` inside).  Exit 1 on violations.

    python -m repro.analysis codes
        Print the diagnostic-code registry.
"""
from __future__ import annotations

import argparse
import glob
import importlib
import os
import sys

from repro.analysis.diagnostics import CODES
from repro.analysis.sanitizer import sanitize_file
from repro.analysis.validate import validate_app


def _load_pipelines(target: str):
    mod_name, _, factory = target.partition(":")
    mod = importlib.import_module(mod_name)
    fn = getattr(mod, factory or "build")
    built = fn()
    if isinstance(built, tuple):
        built = built[0]
    return built


def _cmd_lint(targets) -> int:
    rc = 0
    for target in targets:
        pipes = _load_pipelines(target)
        report = validate_app(pipes)
        n_err = len(report.errors)
        print(f"== lint {target}: {n_err} error(s), "
              f"{len(report.warnings)} warning(s)")
        if report.diagnostics:
            print(report.format())
        if n_err:
            rc = 1
    return rc


def _expand(paths):
    out = []
    for p in paths:
        if os.path.isdir(p):
            out.extend(sorted(glob.glob(os.path.join(p, "*.jsonl"))))
        else:
            out.append(p)
    return out


def _cmd_sanitize(paths) -> int:
    rc = 0
    files = _expand(paths)
    if not files:
        print("sanitize: no journal files found", file=sys.stderr)
        return 1
    for path in files:
        if not os.path.exists(path):
            print(f"sanitize: {path}: no such journal", file=sys.stderr)
            rc = 1
            continue
        report = sanitize_file(path)
        status = "clean" if report.ok else \
            f"{len(report.errors)} violation(s)"
        print(f"== sanitize {path}: {status}")
        if report.diagnostics:
            print(report.format())
        if not report.ok:
            rc = 1
    return rc


def _cmd_codes() -> int:
    for code, (slug, desc) in sorted(CODES.items()):
        print(f"{code}  {slug:24s} {desc}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.analysis")
    sub = ap.add_subparsers(dest="cmd", required=True)
    lint = sub.add_parser("lint", help="validate PST app declarations")
    lint.add_argument("targets", nargs="+",
                      help="module[:factory] building the pipelines")
    san = sub.add_parser("sanitize", help="check journal invariants")
    san.add_argument("paths", nargs="+",
                     help="journal .jsonl files or directories of them")
    sub.add_parser("codes", help="print the diagnostic-code registry")
    args = ap.parse_args(argv)
    if args.cmd == "lint":
        return _cmd_lint(args.targets)
    if args.cmd == "sanitize":
        return _cmd_sanitize(args.paths)
    return _cmd_codes()


if __name__ == "__main__":
    sys.exit(main())
