"""repro.analysis — static pre-flight linter + journal invariant sanitizer.

Two halves (see ROADMAP "Analysis & correctness tooling"):

* :func:`validate_app` — every application error decidable from the
  declared PST/flow/dist/staging specs, found BEFORE any task launches
  (codes E1xx/W2xx).  Wired into ``AppManager.run(validate=...)``.
* :class:`JournalSanitizer` / :func:`sanitize_file` — happens-before
  checking of runtime journals against the executor's dynamic invariants
  (codes S3xx).  Wired into ``PilotRuntime(sanitize=True)`` and the CI
  smoke-journal gate.

CLI: ``python -m repro.analysis lint <module[:factory]>`` and
``python -m repro.analysis sanitize <journal.jsonl|dir>...``.
"""
from repro.analysis.diagnostics import (CODES, Diagnostic, DiagnosticError,
                                        Report)
from repro.analysis.sanitizer import JournalSanitizer, sanitize_file
from repro.analysis.validate import validate_app

__all__ = ["CODES", "Diagnostic", "DiagnosticError", "Report",
           "JournalSanitizer", "sanitize_file", "validate_app"]
