"""Serving metrics: per-class latency, TTFT, goodput, slot occupancy.

The DES run never materializes individual requests — each serve task is a
whole window's decode wave.  :class:`ServingMetrics` reconstructs the
per-request view afterwards: the traffic model regenerates window k's
arrivals, :func:`~repro.serving.server.simulate_continuous`'s offsets say
when each request's first/last token landed inside the wave, and the task
graph's timestamps anchor both to the session clock (virtual in DES, wall
perf_counter in real mode).  DES arrivals follow the offered-load
schedule (open-loop), so source-side admission stalls count as latency
instead of being coordinated-omitted away; real mode anchors to the
source task's actual interval (its windows don't pace wall time).  ``install`` lands the aggregate in
``prof.results["serving"]``:

    per-class: n, p50/p99 latency, p50/p99 TTFT, tokens, deadline-met
               tokens, goodput (met tokens/s over the class's span),
               mean decode-slot occupancy, dropped windows
    overall:   tokens, goodput, throughput (all tokens/s), span
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.runtime.states import TaskState
from repro.serving.server import ContinuousSim
from repro.serving.sla import CLASSES
from repro.serving.traffic import TrafficModel


@dataclass
class _Entry:
    task: str          # serve task name (one decode wave)
    source: str        # producing traffic task (arrival anchor)
    sla: str
    window: int
    sim: ContinuousSim


class ServingMetrics:
    """Post-run reconstruction of per-request serving metrics.

    ``deadlines`` overrides the per-class deadline budget (seconds);
    classes default to ``repro.serving.sla.CLASSES``.
    """

    def __init__(self, model: TrafficModel,
                 deadlines: Optional[Dict[str, float]] = None):
        self.model = model
        self.deadlines = {name: c.deadline_s for name, c in CLASSES.items()}
        self.deadlines.update(deadlines or {})
        self.entries: List[_Entry] = []

    def register(self, *, task: str, source: str, sla: str, window: int,
                 sim: ContinuousSim):
        self.entries.append(_Entry(task, source, sla, window, sim))

    # ------------------------------------------------------------ collect
    @staticmethod
    def _times(t) -> Optional[tuple]:
        """(finish time, on-virtual-clock) for a completed task, or None.
        DES tasks carry virtual timestamps (the virtual interval is
        duration + t_data, so ``v_finished - makespan_s`` is the instant
        decoding began, after stage-in); real-mode tasks fall back to
        wall perf_counter timestamps."""
        if t is None or t.state != TaskState.DONE:
            return None
        if t.v_finished > 0.0:
            return t.v_finished, True
        return t.t_finished, False

    def collect(self, am) -> Dict[str, Any]:
        graph = am.session.graph
        per: Dict[str, Dict[str, Any]] = {}
        w_s = self.model.window_s
        # DES arrivals are anchored to the OFFERED-LOAD schedule, not to
        # the source tasks' actual finish times: a source parked on byte
        # back-pressure (or waiting for a slot) is admission delay the
        # user experiences, so it must count as latency.  Deriving each
        # arrival from its own source's finish would silently shift the
        # arrival clock along with every stall — coordinated omission —
        # and a saturated baseline would measure as fast as an idle one.
        # t0 is the earliest virtual time consistent with some source
        # having run on schedule (window k's source, unstalled, finishes
        # at t0 + (k + 1) * window_s).  Real mode keeps the source-finish
        # anchor: sources there don't pace wall time (sim_duration is
        # virtual), so no wall-clock arrival schedule exists to miss.
        resolved = []
        t0 = None
        for e in self.entries:
            serve = ServingMetrics._times(graph.tasks.get(e.task))
            src = ServingMetrics._times(graph.tasks.get(e.source))
            resolved.append((e, serve, src))
            if serve is not None and src is not None and serve[1]:
                start = src[0] - (e.window + 1) * w_s
                t0 = start if t0 is None else min(t0, start)
        for e, serve, src in resolved:
            acc = per.setdefault(e.sla, {
                "lat": [], "ttft": [], "tokens": 0, "met_tokens": 0,
                "arrivals": [], "finishes": [], "occ": [], "steps": [],
                "dropped_windows": 0})
            if serve is None or src is None:
                acc["dropped_windows"] += 1
                continue
            serve_fin, sim_clock = serve
            # wave decode start on the session clock; real mode uses the
            # modeled per-request offsets against the real task interval
            t = graph.tasks[e.task]
            decode_start = (serve_fin - e.sim.makespan_s if sim_clock
                            else t.t_started)
            deadline = self.deadlines.get(e.sla, float("inf"))
            for r in self.model.requests(e.window, e.sla):
                arrival = (t0 + e.window * w_s + r.offset_s if sim_clock
                           else src[0] - (w_s - r.offset_s))
                fin = decode_start + e.sim.finish_s[r.rid]
                lat = fin - arrival
                acc["lat"].append(lat)
                acc["ttft"].append(decode_start + e.sim.first_s[r.rid]
                                   - arrival)
                acc["tokens"] += r.max_new_tokens
                if lat <= deadline:
                    acc["met_tokens"] += r.max_new_tokens
                acc["arrivals"].append(arrival)
                acc["finishes"].append(fin)
            acc["occ"].append(e.sim.occupancy)
            acc["steps"].append(e.sim.steps)
        return self._summarize(per)

    def _summarize(self, per: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
        out: Dict[str, Any] = {"classes": {}}
        all_arr, all_fin, all_tokens, all_met = [], [], 0, 0
        for sla, acc in sorted(per.items()):
            lat, ttft = np.asarray(acc["lat"]), np.asarray(acc["ttft"])
            span = (max(acc["finishes"]) - min(acc["arrivals"])
                    if acc["arrivals"] else 0.0)
            steps = np.asarray(acc["steps"], dtype=float)
            occ = (float(np.average(acc["occ"], weights=steps))
                   if len(steps) and steps.sum() else 0.0)
            out["classes"][sla] = {
                "n": int(lat.size),
                "p50_latency_s": float(np.percentile(lat, 50)) if lat.size
                else 0.0,
                "p99_latency_s": float(np.percentile(lat, 99)) if lat.size
                else 0.0,
                "p50_ttft_s": float(np.percentile(ttft, 50)) if ttft.size
                else 0.0,
                "p99_ttft_s": float(np.percentile(ttft, 99)) if ttft.size
                else 0.0,
                "tokens": acc["tokens"],
                "met_tokens": acc["met_tokens"],
                "goodput_tok_s": acc["met_tokens"] / span if span else 0.0,
                "occupancy": occ,
                "dropped_windows": acc["dropped_windows"],
            }
            all_arr += acc["arrivals"]
            all_fin += acc["finishes"]
            all_tokens += acc["tokens"]
            all_met += acc["met_tokens"]
        span = max(all_fin) - min(all_arr) if all_arr else 0.0
        out["overall"] = {
            "tokens": all_tokens, "met_tokens": all_met, "span_s": span,
            "goodput_tok_s": all_met / span if span else 0.0,
            "throughput_tok_s": all_tokens / span if span else 0.0,
        }
        return out

    def install(self, am, prof) -> Dict[str, Any]:
        """Collect and land the summary in ``prof.results["serving"]``."""
        summary = self.collect(am)
        prof.results["serving"] = summary
        return summary
