"""Online inference as a first-class ensemble workload.

The serving subsystem compiles a seedable traffic process into PST
pipelines: traffic windows become source tasks whose DES duration is the
window length, each window's requests decode as one continuous-batching
wave (``repro.serve.engine.BatchedServer`` in real mode, the
``simulate_continuous`` cost model in DES), SLA classes map onto frontier
priorities (``PilotRuntime(preempt=True)`` evicts throughput work for
latency work), and ``Channel(capacity_bytes=...)`` back-pressures bursty
producers by staged bytes.  See benchmarks/serve.py for the co-tenant
train+serve pilot this was built for.
"""
from repro.serving.metrics import ServingMetrics                # noqa: F401
from repro.serving.server import (                              # noqa: F401
    ContinuousSim,
    build_serve_pipeline,
    build_serving_app,
    simulate_continuous,
)
from repro.serving.sla import CLASSES, SLAClass, sla_class      # noqa: F401
from repro.serving.traffic import (                             # noqa: F401
    ServeRequest,
    TrafficModel,
    build_traffic_pipeline,
)
