"""Seedable request traffic: a diurnal + bursty arrival process.

A :class:`TrafficModel` is a pure function of ``(seed, window index)``:
window k's request list is recomputable anywhere — the source kernel, the
DES cost model (serving/server.py), and the metrics layer
(serving/metrics.py) all regenerate the same list from the model's
parameters instead of moving 100k request payloads through the task graph.
That is what lets the O(100k)-request benchmark run as O(windows) tasks.

``build_traffic_pipeline`` compiles the model into a PST source pipeline:
one stage per window (the stage's ``sim_duration`` IS the window length,
so virtual time advances at arrival speed), one task per SLA class, each
putting its window's batch descriptor on that class's Channel.  The
declared ``output_nbytes`` is the batch's prompt-byte size, which is what
``Channel(capacity_bytes=...)`` meters for byte back-pressure.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.flow import Channel
from repro.core.kernel_plugin import Kernel
from repro.core.pst import PipelineSpec, Stage, TaskSpec
from repro.serving.sla import CLASSES


@dataclass(frozen=True)
class ServeRequest:
    """One inference request, fully determined by (model seed, window)."""
    rid: int
    window: int
    sla: str                   # latency | throughput
    offset_s: float            # arrival offset inside its window
    prompt_tokens: int
    max_new_tokens: int


@dataclass(frozen=True)
class TrafficModel:
    """Deterministic arrival process: diurnal sinusoid + Bernoulli bursts.

    Window k's requests come from ``np.random.default_rng((seed, k))``, so
    any component can regenerate them independently; the diurnal rate is a
    raised cosine between ``base_rps`` and ``peak_rps`` over ``period_s``,
    and a burst window multiplies the rate by ``burst_mult``.
    """
    seed: int = 0
    window_s: float = 30.0
    base_rps: float = 2.0
    peak_rps: float = 8.0
    period_s: float = 3600.0
    burst_prob: float = 0.05
    burst_mult: float = 4.0
    latency_frac: float = 0.25       # share of latency-class requests
    prompt_tokens: int = 128
    latency_new_tokens: int = 16
    throughput_new_tokens: int = 96
    bytes_per_token: int = 4

    # ------------------------------------------------------------ process
    def rate(self, k: int) -> float:
        """Diurnal arrival rate (requests/s) for window k, pre-burst."""
        t = (k + 0.5) * self.window_s
        diurnal = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / self.period_s))
        return self.base_rps + (self.peak_rps - self.base_rps) * diurnal

    def window(self, k: int) -> List[ServeRequest]:
        """Window k's full request list (deterministic in (seed, k))."""
        rng = np.random.default_rng((self.seed, k))
        rate = self.rate(k)
        if rng.random() < self.burst_prob:
            rate *= self.burst_mult
        n = int(rng.poisson(rate * self.window_s))
        offsets = np.sort(rng.uniform(0.0, self.window_s, n))
        is_lat = rng.random(n) < self.latency_frac
        reqs = []
        for i in range(n):
            sla = "latency" if is_lat[i] else "throughput"
            reqs.append(ServeRequest(
                rid=k * 1_000_000 + i, window=k, sla=sla,
                offset_s=float(offsets[i]),
                prompt_tokens=self.prompt_tokens,
                max_new_tokens=(self.latency_new_tokens if sla == "latency"
                                else self.throughput_new_tokens)))
        return reqs

    def requests(self, k: int, sla: Optional[str] = None) \
            -> List[ServeRequest]:
        reqs = self.window(k)
        if sla is None:
            return reqs
        return [r for r in reqs if r.sla == sla]

    def batch_nbytes(self, reqs: List[ServeRequest]) -> int:
        return sum(r.prompt_tokens for r in reqs) * self.bytes_per_token

    def total_requests(self, n_windows: int) -> int:
        return sum(len(self.window(k)) for k in range(n_windows))


# ---------------------------------------------------------------- pipeline

def build_traffic_pipeline(model: TrafficModel, n_windows: int,
                           channels: Dict[str, Channel], *,
                           name: str = "traffic",
                           prioritize: bool = True) -> List[PipelineSpec]:
    """Compile ``model`` into source pipelines — ONE PER SLA CLASS, each
    with one stage per window whose virtual duration is the window length
    (arrivals advance the DES clock at real-traffic speed), putting that
    window's batch descriptor on ``channels[sla]``.  Windows where a class
    has no arrivals emit no stage for it.

    The classes must be separate pipelines: stages within a pipeline are
    sequential, so a shared source pipeline would let the throughput
    class's byte back-pressure (its source parking on ``channel_space``)
    stall latency-class arrivals it has no business gating.

    ``prioritize=False`` strips the SLA annotation (every task priority 0)
    — the no-priority baseline the serving benchmark compares against.
    """
    margs = dataclasses.asdict(model)
    pipes = []
    for sla in channels:
        if sla not in CLASSES:
            raise KeyError(f"unknown SLA class {sla!r} "
                           f"(known: {sorted(CLASSES)})")
        stages = []
        for k in range(n_windows):
            reqs = model.requests(k, sla)
            if not reqs:
                continue
            kern = Kernel("serve.source")
            kern.arguments = {"model": margs, "window": k, "sla": sla}
            kern.sim_duration = model.window_s
            kern.output_nbytes = model.batch_nbytes(reqs)
            stages.append(Stage(
                [TaskSpec(kern, name=f"{name}.{sla}.w{k:05d}",
                          outputs=channels[sla],
                          sla=sla if prioritize else None)],
                name=f"w{k:05d}"))
        pipes.append(PipelineSpec(stages, name=f"{name}.{sla}"))
    return pipes


def source_task_name(name: str, sla: str, k: int) -> str:
    """Task name ``build_traffic_pipeline`` gives window k's ``sla``
    source — the arrival anchor the metrics layer reads."""
    return f"{name}.{sla}.w{k:05d}"
