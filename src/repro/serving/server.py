"""Continuous-batching decode as PST stages + its DES cost model.

``simulate_continuous`` is the virtual-clock analogue of
``repro.serve.engine.BatchedServer._run_continuous``: B decode slots, each
request admitted into the earliest-free slot and evicted after its own
``max_new_tokens`` steps.  It returns per-request first-token / finish
offsets and the wave makespan — the makespan becomes the serve task's
``sim_duration``, and the offsets let the metrics layer reconstruct
per-request latency from a single task's timestamps.  That is how a
100k-request day of traffic runs in CI as a few thousand DES tasks.

``build_serve_pipeline`` compiles one SLA class into a pipeline: one
single-task stage per traffic window, consuming that class's Channel (the
per-task FIFO port pairs window k's put with window k's take) and carrying
the class's SLA annotation so the frontier orders — and the preemptive
executor evicts — by it.

In real mode the ``serve.decode`` kernel (repro/plugins/serve.py) runs an
actual ``BatchedServer`` (jit prefill/decode, continuous admit/evict) over
the regenerated prompts.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.flow import Channel
from repro.core.kernel_plugin import Kernel
from repro.core.pst import PipelineSpec, Stage, TaskSpec
from repro.serving.traffic import ServeRequest, TrafficModel, \
    build_traffic_pipeline, source_task_name


@dataclass(frozen=True)
class ContinuousSim:
    """Virtual-clock trace of one continuous-batch decode wave."""
    makespan_s: float
    steps: int
    prefills: int
    occupancy: float               # busy slot-steps / (slots * steps)
    first_s: Dict[int, float] = field(default_factory=dict)   # rid -> TTFT
    finish_s: Dict[int, float] = field(default_factory=dict)  # rid -> done


def simulate_continuous(reqs: List[ServeRequest], slots: int, *,
                        step_cost_s: float,
                        prefill_cost_s: float = 0.0) -> ContinuousSim:
    """Model a continuous-batching wave over ``slots`` decode slots.

    Each request takes the earliest-free slot (admission order = request
    order) and holds it for ``max_new_tokens`` steps; a slot frees the
    step its request finishes, exactly like ``BatchedServer``'s per-step
    admit/evict loop.  Admission wave w (the w-th group of ``slots``
    admissions) charges one group-prefill cost to its members' offsets.
    """
    if not reqs:
        return ContinuousSim(0.0, 0, 0, 1.0)
    free = [0] * max(int(slots), 1)       # next free step per slot
    heapq.heapify(free)
    first_s, finish_s = {}, {}
    makespan = 0
    for i, r in enumerate(reqs):
        start = heapq.heappop(free)
        end = start + max(int(r.max_new_tokens), 1)
        heapq.heappush(free, end)
        makespan = max(makespan, end)
        pre = (i // max(int(slots), 1) + 1) * prefill_cost_s
        first_s[r.rid] = (start + 1) * step_cost_s + pre
        finish_s[r.rid] = end * step_cost_s + pre
    prefills = -(-len(reqs) // max(int(slots), 1))
    busy = sum(max(int(r.max_new_tokens), 1) for r in reqs)
    return ContinuousSim(
        makespan_s=makespan * step_cost_s + prefills * prefill_cost_s,
        steps=makespan, prefills=prefills,
        occupancy=busy / (max(int(slots), 1) * makespan),
        first_s=first_s, finish_s=finish_s)


# ---------------------------------------------------------------- pipeline

def build_serve_pipeline(model: TrafficModel, sla: str, channel: Channel,
                         n_windows: int, *, decode_slots: int = 8,
                         cores: int = 1, step_cost_s: float = 0.05,
                         prefill_cost_s: float = 0.0,
                         name: Optional[str] = None,
                         source_pipeline: str = "traffic",
                         prioritize: bool = True,
                         metrics=None) -> PipelineSpec:
    """One SLA class's decode pipeline: a single-task stage per window
    with a DES duration from :func:`simulate_continuous`, consuming
    ``channel`` (FIFO: window k's put meets window k's take).  When a
    :class:`~repro.serving.metrics.ServingMetrics` is given, every window
    is registered so per-request latencies can be reconstructed post-run.
    ``prioritize=False`` strips the SLA annotation (baseline mode)."""
    name = name or f"serve.{sla}"
    margs = dataclasses.asdict(model)
    stages = []
    for k in range(n_windows):
        reqs = model.requests(k, sla)
        if not reqs:
            continue
        sim = simulate_continuous(reqs, decode_slots,
                                  step_cost_s=step_cost_s,
                                  prefill_cost_s=prefill_cost_s)
        kern = Kernel("serve.decode")
        kern.arguments = {"model": margs, "window": k, "sla": sla,
                          "decode_slots": decode_slots}
        kern.cores = cores
        kern.sim_duration = sim.makespan_s
        kern.output_nbytes = (sum(r.max_new_tokens for r in reqs)
                              * model.bytes_per_token)
        task_name = f"{name}.w{k:05d}"
        stages.append(Stage(
            [TaskSpec(kern, name=task_name,
                      inputs={"batch": channel},
                      sla=sla if prioritize else None)],
            name=f"w{k:05d}"))
        if metrics is not None:
            metrics.register(
                task=task_name,
                source=source_task_name(source_pipeline, sla, k),
                sla=sla, window=k, sim=sim)
    return PipelineSpec(stages, name=name)


def build_serving_app(model: TrafficModel, n_windows: int, *,
                      decode_slots: int = 8, cores: int = 1,
                      step_cost_s: float = 0.05,
                      prefill_cost_s: float = 0.0,
                      capacity_bytes: Optional[int] = None,
                      prioritize: bool = True,
                      deadlines: Optional[Dict[str, float]] = None,
                      classes: tuple = ("latency", "throughput")):
    """Wire the full online-inference workload: per-class Channels, the
    traffic source pipeline, one serve pipeline per class, and a metrics
    collector.  Returns ``(pipelines, channels, metrics)`` — run the
    pipelines on any AppManager (DES or real), then
    ``metrics.install(am, prof)`` to land per-class latency/goodput in
    ``prof.results["serving"]``.

    ``capacity_bytes`` bounds each class Channel's unconsumed staged bytes
    (producer-side back-pressure; requires the pilot to run a
    StagingLayer, enforced by diagnostic E115).  ``deadlines`` overrides
    the per-class deadline budgets the metrics count goodput against.
    """
    from repro.serving.metrics import ServingMetrics
    channels = {
        sla: Channel(f"serve.{sla}", capacity_bytes=capacity_bytes)
        for sla in classes}
    metrics = ServingMetrics(model, deadlines=deadlines)
    srcs = build_traffic_pipeline(model, n_windows, channels,
                                  prioritize=prioritize)
    serves = [build_serve_pipeline(model, sla, channels[sla], n_windows,
                                   decode_slots=decode_slots, cores=cores,
                                   step_cost_s=step_cost_s,
                                   prefill_cost_s=prefill_cost_s,
                                   prioritize=prioritize, metrics=metrics)
              for sla in classes]
    return [*srcs, *serves], channels, metrics
