"""SLA classes: the serving subsystem's contract with the frontier.

Online inference splits into two service classes (the pilot papers'
latency-sensitive vs throughput work sharing one allocation):

  latency      interactive traffic.  High frontier priority — its tasks
               pop before anything else — and, on a pilot with
               ``preempt=True``, may evict running throughput-class tasks
               through the requeue/abandon path.  Tight deadline budget.
  throughput   bulk/batch traffic (and co-tenant training).  Baseline
               priority, generous deadline; the preemption victim pool.

A ``TaskSpec(sla="latency")`` inherits the class priority and deadline;
both can be overridden per spec (``priority=``, ``deadline=``).  Unknown
class names are rejected at submit time with diagnostic E115.

This module is a leaf (no repro.core imports): core/pst.py resolves specs
through it without a layering cycle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class SLAClass:
    """One service class: frontier priority + default deadline budget."""
    name: str
    priority: int
    deadline_s: float      # default latency budget (arrival -> last token)
    preempts: bool         # may evict lower-priority RUNNING tasks


LATENCY = SLAClass("latency", priority=10, deadline_s=2.0, preempts=True)
THROUGHPUT = SLAClass("throughput", priority=0, deadline_s=600.0,
                      preempts=False)

CLASSES: Dict[str, SLAClass] = {c.name: c for c in (LATENCY, THROUGHPUT)}


def sla_class(name: str) -> SLAClass:
    """Look up a class; raises ``KeyError`` listing the known names."""
    try:
        return CLASSES[name]
    except KeyError:
        raise KeyError(f"unknown SLA class {name!r} "
                       f"(known: {', '.join(sorted(CLASSES))})") from None


def resolve_sla(spec) -> Tuple[int, Optional[float]]:
    """(priority, deadline) for a TaskSpec-like object: explicit fields
    win, else the SLA class defaults, else (0, None).  Unknown class names
    resolve as if unset — submit-time validation (E115) rejects them
    before any task is built."""
    cls = CLASSES.get(spec.sla) if spec.sla is not None else None
    priority = spec.priority if spec.priority is not None else \
        (cls.priority if cls is not None else 0)
    deadline = spec.deadline if spec.deadline is not None else \
        (cls.deadline_s if cls is not None else None)
    return int(priority), deadline
