"""Global implementation switches.

``impl`` resolution order: explicit argument > environment variable > default.
On the CPU stand-in backend the default is the XLA-native path; on real TPU
the Pallas kernels are the default hot path.
"""
from __future__ import annotations

import os

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_impl(env_var: str) -> str:
    v = os.environ.get(env_var)
    if v:
        return v
    return "pallas" if on_tpu() else "xla"


def attn_impl(override=None) -> str:
    return override or default_impl("REPRO_ATTN_IMPL")


def rglru_impl(override=None) -> str:
    return override or default_impl("REPRO_RGLRU_IMPL")


def mamba_impl(override=None) -> str:
    return override or default_impl("REPRO_MAMBA_IMPL")


def moe_impl(override=None) -> str:
    return override or default_impl("REPRO_MOE_IMPL")
