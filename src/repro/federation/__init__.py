"""repro.federation — multi-pilot fleet with late-binding dispatch and a
backlog-driven recruiter.

The EnTK papers scale one pilot; production campaigns run MANY — different
allocations, different meshes, joining and leaving mid-campaign.  This
package federates the runtime without changing the programming model: a
:class:`Fleet` owns N heterogeneous :class:`~repro.runtime.executor
.PilotRuntime`\\ s (own slot counts, own topologies, own journals) and
duck-types the single-pilot surface ``AppManager`` speaks, so the same
PST application runs federated by swapping the runtime object::

    from repro.federation import Fleet, Recruiter, build_fleet

    fleet = build_fleet(2, slots=8, slots_per_pod=2, mode="sim",
                        journal_base="myrun",
                        recruiter=Recruiter(max_pilots=4, spinup_s=5.0,
                                            hysteresis_s=10.0))
    mgr = AppManager(fleet)        # unchanged PST app from here on
    profile = mgr.run(pipelines)
    fleet.close()

The moving parts:

  fleet.py      ``Fleet`` facade + ``FleetStagingView`` (task-routed
                staging over ONE shared ObjectStore/TransferPlanner) +
                ``make_pilot``/``build_fleet`` constructors.  Pod names
                carry their pilot's prefix (``p1:pod0``) — replica
                locations, retry exclusions, fault routing and journal
                records all key on that, so federation needs no other
                plumbing.
  session.py    ``FederatedSession``: overrides the base session's
                dispatch hooks.  Every ready task LATE-BINDS at launch to
                the pilot minimizing estimated completion — modeled
                ``t_data`` from where its staged inputs actually live
                (link > pilot-to-pilot fetch at ``cross_gbps`` > host
                link), load as tiebreak, blamed pilots last.
  recruiter.py  ``Recruiter``: watches ``TaskGraph.frontier_slots()``
                backlog vs active capacity and spins pilots up/down
                against a slot budget, with hysteresis >= spin-up so the
                fleet converges instead of oscillating (W205 checks the
                configuration statically; E114 catches tasks wider than
                any pilot the fleet could ever field).

Failure model: a whole-pilot death is N pod deaths (PR-6 machinery) —
in-flight attempts abandoned, the pilot's staged replicas dropped from
the shared store, retries re-dispatched to surviving pilots, and the
recruiter sees the lost capacity as backlog pressure and may replace it.
Each pilot journals its own records (tagged with its name), so crash
replay reconstructs the whole fleet's progress — done tasks stay done,
attempt counts and pod exclusions survive, whichever pilot they happened
on.

Extension points (deliberately out of scope here): cross-pilot
speculative duplicates (``Fleet.straggler_factor`` pins speculation off),
per-pilot pricing in the dispatch score, and recruiting heterogeneous
pilot shapes per backlog width distribution.
"""
from repro.federation.fleet import (  # noqa: F401
    Fleet,
    FleetStagingView,
    build_fleet,
    make_pilot,
)
from repro.federation.recruiter import Recruiter  # noqa: F401
from repro.federation.session import FederatedSession  # noqa: F401
