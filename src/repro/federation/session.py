"""FederatedSession: late-binding dispatch of one task stream onto N pilots.

Subclasses :class:`RuntimeSession` and overrides exactly the dispatch
hooks the base exposes (``_rt_for``, ``_occupy``, ``_can_launch_real``,
``_fault_source``, ...) so the drain loops — DES event loop, real-mode
condition-variable loop, fault scans, zombie guards, speculation plumbing —
run UNCHANGED.  What federation adds:

* **Late binding**: a task is bound to a pilot at LAUNCH time, not submit
  time.  ``_dispatch`` scores every pilot with free capacity and picks the
  one minimizing estimated completion: modeled ``t_data`` to move the
  task's staged inputs there (0 for a pilot already holding a replica,
  ``cross_gbps`` for a pilot-to-pilot fetch, ``host_gbps`` from HOST),
  tie-broken by load, with blamed pilots (retry exclusion) last.
* **Per-pilot capacity accounts** (``_busy_by``/``_free_by``) beside the
  base session's global ones — dispatch feasibility is per pilot; a
  32-slot fleet of 4 pilots cannot host a 16-wide task.
* **Per-pilot journals**: ``session_start`` is written into EVERY pilot's
  journal (tagged with the pilot name) and replay at construction merges
  every pilot's ``load_state()`` — a crashed federated run reconstructs
  the whole fleet's progress from the per-pilot files.
* **Whole-pilot death** reuses the pod-failure machinery verbatim: each
  pod of the dead pilot is abandoned/retired/replica-dropped by the
  existing kill paths (pods carry their pilot's prefix, so routing is a
  name parse), the pilot bottoms out at 0 slots and stops receiving
  dispatches, and retries late-bind onto survivors.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.federation.fleet import Fleet, _FaultUnion
from repro.runtime.executor import PilotRuntime, RuntimeSession
from repro.runtime.states import Task, TaskState
from repro.staging.store import HOST


class FederatedSession(RuntimeSession):
    def __init__(self, fleet: Fleet, *, graph=None, on_task_done=None):
        self.fleet = fleet
        super().__init__(fleet, graph=graph, on_task_done=on_task_done)
        self._busy_by: Dict[str, int] = {}
        self._free_by: Dict[str, int] = {}
        self._started: set = set()
        self._init_done = False
        for name, rt in fleet.pilots.items():
            self._start_pilot(name, rt)
        self._init_done = True

    def _start_pilot(self, name: str, rt: PilotRuntime):
        """Open one pilot for dispatch: merge its journal's replay state
        into the session's, init its capacity accounts, and mark a new
        session segment in ITS journal (tagged — the sanitizer resets
        only this pilot's epoch state)."""
        done, results, history = rt.journal.load_state()
        self._replayed_done |= done
        self._replayed_results.update(results)
        for task, entries in history.items():
            self._replayed_history.setdefault(task, entries)
        self._busy_by[name] = 0
        self._free_by[name] = rt.slots
        if self._init_done and self.fleet.mode == "real":
            self._free["n"] += rt.slots     # joined mid-session: new capacity
        self._started.add(name)
        if self.fleet.mode == "sim":
            # per-pilot journals are time-faithful too: every record this
            # pilot writes carries the fleet session's virtual clock
            rt.journal.vclock = lambda: self.vnow
        tr = self.tracer
        if tr is not None:
            tr.metrics.gauge(f"pilot_busy:{name}",
                             lambda n=name: self.pilot_busy(n))
            if self._init_done:            # recruited mid-run, not seeded
                tr.instant("pilot", f"recruit:{name}", self._now(),
                           pilot=name, slots=rt.slots)
        rt.journal.record_event("session_start", mode=rt.mode,
                                slots=rt.slots)

    def _sync_pilots(self):
        for name, rt in self.fleet.pilots.items():
            if name not in self._started:
                self._start_pilot(name, rt)

    def on_pilot_retired(self, name: str):
        """Recruiter shrink notification: the pilot's free capacity
        leaves the global real-mode account (its per-pilot account zeroes
        so a later revival cannot double-credit)."""
        if self.fleet.mode == "real":
            self._free["n"] -= max(self._free_by.get(name, 0), 0)
        self._free_by[name] = 0
        if self.tracer is not None:
            self.tracer.instant("pilot", f"retire:{name}", self._now(),
                                pilot=name)

    def pilot_busy(self, name: str) -> int:
        if self.fleet.mode == "sim":
            return self._busy_by.get(name, 0)
        rt = self.fleet.pilots[name]
        return max(rt.slots - self._free_by.get(name, 0), 0)

    @property
    def busy_slots(self) -> int:
        if self.fleet.mode == "sim":
            return self._busy
        return sum(self.pilot_busy(n) for n in self.fleet.active())

    # ------------------------------------------------------- dispatch hooks
    def _rt_for(self, t: Task) -> PilotRuntime:
        return self.fleet.runtime_for_task(t)

    def _rt_for_pod(self, pod: str) -> PilotRuntime:
        rt = self.fleet.runtime_for_pod(pod)
        return rt if rt is not None else next(
            iter(self.fleet.pilots.values()))

    def _occupy(self, t: Task):
        self._busy += t.slots
        name = t.meta.get("pilot")
        if name in self._busy_by:
            self._busy_by[name] += t.slots

    def _vacate(self, t: Task):
        self._busy -= t.slots
        name = t.meta.get("pilot")
        if name in self._busy_by:
            self._busy_by[name] -= t.slots

    def _debit_free(self, t: Task):
        self._free["n"] -= t.slots
        name = t.meta.get("pilot")
        if name in self._free_by:
            self._free_by[name] -= t.slots

    def _credit_free(self, t: Task):
        self._free["n"] += t.slots
        name = t.meta.get("pilot")
        if name in self._free_by:
            self._free_by[name] += t.slots

    def _credit_free_n(self, rt: PilotRuntime, n: int):
        self._free["n"] += n
        name = getattr(rt, "_fleet_name", None)
        if name in self._free_by:
            self._free_by[name] += n

    def _can_launch_real(self, t: Task) -> bool:
        name = self._dispatch(t, self._free_by)
        if name is None:
            return False
        t.meta["pilot"] = name        # late binding happens HERE
        if self.tracer is not None:
            self.tracer.instant("dispatch", t.name, self._now(), pilot=name)
        return True

    def _too_wide_sim(self, t: Task) -> bool:
        active = self.fleet.active().values()
        return (all(t.slots > rt.slots for rt in active)
                if active else True)

    _too_wide_real = _too_wide_sim

    def _fault_source(self):
        injectors = [rt.faults for rt in self.fleet.pilots.values()
                     if rt.faults is not None]
        if not injectors:
            return None
        if len(injectors) == 1:
            return injectors[0]
        return _FaultUnion(injectors)

    def _housekeeping_sim(self):
        fleet = self.fleet
        self._sync_pilots()
        if fleet.recruiter is not None:
            # with an empty event heap the virtual clock only advances
            # here: jump to a pending recruit's arrival so starved tasks
            # wait for the incoming pilot instead of being canceled
            if not self._heap and not self.graph.done():
                arrival = fleet.recruiter.next_arrival()
                if arrival is not None:
                    self.vnow = max(self.vnow, arrival)
            fleet.recruiter.tick(fleet, self, self.vnow)
            self._sync_pilots()
        for rt in fleet.pilots.values():
            if rt.on_schedule is not None:
                rt.on_schedule(rt, self.graph, self.vnow)
            rt._apply_resize()
            rt._apply_topology_drop()
            # resize/compaction changed rt.slots: dispatch reads it live,
            # sim busy accounting needs no reconciliation

    def _housekeeping_real(self):
        fleet = self.fleet
        self._sync_pilots()
        if fleet.recruiter is not None:
            fleet.recruiter.tick(fleet, self,
                                 time.perf_counter() - self._t0)
            self._sync_pilots()
        for name, rt in fleet.pilots.items():
            if rt.on_schedule is not None:
                rt.on_schedule(rt, self.graph, None)
            delta = rt._apply_resize()
            if delta:
                self._credit_free_n(rt, delta)
            rt._apply_topology_drop()

    # ------------------------------------------------------------ dispatch
    def _est_t_data(self, t: Task, name: str, rt: PilotRuntime) -> float:
        """Modeled seconds to move ``t``'s staged inputs into pilot
        ``name``: 0 when a replica already lives in one of its pods
        (stage-in will link), else a pilot-to-pilot fetch at
        ``cross_gbps`` when any pod replica exists, else the host link."""
        entries = t.meta.get("staged_refs")
        if not entries or rt.staging is None:
            return 0.0
        planner = rt.staging.planner
        prefix = f"{name}:"
        total = 0.0
        for _kind, _key, ref in entries:
            locations = (planner.store.locations(ref.digest)
                         or set(ref.locations))
            pods = [loc for loc in locations if loc != HOST]
            if any(p.startswith(prefix) for p in pods):
                continue
            gbps = planner.cross_gbps if pods else planner.host_gbps
            total += planner.copy_latency_s + ref.nbytes / (gbps * 1e9)
        return total

    def _dispatch(self, t: Task, free: Dict[str, int]) -> Optional[str]:
        """Pick the pilot minimizing estimated completion for ``t`` among
        those with ``t.slots`` free NOW (late binding: the decision uses
        the replica map and load as they are at launch).  Returns None
        when no pilot currently fits — the caller requeues."""
        excluded = t.excluded_pods() if t.history else ()
        best = best_key = None
        for name, rt in self.fleet.active().items():
            if free.get(name, 0) < t.slots:
                continue
            blamed = 1 if any(p.startswith(f"{name}:")
                              for p in excluded) else 0
            load = 1.0 - free[name] / max(rt.slots, 1)
            key = (blamed, self._est_t_data(t, name, rt), load, name)
            if best_key is None or key < best_key:
                best, best_key = name, key
        return best

    # ---------------------------------------------------------- preemption
    # Per-pilot variants of the base session's preemption: capacity is a
    # per-pilot account here, so the deficit arithmetic and the victim
    # pool are scoped to one pilot, and a successful eviction binds the
    # high-priority task to the pilot it made room on.

    def _preempt_enabled(self, t: Task) -> bool:
        return t.priority > 0 and any(
            rt.preempt for rt in self.fleet.active().values())

    def _preempt_sim_for(self, t: Task) -> bool:
        for name, rt in self.fleet.active().items():
            if not rt.preempt or t.slots > rt.slots:
                continue
            need = t.slots - (rt.slots - self._busy_by.get(name, 0))
            victims = [] if need <= 0 else self._preempt_victims(
                t, need, [v for v in self._sim_running_tasks()
                          if v.meta.get("pilot") == name])
            if victims is None:
                continue
            for v in victims:
                self._preempt_sim(v)
            t.meta["pilot"] = name     # bind to the pilot we made room on
            return True
        return False

    def _preempt_real_for(self, t: Task) -> bool:
        for name, rt in self.fleet.active().items():
            if not rt.preempt or t.slots > rt.slots:
                continue
            need = t.slots - self._free_by.get(name, 0)
            victims = [] if need <= 0 else self._preempt_victims(
                t, need,
                [v for (_, epoch), (_th, v) in self._live_attempts.items()
                 if v.meta.get("launch_epoch") == epoch
                 and v.state == TaskState.RUNNING
                 and v.meta.get("pilot") == name])
            if victims is None:
                continue
            for v in victims:
                self._preempt_real(v)
            return True              # _can_launch_real re-dispatches
        return False

    def _schedule_sim(self):
        graph = self.graph
        if any(rt.preempt for rt in self.fleet.active().values()):
            # before the min-width gate: a saturated fleet is exactly
            # when a latency task needs the eviction path
            self._preempt_pass_sim()
        active = self.fleet.active()
        free = {n: rt.slots - self._busy_by.get(n, 0)
                for n, rt in active.items()}
        widest = max(free.values(), default=0)
        min_w = graph.frontier_min_width()
        if min_w is None or min_w > widest:
            return
        # bounded lookahead, as in the locality pass: pop enough ready
        # tasks to fill every free slot plus headroom, dispatch each to
        # its best pilot, hand the unplaceable back
        avail = sum(f for f in free.values() if f > 0)
        cands: List[Task] = []
        while len(cands) < avail + 16:
            t = graph.pop_ready()
            if t is None:
                break
            cands.append(t)
        for t in cands:
            name = self._dispatch(t, free)
            if name is None:
                graph.requeue(t)
                continue
            free[name] -= t.slots
            t.meta["pilot"] = name        # late binding happens HERE
            if self.tracer is not None:
                self.tracer.instant("dispatch", t.name, self.vnow,
                                    pilot=name)
            self._launch_sim(t)

    def _locality_candidates(self, avail: int) -> List[Task]:
        """Real-mode lookahead ordering across the fleet: tasks ranked by
        the CHEAPEST pilot's modeled stage-in cost (input-local anywhere
        beats copy-everywhere); per-task pilot choice still happens in
        ``_can_launch_real``."""
        graph = self.graph
        cands: List[Task] = []
        if avail <= 0:
            return cands
        min_w = graph.frontier_min_width()
        if min_w is None or min_w > avail:
            return cands
        while len(cands) < avail + 16:
            t = graph.pop_ready()
            if t is None:
                break
            cands.append(t)
        active = self.fleet.active()
        cands.sort(key=lambda c: (min(
            (self._est_t_data(c, n, rt) for n, rt in active.items()),
            default=0.0), c.tid))
        return cands
