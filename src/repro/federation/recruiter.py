"""Backlog-driven fleet autoscaler (the scitq ``create_recruiter`` shape).

Where :class:`~repro.runtime.strategy.AdaptiveSlotStrategy` resizes ONE
pilot, the Recruiter resizes the FLEET: it watches the ready-queue backlog
(``TaskGraph.frontier_slots()`` — total slot width waiting to run) against
active capacity, and spins whole pilots up or down within a slot budget.

Anti-thrash mechanics:

* **Hysteresis** — after any change (spawn ordered, pilot joined, pilot
  retired) the recruiter holds its decision for ``hysteresis_s``.  It
  must be at least ``spinup_s``: deciding again before the pilot you
  ordered arrives means re-reacting to the backlog you already bought
  capacity for (the validator's W205 flags that configuration).
* **Modeled spin-up** — a spawn is not instant: the pilot joins
  ``spinup_s`` after the decision (virtual clock in sim, wall clock in
  real mode), so the TTC cost of elasticity is accounted.
* **Shrink only when idle** — a pilot is retired only when the backlog
  is empty, fleet utilization is below ``shrink_idle_frac``, and that
  pilot runs nothing; its staged replicas and journal stay addressable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class Recruiter:
    min_pilots: int = 1
    max_pilots: int = 4
    #: slots of each pilot the factory builds (the fleet's pilot_factory
    #: decides the real shape; this is the recruiter's planning model)
    slots_per_pilot: int = 8
    #: hard ceiling on total fleet slots (active + pending spawns)
    budget_slots: int = 32
    #: minimum seconds between fleet-size decisions
    hysteresis_s: float = 30.0
    #: seconds between ordering a pilot and it joining
    spinup_s: float = 10.0
    #: grow when backlog slots exceed this multiple of active capacity
    grow_backlog_factor: float = 2.0
    #: shrink when backlog is 0 and busy/capacity is at or below this
    shrink_idle_frac: float = 0.05
    #: decision log: {"t", "action": spawn|join|retire, ...}
    events: List[Dict] = field(default_factory=list, repr=False)
    _pending: List[float] = field(default_factory=list, repr=False)
    _last_change: float = field(default=float("-inf"), repr=False)

    def next_arrival(self) -> Optional[float]:
        return min(self._pending) if self._pending else None

    def tick(self, fleet, session, now: float):
        """One decision step, called from the session's housekeeping pass
        (``now`` is virtual in sim, wall-elapsed in real mode)."""
        due = [t for t in self._pending if t <= now]
        if due:
            self._pending = [t for t in self._pending if t > now]
            for _ in due:
                name = fleet.add_pilot()
                self.events.append({"t": now, "action": "join",
                                    "pilot": name})
                self._last_change = now
        if now - self._last_change < self.hysteresis_s:
            return
        active = fleet.active()
        total = sum(rt.slots for rt in active.values())
        backlog = session.graph.frontier_slots()
        pending_slots = len(self._pending) * self.slots_per_pilot
        if (backlog > self.grow_backlog_factor * max(total, 1)
                and fleet.pilot_factory is not None
                and len(active) + len(self._pending) < self.max_pilots
                and total + pending_slots + self.slots_per_pilot
                <= self.budget_slots):
            self._pending.append(now + self.spinup_s)
            self.events.append({"t": now, "action": "spawn",
                                "arrives": now + self.spinup_s,
                                "backlog_slots": backlog})
            self._last_change = now
            return
        if (backlog == 0 and not self._pending
                and len(active) > self.min_pilots
                and session.busy_slots
                <= self.shrink_idle_frac * max(total, 1)):
            # retire the newest idle pilot: oldest pilots hold the most
            # replicas, so they are the worst candidates to drop
            for name in reversed(list(active)):
                if session.pilot_busy(name) == 0:
                    fleet.retire_pilot(name)
                    session.on_pilot_retired(name)
                    self.events.append({"t": now, "action": "retire",
                                        "pilot": name})
                    self._last_change = now
                    return

    # ------------------------------------------------------------ summary
    def summary(self) -> Dict[str, int]:
        actions = [e["action"] for e in self.events
                   if e["action"] in ("spawn", "retire")]
        # thrash = re-buying capacity just dropped (retire -> spawn);
        # spawn -> retire is the normal end-of-campaign wind-down
        flips = sum(1 for a, b in zip(actions, actions[1:])
                    if a == "retire" and b == "spawn")
        return {"n_spawned": actions.count("spawn"),
                "n_retired": actions.count("retire"),
                "n_joined": sum(1 for e in self.events
                                if e["action"] == "join"),
                "direction_flips": flips,
                "n_pending": len(self._pending)}
