"""Fleet: N heterogeneous pilots behind one runtime-shaped facade.

A :class:`Fleet` owns an ordered set of named :class:`PilotRuntime`\\ s —
different slot counts, different meshes, each with its OWN journal — and
duck-types the exact surface ``AppManager`` (repro.core.pst) and
``RuntimeSession`` speak: ``mode``, ``slots``, ``journal``, ``staging``,
``topology``, ``session()``, ``live_pods()``, ``max_retries``, ``close()``.
Existing PST applications run federated by constructing their manager with
a Fleet instead of a PilotRuntime — no API change.

Namespacing invariant: every pilot's pods are prefixed with its name
(``p1:pod0``), either through its staging ``LocalityMap(prefix=...)`` or
through ``PilotRuntime._pod_prefix``.  Replica locations, retry
exclusions, fault injection and journal records all key on pod names, so
the prefix is the ONLY plumbing federation needs — everything downstream
already treats pods as opaque strings.

Staged pilots must share one :class:`ObjectStore`/:class:`TransferPlanner`
(enforced at construction): that is what makes a pilot-to-pilot blob fetch
a planner ``copy`` at ``cross_gbps`` instead of a round-trip through the
manager, and what lets the dispatcher see where every replica lives.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Dict, Iterable, List, Optional, Union

from repro.runtime.executor import PilotRuntime, RuntimeProfile
from repro.runtime.journal import Journal, journal_from_env
from repro.runtime.states import TaskGraph
from repro.staging.transfer import LocalityMap, pilot_of


class FleetStagingView:
    """AppManager-facing staging facade over the pilots' layers.

    Task-scoped calls (``location_for``, ``resolve``) route through the
    task's OWN pilot's layer — its locality map carries that pilot's pod
    prefix, so a task dispatched to p2 stages to ``p2:pod*``.  Everything
    else (store, planner, thresholds, manifests, channel-put staging)
    delegates to the first staged pilot's layer, which is safe because
    all layers share one store and one planner.
    """

    def __init__(self, fleet: "Fleet"):
        self._fleet = fleet

    @property
    def _primary(self):
        for rt in self._fleet.pilots.values():
            if rt.staging is not None:
                return rt.staging
        raise AttributeError("no pilot in this fleet has a staging layer")

    def _layer_for(self, task):
        rt = self._fleet.runtime_for_task(task)
        return rt.staging if rt.staging is not None else self._primary

    def location_for(self, task):
        return self._layer_for(task).location_for(task)

    def resolve(self, task, ref):
        return self._layer_for(task).resolve(task, ref)

    def __getattr__(self, attr):
        return getattr(self._primary, attr)


class _FaultUnion:
    """Aggregate injector view over every pilot's FaultInjector — the
    drain loops consult ONE fault source; per-pod handling still routes
    to the owning pilot via the pod-name prefix."""

    def __init__(self, injectors):
        self._injectors = list(injectors)

    def next_time(self) -> Optional[float]:
        times = [t for inj in self._injectors
                 if (t := inj.next_time()) is not None]
        return min(times) if times else None

    def pending_revive(self) -> bool:
        return any(inj.pending_revive() for inj in self._injectors)

    def pop_due(self, now: float) -> List[tuple]:
        due: List[tuple] = []
        for inj in self._injectors:
            due.extend(inj.pop_due(now))
        return due


class _DigestUnion:
    """Journal shim for spill GC: the keep set of a federated run is the
    union of every journal's referenced digests (a blob journaled by p1
    may be the restart input of a task that will re-dispatch to p2)."""

    def __init__(self, journals: Iterable[Journal]):
        self._journals = list(journals)

    def load_digests(self) -> set:
        digests: set = set()
        for j in self._journals:
            digests |= j.load_digests()
        return digests


class Fleet:
    """N named pilots + one fleet journal + (optionally) a Recruiter.

    ``pilots`` is a name->PilotRuntime dict (or an iterable, auto-named
    ``p1..pN``).  All pilots must share one mode; staged pilots must share
    one ObjectStore.  ``pilot_factory(name) -> PilotRuntime`` lets the
    recruiter spin up replacements/additions mid-run.
    """

    def __init__(self, pilots: Union[Dict[str, PilotRuntime],
                                     Iterable[PilotRuntime]], *,
                 journal: Optional[Journal] = None,
                 recruiter=None,
                 tracer=None,
                 pilot_factory: Optional[Callable[[str], PilotRuntime]]
                 = None):
        if not isinstance(pilots, dict):
            pilots = {f"p{i + 1}": rt for i, rt in enumerate(pilots)}
        if not pilots:
            raise ValueError("a fleet needs at least one pilot")
        modes = {rt.mode for rt in pilots.values()}
        if len(modes) != 1:
            raise ValueError(f"pilots mix modes {sorted(modes)}: a fleet "
                             "runs all-sim or all-real")
        self.mode = modes.pop()
        self.journal = journal if journal is not None else Journal(None)
        self.recruiter = recruiter
        # flight recorder (repro.obs.Tracer) shared by the whole fleet:
        # dispatch decisions, recruit/retire and every pilot's attempt
        # spans land in ONE trace
        self.tracer = tracer
        self.pilot_factory = pilot_factory
        self.pilots: Dict[str, PilotRuntime] = {}
        self.retired: set = set()
        self._by_prefix: Dict[str, PilotRuntime] = {}
        self._next_auto = len(pilots)
        for name, rt in pilots.items():
            self._admit(name, rt)
        stores = {id(rt.staging.store) for rt in self.pilots.values()
                  if rt.staging is not None}
        if len(stores) > 1:
            raise ValueError(
                "staged pilots must share one ObjectStore (and planner): "
                "pilot-to-pilot blob fetch and the dispatcher's replica "
                "view both need a single content-addressed namespace — "
                "build pilots via repro.federation.make_pilot/build_fleet")
        self._staging_view = FleetStagingView(self) if stores else None

    # ------------------------------------------------------------ membership
    def _admit(self, name: str, rt: PilotRuntime):
        if name in self.pilots:
            raise ValueError(f"pilot name {name!r} already in the fleet")
        prefix = f"{name}:"
        if rt.staging is not None and rt.staging.locality is not None:
            loc = rt.staging.locality
            if loc.prefix != prefix:
                if loc.prefix:
                    raise ValueError(
                        f"pilot {name!r} locality prefix {loc.prefix!r} "
                        f"does not match its fleet name ({prefix!r})")
                rt.staging.locality = replace(loc, prefix=prefix)
                rt.staging.planner.locality = rt.staging.locality
        else:
            rt._pod_prefix = prefix
        rt._fleet_name = name
        if rt.journal.tag is None:
            rt.journal.tag = name
        self.pilots[name] = rt
        self._by_prefix[prefix] = rt

    def add_pilot(self, name: Optional[str] = None,
                  rt: Optional[PilotRuntime] = None) -> str:
        """Admit one more pilot (recruiter path: built by the factory).
        Returns its name; a live FederatedSession picks it up at its next
        housekeeping pass."""
        if name is None:
            self._next_auto += 1
            name = f"p{self._next_auto}"
            while name in self.pilots:
                self._next_auto += 1
                name = f"p{self._next_auto}"
        if rt is None:
            if self.pilot_factory is None:
                raise ValueError("no pilot_factory to build the new pilot")
            rt = self.pilot_factory(name)
        if rt.mode != self.mode:
            raise ValueError(f"pilot {name!r} mode {rt.mode!r} != fleet "
                             f"mode {self.mode!r}")
        self._admit(name, rt)
        self.journal.record_event("pilot_joined", pilot=name,
                                  slots=rt.slots)
        return name

    def retire_pilot(self, name: str):
        """Take a pilot out of dispatch (recruiter shrink).  The pilot
        object stays in ``pilots`` — its journal, staged replicas and any
        straggling bookkeeping remain addressable."""
        if name not in self.pilots:
            raise ValueError(f"unknown pilot {name!r}")
        self.retired.add(name)
        self.journal.record_event("pilot_retired", pilot=name)

    def active(self) -> Dict[str, PilotRuntime]:
        """Dispatchable pilots, in admission order."""
        return {n: rt for n, rt in self.pilots.items()
                if n not in self.retired}

    def runtime_for_task(self, task) -> PilotRuntime:
        """The pilot a task is (or was last) bound to; falls back to the
        first pilot for never-dispatched tasks (replayed/canceled ones)."""
        rt = self.pilots.get(task.meta.get("pilot"))
        if rt is not None:
            return rt
        return next(iter(self.pilots.values()))

    def runtime_for_pod(self, pod: str) -> Optional[PilotRuntime]:
        return self._by_prefix.get(pilot_of(pod))

    # ------------------------------------------------------------ facade
    @property
    def slots(self) -> int:
        """Aggregate active capacity (AppManager's utilization and the
        recruiter's budget both read this).  A single task can NOT span
        pilots — per-task width is bounded by one pilot's slots."""
        return sum(rt.slots for rt in self.active().values())

    @property
    def staging(self):
        return self._staging_view

    @property
    def topology(self):
        """Non-None only when every active pilot carries a device
        topology (AppManager gates ``ctx["submesh"]`` on this); the
        per-task mesh comes from the task's own pilot."""
        topos = [rt.topology for rt in self.active().values()]
        if topos and all(tp is not None for tp in topos):
            return topos[0]
        return None

    def submesh_for(self, task):
        return self.runtime_for_task(task).submesh_for(task)

    @property
    def max_retries(self) -> int:
        return max(rt.max_retries for rt in self.pilots.values())

    @property
    def straggler_factor(self) -> float:
        """Speculation stays per-pilot for now: a cross-pilot duplicate
        would need fleet-wide duration histories and a second staging
        manifest — a documented extension point, disabled federated."""
        return 0.0

    @property
    def dead_pods(self) -> set:
        dead: set = set()
        for rt in self.pilots.values():
            dead |= rt.dead_pods
        return dead

    def live_pods(self) -> List[str]:
        pods: set = set()
        for name, rt in self.active().items():
            pods.update(rt.live_pods())
        return sorted(pods)

    def resize(self, slots: int):
        raise ValueError(
            "a Fleet is resized by recruiting/retiring pilots (see "
            "repro.federation.Recruiter), not by resize(); resize "
            "individual pilots via fleet.pilots[name].resize()")

    # ------------------------------------------------------------ chaos
    def inject_pod_failure(self, pod: str):
        """Kill one (prefixed) pod at the next scheduling step."""
        rt = self.runtime_for_pod(pod)
        if rt is None:
            raise ValueError(f"pod {pod!r} matches no pilot prefix")
        rt.inject_pod_failure(pod)

    def inject_pilot_failure(self, name: str):
        """Whole-pilot death: every live pod of the pilot dies.  In-flight
        attempts are abandoned, its staged replicas are dropped, retries
        re-dispatch to surviving pilots, and the recruiter (if any) sees
        the lost capacity as backlog pressure and may replace it."""
        rt = self.pilots[name]
        for pod in rt.live_pods():
            rt.inject_pod_failure(pod)

    # ------------------------------------------------------------ sessions
    def session(self, *, on_task_done: Optional[Callable] = None):
        from repro.federation.session import FederatedSession
        return FederatedSession(self, on_task_done=on_task_done)

    def run(self, graph: TaskGraph) -> RuntimeProfile:
        """Closed-world federated execution of a prebuilt graph (parity
        with ``PilotRuntime.run``)."""
        from repro.federation.session import FederatedSession
        graph.validate()
        sess = FederatedSession(self, graph=graph)
        skipped = sum(sess._replay_task(t) for t in graph.tasks.values())
        if skipped:
            sess.prof.events.append({"event": "journal_skip", "n": skipped})
        return sess.drain()

    # ------------------------------------------------------------ shutdown
    def close(self, *, keep_durable: bool = True) -> int:
        """Close every pilot journal plus the fleet journal; GC spill
        files against the UNION of all journals' digests — any journal
        still naming a blob keeps its spill file restartable."""
        n = 0
        layer = self._staging_view._primary if self._staging_view else None
        if layer is not None:
            union = _DigestUnion([rt.journal for rt in self.pilots.values()]
                                 + [self.journal])
            n = layer.gc_spill(union, keep_durable=keep_durable)
        for rt in self.pilots.values():
            rt.journal.close()
        self.journal.close()
        return n

    # ------------------------------------------------------------ summary
    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "n_pilots": len(self.pilots),
            "n_active": len(self.active()),
            "n_retired": len(self.retired),
            "slots": self.slots,
            "pilot_slots": {n: rt.slots for n, rt in self.pilots.items()},
        }
        if self.recruiter is not None:
            out["recruiter"] = self.recruiter.summary()
        return out


# ------------------------------------------------------------ constructors
def make_pilot(name: str, *, slots: int, mode: str = "sim",
               store=None, planner=None, slots_per_pod: int = 1,
               threshold_bytes: int = 1 << 10,
               journal: Optional[Journal] = None,
               topology=None, faults=None, max_retries: int = 2,
               **kwargs) -> PilotRuntime:
    """One fleet-ready pilot: when a shared ``store``/``planner`` is
    given, the pilot gets its own StagingLayer with a ``{name}:``-prefixed
    locality over them."""
    staging = None
    if store is not None:
        from repro.staging import StagingLayer
        staging = StagingLayer(
            store=store, planner=planner,
            locality=LocalityMap(n_slots=slots, slots_per_pod=slots_per_pod,
                                 prefix=f"{name}:"),
            threshold_bytes=threshold_bytes)
    return PilotRuntime(slots=slots, mode=mode, staging=staging,
                        journal=journal, topology=topology, faults=faults,
                        max_retries=max_retries, **kwargs)


def build_fleet(n_pilots: int, *, slots: int = 8, mode: str = "sim",
                slots_per_pod: int = 1, staging: bool = True,
                threshold_bytes: int = 1 << 10,
                byte_budget: int = 1 << 40,
                spill_dir: Optional[str] = None,
                journal_base: Optional[str] = None,
                recruiter=None, max_retries: int = 2,
                **pilot_kwargs) -> Fleet:
    """Homogeneous starter fleet: ``n_pilots`` pilots of ``slots`` slots
    over ONE shared ObjectStore/TransferPlanner, per-pilot journals named
    ``{journal_base}-{name}`` (tagged with the pilot name — crash replay
    reconstructs the whole fleet from the files), and a ``pilot_factory``
    wired so a Recruiter can grow the fleet with identical pilots."""
    store = planner = None
    if staging:
        from repro.staging import ObjectStore, TransferPlanner
        store = ObjectStore(byte_budget=byte_budget, spill_dir=spill_dir)
        planner = TransferPlanner(store)

    def factory(name: str) -> PilotRuntime:
        journal = (journal_from_env(f"{journal_base}-{name}", tag=name)
                   if journal_base else None)
        return make_pilot(name, slots=slots, mode=mode, store=store,
                          planner=planner, slots_per_pod=slots_per_pod,
                          threshold_bytes=threshold_bytes, journal=journal,
                          max_retries=max_retries, **pilot_kwargs)

    pilots = {f"p{i + 1}": factory(f"p{i + 1}") for i in range(n_pilots)}
    fleet_journal = (journal_from_env(f"{journal_base}-fleet")
                     if journal_base else None)
    return Fleet(pilots, journal=fleet_journal, recruiter=recruiter,
                 pilot_factory=factory)
