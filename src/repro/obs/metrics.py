"""Metrics timelines: counter/gauge/histogram registry sampled on clock
ticks (virtual in sim, wall-elapsed in real mode).

The registry is pull-based: a subsystem registers a *gauge* as a zero-arg
closure over its live state (frontier depth, busy slots, channel backlog
bytes, staging hit-rate, per-pilot load) and the drain loop calls
:meth:`maybe_sample` once per clock advance.  Sampling is adaptively
decimated — when the timeline exceeds ``max_samples`` points every other
sample is dropped and the minimum sampling interval doubles — so a 100k-task
DES run keeps a bounded, evenly thinned timeline instead of one point per
event (this is what keeps the frontier-bench tracing overhead inside its
10% gate).

Counters are monotonic scalars (`inc`), histograms are streaming summaries
(n/sum/min/max + power-of-two buckets) — neither is per-tick, so both stay
O(1) in memory.  ``series()`` renders everything JSON-able; the AppManager
lands it in ``prof.results["timeseries"]``.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional

_frexp = math.frexp


class Histogram:
    """Streaming summary: n/sum/min/max + log2 buckets, O(1) per update.
    ``hist(name)`` hands the object out so hot paths (the tracer's
    per-attempt updates) skip the registry lookup."""

    __slots__ = ("n", "sum", "min", "max", "buckets")

    def __init__(self):
        self.n = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets: Dict[int, int] = {}

    def add(self, v: float):
        self.n += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        b = 0 if v <= 0 else _frexp(v)[1]           # log2 bucket exponent
        bk = self.buckets
        bk[b] = bk.get(b, 0) + 1


class MetricsTimeline:
    def __init__(self, *, max_samples: int = 2048,
                 min_interval: float = 0.0):
        self._gauges: Dict[str, Callable[[], float]] = {}
        self.counters: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}
        self.t: List[float] = []
        self.samples: Dict[str, List[Optional[float]]] = {}
        self.max_samples = max(int(max_samples), 8)
        self._interval = float(min_interval)
        # effective gap: never re-sample a clock that has not advanced
        # (the DES drain calls maybe_sample once per event; many events
        # share one virtual tick)
        self._min_gap = max(self._interval, 1e-12)
        self._last_t: Optional[float] = None

    # ------------------------------------------------------------ registry
    def gauge(self, name: str, fn: Callable[[], float]):
        """Register (or replace) a pull gauge.  A gauge registered mid-run
        backfills None for the ticks it missed, so every series stays
        aligned with ``t``."""
        self._gauges[name] = fn
        self.samples.setdefault(name, [None] * len(self.t))

    def inc(self, name: str, value: float = 1.0):
        self.counters[name] = self.counters.get(name, 0.0) + value

    def hist(self, name: str) -> Histogram:
        """The named :class:`Histogram`, created on first use."""
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    def observe(self, name: str, value: float):
        """Streaming histogram update (O(1); no per-sample storage)."""
        self.hist(name).add(float(value))

    # ------------------------------------------------------------ sampling
    def maybe_sample(self, now: float):
        """Sample every registered gauge unless the adaptive minimum
        interval since the last sample has not elapsed."""
        last = self._last_t
        if last is not None and now - last < self._min_gap:
            return
        self.sample(now)

    def sample(self, now: float):
        self._last_t = now
        self.t.append(now)
        for name, fn in self._gauges.items():
            try:
                v = fn()
            except Exception:      # noqa: BLE001 - a dying gauge must not
                v = None           # take the run down
            self.samples[name].append(v)
        if len(self.t) > self.max_samples:
            self._decimate()

    def _decimate(self):
        """Drop every other sample and double the minimum interval: the
        timeline stays bounded and evenly thinned however long the run."""
        self.t = self.t[::2]
        for name in self.samples:
            self.samples[name] = self.samples[name][::2]
        span = (self.t[-1] - self.t[0]) if len(self.t) > 1 else 0.0
        floor = span / self.max_samples if span > 0 else 1e-9
        self._interval = max(self._interval * 2, floor)
        self._min_gap = max(self._interval, 1e-12)

    # ------------------------------------------------------------ output
    def series(self) -> dict:
        """JSON-able snapshot: aligned gauge timelines, final counter
        values, histogram summaries."""
        hists = {}
        for name, h in self._hists.items():
            hists[name] = {
                "n": h.n, "sum": h.sum, "min": h.min, "max": h.max,
                "buckets": {str(k): v for k, v in h.buckets.items()},
                "mean": h.sum / h.n if h.n else 0.0}
        return {"t": list(self.t),
                "gauges": {k: list(v) for k, v in self.samples.items()},
                "counters": dict(self.counters),
                "histograms": hists,
                "n_samples": len(self.t)}
