"""TTC decomposition, Chrome-trace export, and critical-path analysis
over journal files.

The journal (repro.runtime.journal) already records every attempt's
lifecycle — ``scheduled`` opens an attempt, ``finished``/``failed``/
``pod_lost``/``preempted``/``canceled`` close it — and PR 10 made those
records time-faithful (``vt`` = virtual clock in sim, wall ``t``
otherwise) and slot-attributed (``slot_ids``, ``width``, ``pipeline``,
``deps``, ``v_ready``).  This module re-derives the run's full timeline
from that trace alone: no live Tracer needed, any journal from any past
run decomposes.

The decomposition identity, per slot row::

    w1 - w0  =  t_exec + t_data + t_sched + t_block + t_idle

is EXACT by construction (the five classes partition the slot's window;
``residual`` reports the floating-point leftover and the CLI gates it at
1e-6).  Gap classification uses global step functions swept over the
whole segment:

* some task is ready-but-not-running        -> ``t_sched``  (scheduler /
  packing delay: work existed, the slot sat empty)
* tasks pending on deps, or a pipeline
  parked on an unsatisfiable input          -> ``t_block``
* neither                                   -> ``t_idle``   (tail / drain)

Truncated attempts (preemption, pod loss, supersession, cancelation) end
their span at the truncation record — never an overlap — and their exec
seconds are additionally tallied as ``t_exec_lost`` (wasted work the
retry must redo).
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Any, Dict, List, Optional, Tuple

_CLOSERS = {
    "pod_lost": "pod_lost",
    "worker_died": "worker_died",
    "heartbeat_timeout": "heartbeat_timeout",
    "preempted": "preempted",
    "canceled": "canceled",
}
#: outcomes whose exec seconds count as lost work
_LOST = ("pod_lost", "worker_died", "heartbeat_timeout", "preempted",
         "canceled", "superseded", "failed", "open")
#: Perfetto/Chrome reserved color names per piece kind / outcome
_COLORS = {
    "exec": "thread_state_running",
    "data": "thread_state_iowait",
    "sched": "thread_state_runnable",
    "block": "bad",
    "idle": "thread_state_sleeping",
    "preempted": "terrible",
    "pod_lost": "terrible",
    "worker_died": "terrible",
    "heartbeat_timeout": "terrible",
    "failed": "terrible",
    "canceled": "grey",
    "superseded": "yellow",
    "open": "grey",
}


class Segment:
    """One session segment of one journal: paired attempt spans, park
    intervals, instants, and the dep/readiness metadata the decomposition
    and critical-path walks consume."""

    def __init__(self, index: int = 0):
        self.index = index
        self.clock: str = "wall"            # "vt" once a vt record shows up
        self.spans: List[Dict[str, Any]] = []
        self.instants: List[Dict[str, Any]] = []
        self.parks: List[Dict[str, Any]] = []
        self.deps: Dict[str, List[str]] = {}
        self.ready_at: Dict[Tuple[str, int], float] = {}
        self.terminal_at: Dict[str, float] = {}
        #: dynamic tasks only (``submitted`` records); static tasks are
        #: pending from the segment's start
        self.submitted_at: Dict[str, float] = {}
        self.w0 = math.inf
        self.w1 = -math.inf
        self.n_records = 0
        self._open: Dict[Tuple[str, int], Dict[str, Any]] = {}
        self._park_open: Dict[str, Dict[str, Any]] = {}
        self._wall_base: Optional[float] = None

    # ------------------------------------------------------------ time
    def _time(self, rec: dict) -> Optional[float]:
        vt = rec.get("vt")
        if vt is not None:
            self.clock = "vt"
            return float(vt)
        if self.clock == "vt":
            return None                  # stray wall record in a vt segment
        t = rec.get("t")
        if t is None:
            return None
        if self._wall_base is None:
            self._wall_base = float(t)
        return float(t) - self._wall_base

    def _touch(self, t: Optional[float]):
        if t is not None:
            self.w0 = min(self.w0, t)
            self.w1 = max(self.w1, t)

    # ------------------------------------------------------------ ingest
    def observe(self, rec: dict):
        self.n_records += 1
        t = self._time(rec)
        self._touch(t)
        ev = rec.get("event")
        task = rec.get("task")
        if task is None:
            if ev == "pipeline_parked":
                self._park(rec, t)
            elif ev == "pipeline_woken":
                self._wake(rec, t)
            elif ev in ("pod_lost", "pod_revived", "topology_compacted") \
                    and t is not None:
                self.instants.append({"name": ev, "t": t,
                                      "pod": rec.get("pod"),
                                      "n_slots": rec.get("n_slots")})
            return
        if t is None:
            return
        att = int(rec.get("attempts", 1))
        if ev == "submitted":
            self.submitted_at.setdefault(task, t)
        elif ev == "scheduled":
            self._on_scheduled(task, att, t, rec)
        elif ev == "finished":
            if rec.get("by") is not None:
                self._close(task, att, t, "superseded")
            elif rec.get("state") == "DONE":
                sp = self._close(task, att,
                                 float(rec.get("v_finished", t)), "done")
                if sp is not None:
                    if "v_started" in rec:
                        sp["t0"] = float(rec["v_started"])
                    sp["t_data"] = float(rec.get("t_data", 0.0))
                self.terminal_at[task] = t
        elif ev == "failed":
            self._close(task, att, t, "failed")
            if rec.get("state") == "FAILED":
                self.terminal_at[task] = t
        elif ev in _CLOSERS:
            self._close(task, att, t, _CLOSERS[ev])
            if rec.get("state") == "CANCELED":
                self.terminal_at[task] = t

    def _on_scheduled(self, task: str, att: int, t: float, rec: dict):
        sp = {"task": task, "attempt": att, "t0": t, "t1": None,
              "outcome": None, "pod": rec.get("pod"),
              "pilot": rec.get("pilot"), "pipeline": rec.get("pipeline"),
              "slot_ids": rec.get("slot_ids"),
              "width": int(rec.get("width", 1)),
              "t_data": float(rec.get("t_data", 0.0))}
        self._open[(task, att)] = sp
        if rec.get("deps"):
            self.deps[task] = list(rec["deps"])
        ready = rec.get("v_ready")
        if ready is not None:
            self.ready_at[(task, att)] = float(ready)

    def _close(self, task: str, att: int, t: float, outcome: str):
        sp = self._open.pop((task, att), None)
        if sp is None:
            return None                   # duplicate closer (failed after
        sp["t1"] = max(t, sp["t0"])       # pod_lost) — first close wins
        sp["outcome"] = outcome
        self.spans.append(sp)
        return sp

    def _park(self, rec: dict, t: Optional[float]):
        if t is None:
            return
        pk = {"pipeline": rec.get("pipeline"), "on": rec.get("on"),
              "t0": t, "t1": None}
        self._park_open[rec.get("pipeline")] = pk
        self.parks.append(pk)

    def _wake(self, rec: dict, t: Optional[float]):
        pk = self._park_open.pop(rec.get("pipeline"), None)
        if pk is not None and t is not None:
            pk["t1"] = max(t, pk["t0"])

    # ------------------------------------------------------------ close
    def finish(self):
        """Seal the segment: spans/parks still open truncate at ``w1``
        (crash artifact, or a pipeline parked forever)."""
        if not math.isfinite(self.w0):
            self.w0, self.w1 = 0.0, 0.0
        self.n_open = len(self._open)
        for sp in self._open.values():
            sp["t1"] = max(self.w1, sp["t0"])
            sp["outcome"] = "open"
            self.spans.append(sp)
        self._open = {}
        for pk in self._park_open.values():
            pk["t1"] = max(self.w1, pk["t0"])
        self._park_open = {}
        self.spans.sort(key=lambda s: (s["t0"], s["task"], s["attempt"]))
        return self


def load_segments(path: str) -> List[Segment]:
    """Parse one journal file into its session segments (``session_start``
    bounds a segment; a crash-restart journal yields several — the FINAL
    one is the run that completed).  Torn trailing lines are skipped,
    exactly as the replay parsers do."""
    segments: List[Segment] = [Segment(0)]
    with open(path) as f:
        for line in f:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if rec.get("event") == "session_start":
                if segments[-1].n_records:
                    segments.append(Segment(len(segments)))
                seg = segments[-1]
                seg.observe(rec)
                continue
            segments[-1].observe(rec)
    return [seg.finish() for seg in segments]


def segment_from_tracer(tracer) -> Segment:
    """Build a Segment from a live :class:`~repro.obs.tracer.Tracer` —
    the no-journal path for Chrome export (``prof`` in hand, no file)."""
    seg = Segment(0)
    seg.clock = "vt" if tracer.clock == "virtual" else "wall"
    for sp in tracer.spans:
        if sp["cat"] == "task":
            rec = {"task": sp["task"], "attempt": sp["attempt"],
                   "t0": sp["t0"], "t1": sp["t1"],
                   "outcome": sp["outcome"], "pod": sp.get("pod"),
                   "pilot": sp.get("pilot"),
                   "pipeline": sp.get("pipeline"),
                   "slot_ids": sp.get("slots"),
                   "width": sp.get("width", 1),
                   "t_data": sp.get("t_data", 0.0)}
            seg.spans.append(rec)
            if rec["outcome"] in ("done", "failed"):
                seg.terminal_at[rec["task"]] = rec["t1"]
        elif sp["cat"] == "park":
            seg.parks.append({"pipeline": sp.get("pipeline"),
                              "on": sp.get("on"),
                              "t0": sp["t0"], "t1": sp["t1"]})
        seg.w0 = min(seg.w0, sp["t0"])
        seg.w1 = max(seg.w1, sp["t1"])
    for ev in tracer.events:
        seg.instants.append({"name": ev["name"], "t": ev["t"],
                             "pod": ev.get("pod"),
                             "n_slots": ev.get("n_slots")})
        seg.w0 = min(seg.w0, ev["t"])
        seg.w1 = max(seg.w1, ev["t"])
    seg.n_records = len(seg.spans) + len(seg.instants)
    return seg.finish()


# ---------------------------------------------------------------- classify
def _classified_intervals(seg: Segment):
    """Sweep the segment's global step functions into a list of
    ``(a, b, cls)`` elementary intervals with cls in sched|block|idle."""
    deltas: Dict[float, List[int]] = {}

    def add(t0: float, t1: float, idx: int):
        if t1 <= t0:
            return
        deltas.setdefault(t0, [0, 0, 0, 0])[idx] += 1
        deltas.setdefault(t1, [0, 0, 0, 0])[idx] -= 1

    by_attempt = {(s["task"], s["attempt"]): s for s in seg.spans}
    for key, ready in seg.ready_at.items():
        sp = by_attempt.get(key)
        if sp is not None:
            add(ready, sp["t0"], 0)                       # ready, unlaunched
    tasks = ({s["task"] for s in seg.spans}
             | set(seg.terminal_at) | set(seg.submitted_at))
    for task in tasks:                                    # pending
        add(seg.submitted_at.get(task, seg.w0),
            seg.terminal_at.get(task, seg.w1), 1)
    for sp in seg.spans:
        add(sp["t0"], sp["t1"], 2)                        # running
    for pk in seg.parks:
        add(pk["t0"], pk["t1"] if pk["t1"] is not None else seg.w1, 3)

    times = sorted(deltas)
    out: List[Tuple[float, float, str]] = []
    ready = pending = running = parked = 0
    for i, tt in enumerate(times):
        d = deltas[tt]
        ready += d[0]
        pending += d[1]
        running += d[2]
        parked += d[3]
        if i + 1 < len(times):
            if ready > 0:
                cls = "sched"
            elif pending - running - ready > 0 or parked > 0:
                cls = "block"
            else:
                cls = "idle"
            out.append((tt, times[i + 1], cls))
    return out


def _gap_pieces(classes, starts, g0: float, g1: float):
    """Split gap [g0, g1) by the classified intervals (idle when the gap
    outruns the classified range — e.g. [w0, first event))."""
    pieces: List[Tuple[float, float, str]] = []
    if g1 - g0 <= 0:
        return pieces
    i = max(bisect.bisect_right(starts, g0) - 1, 0)
    cur = g0
    while cur < g1 and i < len(classes):
        a, b, cls = classes[i]
        if b <= cur:
            i += 1
            continue
        if a >= g1:
            break
        lo, hi = max(a, cur), min(b, g1)
        if lo > cur:
            pieces.append((cur, lo, "idle"))
        if hi > lo:
            pieces.append((lo, hi, cls))
        cur = hi
        i += 1
    if cur < g1:
        pieces.append((cur, g1, "idle"))
    # merge adjacent same-class pieces
    merged: List[List] = []
    for p in pieces:
        if merged and merged[-1][2] == p[2] and \
                abs(merged[-1][1] - p[0]) < 1e-12:
            merged[-1][1] = p[1]
        else:
            merged.append(list(p))
    return [tuple(p) for p in merged]


# ---------------------------------------------------------------- lanes
def _slot_rows(seg: Segment) -> Dict[Tuple, List[dict]]:
    """Group spans into slot rows: by granted ``slot_ids`` when the
    journal carries them, else deterministic greedy lane packing per
    pilot (a width-w span occupies w lanes — slot-seconds semantics)."""
    rows: Dict[Tuple, List[dict]] = {}
    lanes: Dict[Optional[str], List[float]] = {}   # pilot -> lane free_at
    for sp in seg.spans:                           # already (t0, task)-sorted
        ids = sp.get("slot_ids")
        if ids:
            for sid in ids:
                rows.setdefault((sp.get("pilot"), f"slot{sid:04d}"),
                                []).append(sp)
            continue
        pool = lanes.setdefault(sp.get("pilot"), [])
        grant = [i for i, free in enumerate(pool)
                 if free <= sp["t0"] + 1e-9][:sp["width"]]
        while len(grant) < sp["width"]:
            pool.append(-math.inf)
            grant.append(len(pool) - 1)
        for i in grant:
            pool[i] = sp["t1"]
            rows.setdefault((sp.get("pilot"), f"lane{i:04d}"),
                            []).append(sp)
    return rows


# ---------------------------------------------------------------- decompose
def decompose(seg: Segment) -> dict:
    """Exact TTC decomposition of one segment: per slot row,
    ``t_exec + t_data + t_sched + t_block + t_idle == w1 - w0``
    (``residual`` is the float leftover; the CLI gates it at 1e-6)."""
    w0, w1 = seg.w0, seg.w1
    classes = _classified_intervals(seg)
    starts = [c[0] for c in classes]
    slots: Dict[str, dict] = {}
    for (pilot, lane), spans in sorted(
            _slot_rows(seg).items(),
            key=lambda kv: (kv[0][0] or "", kv[0][1])):
        label = f"{pilot}:{lane}" if pilot else lane
        comp = {"t_exec": 0.0, "t_data": 0.0, "t_sched": 0.0,
                "t_block": 0.0, "t_idle": 0.0, "t_exec_lost": 0.0,
                "n_attempts": 0, "n_preempted": 0, "n_pod_lost": 0,
                "residual": 0.0, "pieces": []}
        cursor = w0
        for sp in spans:
            t0, t1 = max(sp["t0"], cursor), max(sp["t1"], cursor)
            for a, b, cls in _gap_pieces(classes, starts, cursor, t0):
                comp[f"t_{cls}"] += b - a
                comp["pieces"].append({"t0": a, "t1": b, "kind": cls})
            span = t1 - t0
            data = min(max(sp.get("t_data", 0.0), 0.0), span)
            ex = span - data
            comp["t_data"] += data
            comp["t_exec"] += ex
            comp["n_attempts"] += 1
            out = sp["outcome"]
            if out == "preempted":
                comp["n_preempted"] += 1
            elif out in ("pod_lost", "worker_died", "heartbeat_timeout"):
                comp["n_pod_lost"] += 1
            if out in _LOST:
                comp["t_exec_lost"] += ex
            if data > 0:
                comp["pieces"].append(
                    {"t0": t0, "t1": t0 + data, "kind": "data",
                     "task": sp["task"], "attempt": sp["attempt"]})
            if ex > 0 or data == 0:
                comp["pieces"].append(
                    {"t0": t0 + data, "t1": t1, "kind": "exec",
                     "task": sp["task"], "attempt": sp["attempt"],
                     "outcome": out})
            cursor = max(cursor, t1)
        for a, b, cls in _gap_pieces(classes, starts, cursor, w1):
            comp[f"t_{cls}"] += b - a
            comp["pieces"].append({"t0": a, "t1": b, "kind": cls})
        total = (comp["t_exec"] + comp["t_data"] + comp["t_sched"]
                 + comp["t_block"] + comp["t_idle"])
        comp["residual"] = abs((w1 - w0) - total)
        slots[label] = comp
    totals = {k: sum(c[k] for c in slots.values())
              for k in ("t_exec", "t_data", "t_sched", "t_block",
                        "t_idle", "t_exec_lost", "n_attempts",
                        "n_preempted", "n_pod_lost")}
    return {"window": [w0, w1], "clock": seg.clock,
            "n_open": getattr(seg, "n_open", 0),
            "residual_max": max(
                (c["residual"] for c in slots.values()), default=0.0),
            "slots": slots, "totals": totals}


# ---------------------------------------------------------------- chrome
def to_chrome(named_segments: List[Tuple[str, Segment]]) -> str:
    """Render segments as a Chrome/Perfetto ``trace_event`` JSON string
    (load via chrome://tracing or ui.perfetto.dev).  One process per
    segment, one thread row per slot, X slices per exec/data/gap piece,
    instants for pod events.  Output is byte-deterministic: events are
    fully sorted and serialized with sorted keys."""
    events: List[dict] = []
    for pid, (name, seg) in enumerate(named_segments):
        dec = decompose(seg)
        w0 = dec["window"][0]
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": name}})
        for tid, (label, comp) in enumerate(sorted(dec["slots"].items()),
                                            start=1):
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name", "args": {"name": label}})
            for p in comp["pieces"]:
                out = p.get("outcome")
                color = _COLORS[out] if out in _COLORS and out != "done" \
                    else _COLORS[p["kind"]]
                args = {k: p[k] for k in ("task", "attempt", "outcome")
                        if p.get(k) is not None}
                nm = p.get("task", p["kind"])
                events.append({"ph": "X", "pid": pid, "tid": tid,
                               "name": nm, "cat": p["kind"],
                               "cname": color,
                               "ts": round((p["t0"] - w0) * 1e6, 3),
                               "dur": round((p["t1"] - p["t0"]) * 1e6, 3),
                               "args": args})
        for inst in seg.instants:
            events.append({"ph": "i", "pid": pid, "tid": 0, "s": "p",
                           "name": inst["name"], "cat": "pod",
                           "ts": round((inst["t"] - w0) * 1e6, 3),
                           "args": {k: inst[k] for k in ("pod", "n_slots")
                                    if inst.get(k) is not None}})
    events.sort(key=lambda e: (e["pid"], e["tid"], e.get("ts", -1.0),
                               e["ph"], e["name"]))
    return json.dumps({"traceEvents": events, "displayTimeUnit": "ms"},
                      sort_keys=True, separators=(",", ":"))


# ---------------------------------------------------------------- critpath
def critical_path(seg: Segment, k: int = 3) -> List[dict]:
    """Top-k critical chains through the segment's span/dep DAG.

    Walk back from the k latest-finishing tasks, at each step following
    the dependency that finished LAST; every link reports its slack —
    the gap between the dep's finish and this task's start (scheduling /
    staging / queueing delay the chain absorbed).  A chain of zero-slack
    links is the classic critical path."""
    done = {}
    for sp in seg.spans:
        if sp["outcome"] == "done":
            done[sp["task"]] = sp
    ends = sorted(done.values(),
                  key=lambda s: (-s["t1"], s["task"]))[:max(k, 0)]
    chains, seen = [], set()
    for end in ends:
        links, cur = [], end
        while True:
            deps = [done[d] for d in seg.deps.get(cur["task"], ())
                    if d in done]
            link = {"task": cur["task"], "t0": cur["t0"], "t1": cur["t1"],
                    "span": cur["t1"] - cur["t0"]}
            if not deps:
                links.append(link)
                break
            dep = max(deps, key=lambda s: (s["t1"], s["task"]))
            link["dep"] = dep["task"]
            link["slack"] = max(cur["t0"] - dep["t1"], 0.0)
            links.append(link)
            cur = dep
        links.reverse()
        key = tuple(ln["task"] for ln in links)
        if key in seen:
            continue
        seen.add(key)
        chains.append({"ttc": end["t1"], "n_links": len(links),
                       "total_slack": sum(ln.get("slack", 0.0)
                                          for ln in links),
                       "links": links})
    return chains
