"""repro.obs: flight-recorder span tracing, metrics timelines, and TTC
overhead decomposition.

Two complementary surfaces:

* **Live**: hand a :class:`Tracer` to ``PilotRuntime(tracer=...)`` (or a
  federation ``Fleet(tracer=...)``) — every attempt, park, preemption,
  pod event and dispatch decision becomes a span/instant on the run's
  authoritative clock, and the tracer's :class:`MetricsTimeline` samples
  frontier depth, slot occupancy, channel backlog, staging hit-rate and
  per-pilot load on clock ticks.  ``AppManager.run`` lands the timeline
  in ``prof.results["timeseries"]``.

* **Post-hoc**: any journal file replays into the same model —
  ``python -m repro.obs trace|decompose|critical-path`` (see
  :mod:`repro.obs.report`).  No live tracer needed.
"""
from repro.obs.metrics import MetricsTimeline
from repro.obs.report import (Segment, critical_path, decompose,
                              load_segments, to_chrome)
from repro.obs.tracer import TASK, Tracer

__all__ = ["Tracer", "TASK", "MetricsTimeline", "Segment",
           "load_segments", "decompose", "to_chrome", "critical_path"]
