"""CLI for the flight recorder: journal files in, traces/reports out.

  python -m repro.obs trace <journal.jsonl | dir> [--out PATH]
      Export Chrome/Perfetto trace_event JSON (one process per session
      segment, one row per slot).  Load in ui.perfetto.dev.

  python -m repro.obs decompose <journal.jsonl | dir> [--tol 1e-6] [--json]
      Exact per-slot TTC decomposition of the final session segment.
      Exits 1 when any slot's residual exceeds --tol or the final
      segment ends with unpaired (still-open) attempt spans — the CI
      gate over smoke journals.

  python -m repro.obs critical-path <journal.jsonl> [-k 3] [--json]
      Top-k critical chains with per-link slack.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.obs.report import (critical_path, decompose, load_segments,
                              to_chrome)


def _journals(path: str):
    if os.path.isdir(path):
        names = sorted(n for n in os.listdir(path) if n.endswith(".jsonl"))
        return [os.path.join(path, n) for n in names]
    return [path]


def _cmd_trace(args) -> int:
    paths = _journals(args.journal)
    if not paths:
        print(f"repro.obs: no journals under {args.journal}",
              file=sys.stderr)
        return 1
    named = []
    for p in paths:
        stem = os.path.splitext(os.path.basename(p))[0]
        for seg in load_segments(p):
            name = stem if seg.index == 0 else f"{stem}#{seg.index}"
            named.append((name, seg))
    out = to_chrome(named)
    dest = args.out
    if dest is None:
        dest = (os.path.join(args.journal, "trace.json")
                if os.path.isdir(args.journal)
                else os.path.splitext(args.journal)[0] + ".trace.json")
    os.makedirs(os.path.dirname(os.path.abspath(dest)), exist_ok=True)
    with open(dest, "w") as f:
        f.write(out)
    print(f"repro.obs: wrote {dest} "
          f"({len(named)} segment(s), {len(out)} bytes)")
    return 0


def _cmd_decompose(args) -> int:
    failures = 0
    for p in _journals(args.journal):
        seg = load_segments(p)[-1]          # crash-restart: final run only
        if not seg.n_records:
            continue
        dec = decompose(seg)
        bad = dec["residual_max"] > args.tol or dec["n_open"] > 0
        failures += bad
        if args.json:
            for c in dec["slots"].values():
                c.pop("pieces", None)
            print(json.dumps({"journal": os.path.basename(p), **dec},
                             sort_keys=True))
            continue
        t = dec["totals"]
        w0, w1 = dec["window"]
        print(f"{os.path.basename(p)}: window {w1 - w0:.6g}s "
              f"x {len(dec['slots'])} slots [{dec['clock']}]"
              + ("  ** FAIL **" if bad else ""))
        print(f"  exec {t['t_exec']:.6g}  data {t['t_data']:.6g}  "
              f"sched {t['t_sched']:.6g}  block {t['t_block']:.6g}  "
              f"idle {t['t_idle']:.6g}  (lost {t['t_exec_lost']:.6g})")
        print(f"  attempts {t['n_attempts']}  preempted "
              f"{t['n_preempted']}  pod_lost {t['n_pod_lost']}  "
              f"residual_max {dec['residual_max']:.3g}  "
              f"open_spans {dec['n_open']}")
    return 1 if failures else 0


def _cmd_critical_path(args) -> int:
    seg = load_segments(args.journal)[-1]
    chains = critical_path(seg, k=args.k)
    if args.json:
        print(json.dumps(chains, sort_keys=True))
        return 0
    for i, ch in enumerate(chains):
        print(f"chain {i}: ttc {ch['ttc']:.6g}  links {ch['n_links']}  "
              f"slack {ch['total_slack']:.6g}")
        for ln in ch["links"]:
            slack = (f"  slack {ln['slack']:.6g}" if "slack" in ln else "")
            print(f"  {ln['task']}  [{ln['t0']:.6g}, {ln['t1']:.6g}]"
                  f"{slack}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("trace", help="export Chrome trace_event JSON")
    p.add_argument("journal")
    p.add_argument("--out", default=None)
    p.set_defaults(fn=_cmd_trace)

    p = sub.add_parser("decompose", help="per-slot TTC decomposition")
    p.add_argument("journal")
    p.add_argument("--tol", type=float, default=1e-6)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_decompose)

    p = sub.add_parser("critical-path", help="top-k critical chains")
    p.add_argument("journal")
    p.add_argument("-k", type=int, default=3)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_critical_path)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
