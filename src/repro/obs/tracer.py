"""Flight-recorder span tracing for the pilot runtime.

A :class:`Tracer` is handed to ``PilotRuntime(tracer=...)`` (or set as
``Fleet.tracer``) and the executor/AppManager/federation hook points call
``task_begin``/``task_end``/``begin``/``end``/``instant`` on it — every
call site guards with ``if tracer is not None``, so an untraced run pays a
single attribute read per hook.  Spans are begin/end pairs keyed to
(pod, slot, pipeline, task, attempt) on the run's authoritative clock:
the virtual clock in sim mode, wall seconds since drain start in real
mode.  A truncated attempt (preemption, pod loss, supersession) ENDS its
span at the truncation time with that outcome — spans never overlap on a
slot, which is what keeps the TTC decomposition (repro.obs.report)
disjoint.

The task-attempt path is the hot one (a 100k-task sim opens and closes
100k spans inside the DES loop), so it records raw tuples and defers
EVERYTHING derivable — dict materialization (the :attr:`spans` read),
outcome counters and span/data/exec histograms (:meth:`_fold`) — to read
time.  The per-attempt cost inside the DES loop is two dict ops and one
tuple append.  The generic ``begin``/``end`` path (parks, transfers)
stays dict-based; it fires orders of magnitude less often.

The tracer owns a :class:`~repro.obs.metrics.MetricsTimeline` — per-attempt
spans fold into histograms (``t_data_attempt``/``t_exec_attempt`` are
recorded for attempts that staged data; for the rest ``attempt_span`` IS
the exec histogram) and ``attempts_<outcome>`` counters, and the drain
loops sample the registered gauges on clock ticks.  Read the timeline
through :meth:`timeseries` (it folds first); ``metrics.series()`` alone
misses attempts recorded since the last fold.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsTimeline

#: span category for task attempts (other cats: "park", "transfer", ...)
TASK = "task"

#: interned ``attempts_<outcome>`` counter names (hot-path cache)
_COUNTER_KEY: Dict[str, str] = {}


class Tracer:
    def __init__(self, *, metrics: Optional[MetricsTimeline] = None):
        self.metrics = metrics if metrics is not None else MetricsTimeline()
        #: "virtual" | "wall" — stamped by the session at first use
        self.clock: Optional[str] = None
        self.events: List[Dict[str, Any]] = []    # instants (pod loss, ...)
        self._open: Dict[Tuple, Any] = {}
        # task spans: raw (task, attempt, t0, t1, outcome, extras) tuples,
        # materialized to dicts lazily by the .spans property
        self._raw: List[Tuple] = []
        self._span_cache: List[Dict[str, Any]] = []
        self._closed: List[Dict[str, Any]] = []   # generic (non-task) spans
        self._folded = 0          # prefix of _raw already in the metrics

    # ------------------------------------------------------------ generic
    def begin(self, key: Tuple, cat: str, name: str, now: float, **args):
        """Open a span under ``key`` (re-begin on an open key replaces the
        stale span — defensively; the runtime never does)."""
        span = {"cat": cat, "name": name, "t0": float(now), "t1": None,
                "outcome": None}
        if args:
            span.update(args)
        self._open[key] = span

    def end(self, key: Tuple, now: float, outcome: str = "done", **args):
        """Close the span under ``key`` (no-op when the key is unknown —
        e.g. a supersession record for a task that never launched)."""
        span = self._open.pop(key, None)
        if span is None:
            return None
        span["t1"] = float(now)
        span["outcome"] = outcome
        if args:
            span.update(args)
        self._closed.append(span)
        return span

    def instant(self, cat: str, name: str, now: float, **args):
        ev = {"cat": cat, "name": name, "t": float(now)}
        if args:
            ev.update(args)
        self.events.append(ev)

    # ------------------------------------------------------------ tasks
    def task_begin(self, t, now: float, pod: Optional[str] = None,
                   t_data: float = 0.0):
        """Open a task-attempt span (hot path: no dict until the span is
        read back; ``extras`` only materializes for annotated tasks)."""
        extras = None
        meta = t.meta
        if pod is not None:
            extras = {"pod": pod}
        if t_data:
            extras = extras or {}
            extras["t_data"] = t_data
        if t.slots != 1:
            extras = extras or {}
            extras["width"] = t.slots
        if meta:
            pilot = meta.get("pilot")
            pipeline = meta.get("pipeline")
            ids = meta.get("slot_ids")
            if pilot is not None or pipeline is not None or ids:
                extras = extras or {}
                if pilot is not None:
                    extras["pilot"] = pilot
                if pipeline is not None:
                    extras["pipeline"] = pipeline
                if ids:
                    extras["slots"] = list(ids)
        self._open[(t.name, t.attempts)] = (now, extras)

    def task_end(self, t, now: float, outcome: str):
        opened = self._open.pop((t.name, t.attempts), None)
        if opened is None:
            return None
        self._raw.append(
            (t.name, t.attempts, opened[0], now, outcome, opened[1]))
        return True

    def _fold(self):
        """Fold raw attempt records into the metrics registry (counters
        and histograms) — deferred off the DES hot path; idempotent over
        the already-folded prefix."""
        raw = self._raw
        if self._folded == len(raw):
            return
        m = self.metrics
        h_span = m.hist("attempt_span")
        h_data = m.hist("t_data_attempt")
        h_exec = m.hist("t_exec_attempt")
        cnt = m.counters
        for rec in raw[self._folded:]:
            _name, _attempt, t0, t1, outcome, extras = rec
            key = _COUNTER_KEY.get(outcome)
            if key is None:
                key = _COUNTER_KEY[outcome] = "attempts_" + outcome
            cnt[key] = cnt.get(key, 0.0) + 1.0
            dur = t1 - t0
            h_span.add(dur)
            if extras is not None and outcome == "done":
                t_data = extras.get("t_data")
                if t_data:
                    h_data.add(t_data)
                    h_exec.add(dur - t_data if dur > t_data else 0.0)
        self._folded = len(raw)

    def timeseries(self) -> dict:
        """The metrics timeline with all recorded attempts folded in —
        this is what lands in ``prof.results["timeseries"]``."""
        self._fold()
        return self.metrics.series()

    # ------------------------------------------------------------ results
    @staticmethod
    def _materialize(raw: Tuple) -> Dict[str, Any]:
        name, attempt, t0, t1, outcome, extras = raw
        span = {"cat": TASK, "task": name, "attempt": attempt,
                "t0": t0, "t1": t1, "outcome": outcome}
        if extras:
            span.update(extras)
        return span

    @property
    def spans(self) -> List[Dict[str, Any]]:
        """All closed spans as dicts, end order: task-attempt spans
        (materialized from the raw hot-path records) then generic spans
        (parks, transfers)."""
        if len(self._span_cache) != len(self._raw) + len(self._closed):
            self._span_cache = [self._materialize(r) for r in self._raw]
            self._span_cache.extend(self._closed)
        return self._span_cache

    def unpaired(self) -> List[Dict[str, Any]]:
        """Spans still open (a clean run ends with none; pipelines parked
        at drain end legitimately remain — the caller filters by cat)."""
        out = []
        for key, val in self._open.items():
            if isinstance(val, dict):
                out.append(val)
            else:
                t0, extras = val
                span = {"cat": TASK, "task": key[0], "attempt": key[1],
                        "t0": t0, "t1": None, "outcome": None}
                if extras:
                    span.update(extras)
                out.append(span)
        return out

    def summary(self) -> dict:
        self._fold()
        return {"n_spans": len(self._raw) + len(self._closed),
                "n_events": len(self.events),
                "n_open": len(self._open), "clock": self.clock}
