import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/roofline artifacts.

No arrays are allocated: inputs are ShapeDtypeStructs, states come from
jax.eval_shape.  This is the proof that the distribution config is coherent —
sharding mismatches, compile-time OOM and unsupported collectives all fail
here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
Results accumulate in dryrun_results/<arch>_<shape>_<mesh>.json.
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, cell_applicable, get_config, input_specs, list_configs  # noqa: E402
from repro.dist.sharding import batch_shardings, cache_shardings, state_shardings  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.hlo_costs import module_costs  # noqa: E402
from repro.roofline.report import make_row  # noqa: E402
from repro.serve import build_prefill_step, build_serve_step, cache_specs  # noqa: E402
from repro.train import build_train_step, train_state_specs  # noqa: E402

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "dryrun_results")


def _mem_stats(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "alias_bytes": float(ma.alias_size_in_bytes),
        }
    except Exception:
        return None


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Build step fn + specs + shardings and lower.  Returns lowered."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    if shape.kind == "train":
        state_specs = train_state_specs(cfg)
        st_sh = state_shardings(cfg, mesh, state_specs)
        b_specs = input_specs(cfg, shape)
        b_sh = batch_shardings(cfg, mesh, b_specs, "train")
        step = build_train_step(cfg, mesh)
        lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, None),
                          donate_argnums=(0,)).lower(state_specs, b_specs)
        return lowered, cfg, shape, mesh

    # serving cells use bf16 parameters
    scfg = cfg.replace(param_dtype="bfloat16")
    p_specs = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["init_params"])
        .init_params(scfg, jax.random.PRNGKey(0)))
    p_sh = state_shardings(scfg, mesh, p_specs)

    if shape.kind == "prefill":
        b_specs = input_specs(scfg, shape)
        b_sh = batch_shardings(scfg, mesh, b_specs, "serve")
        step = build_prefill_step(scfg, mesh, cache_len=shape.seq_len)
        c_specs = cache_specs(scfg, shape.global_batch, shape.seq_len)
        c_sh = cache_shardings(scfg, mesh, c_specs)
        out_sh = {"logits": None, "cache": c_sh}
        lowered = jax.jit(step, in_shardings=(p_sh, b_sh),
                          out_shardings=out_sh).lower(p_specs, b_specs)
        return lowered, scfg, shape, mesh

    # decode
    b_specs = input_specs(scfg, shape)
    b_sh = batch_shardings(scfg, mesh, b_specs, "serve")
    c_specs = cache_specs(scfg, shape.global_batch, shape.seq_len)
    c_sh = cache_shardings(scfg, mesh, c_specs)
    step = build_serve_step(scfg, mesh)
    lowered = jax.jit(
        step, in_shardings=(p_sh, c_sh, b_sh["tokens"], b_sh["positions"]),
        out_shardings=(None, c_sh), donate_argnums=(1,)).lower(
            p_specs, c_specs, b_specs["tokens"], b_specs["positions"])
    return lowered, scfg, shape, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        _save(res, save)
        return res

    t0 = time.time()
    try:
        lowered, cfg2, shape2, mesh = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        txt = compiled.as_text()
        costs = module_costs(txt)
        mem = _mem_stats(compiled)
        ca = {}
        try:
            ca = {k: float(v) for k, v in (compiled.cost_analysis() or {}).items()
                  if isinstance(v, (int, float))}
        except Exception:
            pass
        chips = mesh.devices.size
        ideal = None
        if shape2.kind == "decode":
            # bytes floor: bf16 params + the whole cache, read once
            from repro.models import init_params
            p_specs = jax.eval_shape(lambda: init_params(
                cfg2, jax.random.PRNGKey(0)))
            c_specs = cache_specs(cfg2, shape2.global_batch, shape2.seq_len)
            nbytes = lambda t: sum(x.size * x.dtype.itemsize
                                   for x in jax.tree.leaves(t))
            ideal = nbytes(p_specs) + nbytes(c_specs)
        row = make_row(cfg2, shape2, mesh_name, chips, costs, mem,
                       ideal_bytes_total=ideal)
        res = {"status": "ok", "t_lower_s": t_lower, "t_compile_s": t_compile,
               "xla_cost_analysis_flops": ca.get("flops"),
               **row.to_dict()}
    except Exception as e:  # a failing cell is a bug in the system
        res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    _save(res, save)
    return res


def _save(res: dict, save: bool):
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    fn = f"{res['arch']}_{res['shape']}_{res['mesh']}.json"
    with open(os.path.join(RESULTS_DIR, fn), "w") as f:
        json.dump(res, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_err = 0
    for a, s, mp in cells:
        res = run_cell(a, s, mp)
        tag = res["status"]
        n_ok += tag == "ok"
        n_skip += tag == "skipped"
        n_err += tag == "error"
        if tag == "ok":
            print(f"[ok]   {a:24s} {s:12s} {res['mesh']:10s} "
                  f"comp={res['t_compute']*1e3:8.2f}ms "
                  f"mem={res['t_memory']*1e3:8.2f}ms "
                  f"coll={res['t_collective']*1e3:8.2f}ms "
                  f"bound={res['bottleneck']:10s} "
                  f"(compile {res['t_compile_s']:.0f}s)", flush=True)
        elif tag == "skipped":
            print(f"[skip] {a:24s} {s:12s} {res['mesh']:10s} {res['reason']}",
                  flush=True)
        else:
            print(f"[ERR]  {a:24s} {s:12s} {res['mesh']:10s} {res['error']}",
                  flush=True)
    print(f"\n{n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
