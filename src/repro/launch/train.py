"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --shape train_4k --steps 100 --mesh prod [--multi-pod] \
        --ckpt-dir /ckpts/gemma2

On a real fleet this runs under multi-controller JAX (jax.distributed); on
this container use --mesh local with a reduced config (--reduced).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, get_config, input_specs, reduced
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLM
from repro.dist.sharding import batch_shardings, state_shardings
from repro.launch.mesh import make_production_mesh
from repro.train import TrainHyper, build_train_step, make_train_state, \
    train_state_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", choices=("local", "prod"), default="local")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = SHAPES[args.shape]
    if args.batch or args.seq:
        shape = ShapeSpec("cli", "train", args.seq or shape.seq_len,
                          args.batch or shape.global_batch)

    mesh = None
    if args.mesh == "prod":
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    # arch-selected schedule (minicpm ships WSD)
    schedule = args.schedule
    if schedule is None:
        import importlib
        mod = importlib.import_module(
            f"repro.configs.{args.arch.replace('-', '_')}")
        schedule = getattr(mod, "SCHEDULE", "cosine")

    hyper = TrainHyper(base_lr=args.lr, warmup=min(100, args.steps // 10 + 1),
                       total_steps=args.steps, schedule=schedule)
    step_fn = build_train_step(cfg, mesh, hyper)
    if mesh is not None:
        st_sh = state_shardings(cfg, mesh, train_state_specs(cfg))
        b_specs = input_specs(cfg, shape)
        b_sh = batch_shardings(cfg, mesh, b_specs, "train")
        step = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                       out_shardings=(st_sh, None), donate_argnums=(0,))
    else:
        step = jax.jit(step_fn, donate_argnums=(0,))
        b_sh = None

    state = make_train_state(cfg, jax.random.PRNGKey(0))
    start = 0
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and ck.latest_step() is not None:
        state, start = ck.restore(jax.eval_shape(lambda: state))
        print(f"restored step {start}")

    data = SyntheticLM(cfg, shape, seed=0, shardings=b_sh)
    t0 = time.time()
    for i in range(start, args.steps):
        state, m = step(state, data.batch_at(i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e}", flush=True)
        if ck and i and i % args.ckpt_every == 0:
            ck.save(state, i, blocking=False)
    if ck:
        ck.save(state, args.steps)
        ck.wait()
    steps = args.steps - start
    print(f"{steps} steps in {time.time()-t0:.1f}s "
          f"({(time.time()-t0)/max(steps,1):.2f}s/step)")


if __name__ == "__main__":
    main()
