"""Production serving driver: batched prefill+decode on a (reduced) arch.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = cfg.replace(param_dtype="float32" if args.reduced else "bfloat16")
    params = init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, batch=args.batch,
                        prompt_len=args.prompt_len,
                        max_len=args.prompt_len + args.new_tokens + 1)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    srv.submit(reqs)
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    ntok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {ntok} tokens in {dt:.2f}s; "
          f"stats={srv.stats}")


if __name__ == "__main__":
    main()
