"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  Hardware model:
TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (used by the
roofline report, repro.roofline).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16e9           # HBM capacity per chip


HW = HardwareSpec()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes)
