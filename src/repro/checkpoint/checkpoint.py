"""Sharded checkpointing: save/restore arbitrary state pytrees.

Layout: <dir>/step_<n>/shard_<host>.npz + manifest.json.  Each host writes
only its addressable shard data (single host here; the structure is the
multi-host one).  Async mode copies to host memory synchronously (cheap) and
writes in a background thread so the train loop isn't blocked on disk.
Retention keeps the newest ``keep`` checkpoints.  Restore reshards onto the
provided shardings (elastic restarts may use a different mesh).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(state) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, arrays: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(arrays[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, host: int = 0):
        self.dir = directory
        self.keep = keep
        self.host = host
        self._pending: List[threading.Thread] = []
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save
    def save(self, state, step: int, *, blocking: bool = True) -> str:
        flat = _flatten(state)
        host_np = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        d = os.path.join(self.dir, f"step_{step:010d}")
        tmp = d + ".tmp"

        def write():
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"shard_{self.host}.npz"), **host_np)
            manifest = {
                "step": step,
                "keys": sorted(host_np),
                "shapes": {k: list(v.shape) for k, v in host_np.items()},
                "dtypes": {k: str(v.dtype) for k, v in host_np.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, d)           # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            t = threading.Thread(target=write, daemon=True)
            t.start()
            self._pending.append(t)
        return d

    def wait(self):
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None, *,
                shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(d, f"shard_{self.host}.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        state = _unflatten_into(template, arrays)
        if shardings is not None:
            flat_s, tdef = jax.tree.flatten(shardings)
            flat_x = tdef.flatten_up_to(state)
            state = tdef.unflatten([
                jax.device_put(x, s) if s is not None else jax.device_put(x)
                for x, s in zip(flat_x, flat_s)])
        else:
            state = jax.tree.map(jax.device_put, state)
        return state, step
