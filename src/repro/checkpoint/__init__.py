from repro.checkpoint.checkpoint import Checkpointer  # noqa: F401
