"""Locality-aware transfer planning: link / copy / materialize + t_data.

"Harnessing the Power of Many" shows staging policy (link vs copy vs remote
transfer) dominating ensemble TTC at scale; the RADICAL-Pilot
characterization papers make locality of task data a first-class scheduler
input.  This module is that policy layer:

  LocalityMap        maps pilot slot ids onto locality domains ("pods"):
                     two slots in the same pod share fast memory/interconnect
                     (e.g. one pod of the 2x16x16 production mesh), so a
                     blob resident in the pod is *linked*, not copied.
  TransferPlanner    resolves one ``StagedRef`` + destination to the
                     cheapest available mode and its modeled cost:

                       link          replica already in the consumer's pod —
                                     share the decoded object, ~zero cost
                       copy          in-memory replica in another pod —
                                     decode a fresh object, nbytes/copy_bw
                       materialize   only a spilled blob exists — read the
                                     spill file, nbytes/disk_bw

Copy bandwidth is **tiered**: pod↔pod inside one pilot rides the fast
interconnect (``copy_gbps``), pilot↔pilot crosses the inter-pilot fabric
(``cross_gbps``), and anything touching HOST pays the slow host link
(``host_gbps``).  Source selection prefers a same-pilot pod replica, then
a cross-pilot pod replica (pilot-to-pilot fetch — the blob never routes
through the manager), and falls back to HOST last.  Pilot membership is
encoded in the pod name itself: a federated ``LocalityMap`` carries a
``prefix`` (e.g. ``"p1:"``) so its pods are ``p1:pod0, p1:pod1, ...`` —
fleet-unique names that replica sets, retry exclusion, and this tiering
all key on without extra plumbing.

The modeled cost charges ``t_data`` in DES (sim) mode; in real mode the
executed transfer is measured on the wall clock (link returns the shared
object, copy genuinely re-decodes, materialize genuinely reads disk), so
real profiles stay honest.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Optional, Sequence

from repro.staging.store import HOST, ObjectStore, StagedRef

MODES = ("link", "copy", "materialize")


def pilot_of(location: str) -> str:
    """Pilot prefix of a pod location: ``"p1:pod3" -> "p1:"``, an
    unprefixed ``"pod3" -> ""`` (single-pilot runs), ``HOST -> "host"``
    (its own tier — never equal to any pod's pilot)."""
    return location.partition("pod")[0]


@dataclass(frozen=True)
class LocalityMap:
    """Slot id -> locality domain ("pod").

    ``slots_per_pod`` groups consecutive slot ids: the pod2x16x16 mesh
    carved one-slot-per-pod uses ``slots_per_pod=1`` (each slot IS a pod);
    a single pod16x16 carved into k submesh slots uses ``slots_per_pod=k``
    (every slot shares the pod).  Data staged outside any slot lives at
    ``HOST``.

    ``prefix`` namespaces the pod names (``prefix="p1:"`` -> ``p1:pod0``)
    so several pilots' pods coexist in one shared ObjectStore / journal /
    exclusion set without collision — repro.federation sets it per pilot.
    """
    n_slots: int
    slots_per_pod: int = 1
    prefix: str = ""

    def __post_init__(self):
        if self.n_slots <= 0 or self.slots_per_pod <= 0:
            raise ValueError("n_slots and slots_per_pod must be positive")

    @classmethod
    def from_topology(cls, topology, slots_per_pod: int = 1,
                      prefix: str = "") -> "LocalityMap":
        """Locality over a dist.topology.SlotTopology's slot ids."""
        return cls(n_slots=topology.n_slots, slots_per_pod=slots_per_pod,
                   prefix=prefix)

    @property
    def n_pods(self) -> int:
        return (self.n_slots + self.slots_per_pod - 1) // self.slots_per_pod

    def pod_of(self, slot_id: int) -> str:
        return f"{self.prefix}pod{int(slot_id) // self.slots_per_pod}"

    def location_for(self, slot_ids: Optional[Sequence[int]]) -> str:
        """A task's locality domain: the pod of its first granted slot
        (multi-slot tasks are granted locality-packed ids), HOST if the
        task holds no slot ids (no topology / not yet granted)."""
        if not slot_ids:
            return HOST
        return self.pod_of(min(slot_ids))

    def pods_of(self, slot_ids: Sequence[int]) -> set:
        return {self.pod_of(s) for s in slot_ids}


@dataclass(frozen=True)
class TransferSpec:
    """One planned move of one blob to one destination pod."""
    digest: str
    nbytes: int
    mode: str                  # link | copy | materialize
    src: str                   # source location (pod id or HOST/"disk")
    dst: str
    cost_s: float              # modeled seconds (DES charge)


class TransferPlanner:
    """Resolve consumer bindings to the cheapest transfer mode.

    Bandwidths are modeled (GB/s) for DES cost accounting; latencies are
    the fixed per-transfer floors.  ``stats`` accumulates decisions —
    ``hit_rate`` (links over all transfers) is the locality headline the
    staging benchmark reports.
    """

    def __init__(self, store: ObjectStore, locality: Optional[LocalityMap]
                 = None, *, copy_gbps: float = 25.0, disk_gbps: float = 2.0,
                 host_gbps: float = 8.0, cross_gbps: float = 12.5,
                 link_latency_s: float = 0.0, copy_latency_s: float = 1e-4):
        self.store = store
        self.locality = locality
        self.copy_gbps = copy_gbps          # pod<->pod, same pilot
        self.disk_gbps = disk_gbps          # spill materialization
        self.host_gbps = host_gbps          # anything touching HOST
        self.cross_gbps = cross_gbps        # pod<->pod across pilots
        self.link_latency_s = link_latency_s
        self.copy_latency_s = copy_latency_s
        self.stats: Dict[str, float] = {
            "link": 0, "copy": 0, "materialize": 0, "cross_pilot": 0,
            "bytes_linked": 0, "bytes_copied": 0, "bytes_materialized": 0,
            "bytes_cross_pilot": 0, "t_data_modeled": 0.0}
        self._lock = threading.Lock()      # stats only; store self-locks

    # ------------------------------------------------------------ planning
    def _copy_gbps_for(self, src: str, dst: str) -> float:
        """Bandwidth tier for a copy: host link when either end is HOST,
        inter-pilot fabric across pilots, pod interconnect inside one."""
        if src == HOST or dst == HOST:
            return self.host_gbps
        if pilot_of(src) != pilot_of(dst):
            return self.cross_gbps
        return self.copy_gbps

    def _pick_source(self, known: set, dst: str) -> str:
        """Copy source for ``dst``: same-pilot pod replica first, then a
        cross-pilot pod replica (direct pilot-to-pilot fetch), HOST last —
        pod replicas always beat the slow host link when both exist."""
        pods = sorted(loc for loc in known if loc != HOST)
        if dst != HOST:
            same = [p for p in pods if pilot_of(p) == pilot_of(dst)]
            if same:
                return same[0]
        return pods[0] if pods else HOST

    def plan(self, ref: StagedRef, dst: str) -> TransferSpec:
        """Cheapest mode for ``ref`` at ``dst``: link when a replica is
        already in the destination pod, copy from an in-memory replica in
        another pod (tiered bandwidth — see :meth:`_pick_source`),
        materialize when only the spilled blob survives."""
        d, n = ref.digest, ref.nbytes
        live = self.store.locations(d)
        known = live or set(ref.locations)
        if self.store.in_memory(d):
            if dst in known:
                return TransferSpec(d, n, "link", dst, dst,
                                    self.link_latency_s)
            src = self._pick_source(known, dst)
            gbps = self._copy_gbps_for(src, dst)
            return TransferSpec(d, n, "copy", src, dst,
                                self.copy_latency_s + n / (gbps * 1e9))
        if self.store.spilled(d):
            return TransferSpec(d, n, "materialize", "disk", dst,
                                self.copy_latency_s
                                + n / (self.disk_gbps * 1e9))
        raise KeyError(f"blob {d[:10]}… is neither resident nor spilled")

    # ------------------------------------------------------------ execute
    def execute(self, spec: TransferSpec):
        """Perform the planned move; returns the payload value (None for
        virtual blobs).  The destination gains a replica, so the NEXT
        consumer in that pod links.  Real work matches the mode: link
        shares the decoded object, copy decodes fresh bytes, materialize
        reads the spill file first."""
        value = self.store.get(spec.digest, location=spec.dst,
                               fresh=spec.mode != "link")
        key = {"link": "bytes_linked", "copy": "bytes_copied",
               "materialize": "bytes_materialized"}[spec.mode]
        cross = (spec.mode == "copy" and spec.src != HOST
                 and spec.dst != HOST
                 and pilot_of(spec.src) != pilot_of(spec.dst))
        with self._lock:
            self.stats[spec.mode] += 1
            self.stats[key] += spec.nbytes
            self.stats["t_data_modeled"] += spec.cost_s
            if cross:
                self.stats["cross_pilot"] += 1
                self.stats["bytes_cross_pilot"] += spec.nbytes
        return value

    # ------------------------------------------------------------ summary
    @property
    def n_transfers(self) -> int:
        return int(self.stats["link"] + self.stats["copy"]
                   + self.stats["materialize"])

    @property
    def hit_rate(self) -> float:
        """Fraction of transfers that were pod-local links."""
        n = self.n_transfers
        return self.stats["link"] / n if n else 0.0

    def summary(self) -> Dict[str, float]:
        return {**{k: self.stats[k] for k in
                   ("link", "copy", "materialize", "cross_pilot",
                    "bytes_copied", "bytes_materialized",
                    "bytes_cross_pilot", "t_data_modeled")},
                "n_transfers": self.n_transfers,
                "locality_hit_rate": round(self.hit_rate, 4)}
