"""Port integration: staged refs through channels, tasks, and the journal.

``core/flow.py`` Channels move stage results by value; at fleet scale a
trajectory-sized payload copied through every put is invisible to profiles
and unbounded in memory.  The :class:`StagingLayer` here turns large puts
into :class:`StagedRef` handles (one content-addressed blob, N cheap takes)
and transparently dereferences them back into ``ctx["inputs"]`` between
``pop_ready`` and kernel launch — charging every move to ``t_data``.

Wiring (who calls what):

  AppManager (core/pst.py)
    - ``stage_payload``/``stage_virtual`` on channel put (real/DES mode)
    - ``on_take`` when a consumer binding takes a staged put
    - ``manifest_input``/``acquire_stage_in`` at task build: records the
      task's staged refs in ``task.meta["staged_refs"]``
    - ``resolve`` in the task closure: refs -> values (from the stage-in
      pass below)
  PilotRuntime / RuntimeSession (runtime/executor.py)
    - ``stage_in(task, mode)`` between ``pop_ready`` and kernel launch:
      plans + executes every transfer to the task's granted pod
    - ``preferred_ids``/``prefers`` for locality-aware slot grant and
      frontier ordering
    - ``finish(task)`` at terminal state: releases the task's holds
  Journal (runtime/journal.py)
    - ``encode_refs``/``decode_refs``: refs survive the JSONL round-trip,
      so a coupled restart replays refs WITHOUT re-staging payloads

Only top-level port payloads are dereferenced automatically; a
``StagedRef`` *nested inside* a result dict stays lazy — a consumer that
only reads scalar fields (e.g. ``re.exchange`` reading member losses)
never pays for the bulk field (see ``iter_refs``/kernel ``ctx["staging"]``).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Iterator, List, Optional

from repro.staging.store import HOST, ObjectStore, StagedRef
from repro.staging.transfer import LocalityMap, TransferPlanner

REF_KEY = "__staged_ref__"


# ---------------------------------------------------------------- encoding

def encode_refs(value: Any) -> Any:
    """JSON-encodable form: StagedRefs become marker dicts (recursing into
    dicts/lists); everything else passes through."""
    if isinstance(value, StagedRef):
        return {REF_KEY: [value.digest, value.nbytes,
                          list(value.locations)]}
    if isinstance(value, dict):
        return {k: encode_refs(v) for k, v in value.items()}
    if isinstance(value, list):
        return [encode_refs(v) for v in value]
    return value


def decode_refs(value: Any) -> Any:
    """Inverse of :func:`encode_refs` (applied to journal-replayed puts)."""
    if isinstance(value, dict):
        if set(value) == {REF_KEY}:
            d, n, locs = value[REF_KEY]
            return StagedRef(str(d), int(n), tuple(locs))
        return {k: decode_refs(v) for k, v in value.items()}
    if isinstance(value, list):
        return [decode_refs(v) for v in value]
    return value


def iter_refs(value: Any) -> Iterator[StagedRef]:
    """Yield every StagedRef nested anywhere in ``value``."""
    if isinstance(value, StagedRef):
        yield value
    elif isinstance(value, dict):
        for v in value.values():
            yield from iter_refs(v)
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from iter_refs(v)


def payload_nbytes(value: Any) -> int:
    from repro.staging.store import encode
    return len(encode(value))


# ---------------------------------------------------------------- the layer

class StagingLayer:
    """One staging policy bound to one PilotRuntime.

    ``threshold_bytes``: channel puts at or above it are staged (smaller
    payloads keep the pass-by-value fast path).  ``locality`` defaults to
    one pod per pilot slot when the runtime binds; ``prefer_local=False``
    disables locality-aware placement/ordering (the benchmark's "copy
    everywhere" baseline) while keeping accounting.
    """

    def __init__(self, *, store: Optional[ObjectStore] = None,
                 planner: Optional[TransferPlanner] = None,
                 locality: Optional[LocalityMap] = None,
                 threshold_bytes: int = 4096,
                 byte_budget: int = 256 << 20,
                 spill_dir: Optional[str] = None,
                 prefer_local: bool = True,
                 copy_gbps: float = 25.0, disk_gbps: float = 2.0):
        self.store = store if store is not None else \
            ObjectStore(byte_budget=byte_budget, spill_dir=spill_dir)
        self.locality = locality
        self.planner = planner if planner is not None else \
            TransferPlanner(self.store, locality,
                            copy_gbps=copy_gbps, disk_gbps=disk_gbps)
        if self.planner.locality is None:
            self.planner.locality = locality
        self.threshold_bytes = int(threshold_bytes)
        self.prefer_local = prefer_local
        self._lock = threading.RLock()

    # ------------------------------------------------------------ binding
    def bind_runtime(self, runtime):
        """Called by PilotRuntime.__init__: default the locality map to
        the pilot's slot count (one pod per slot) when none was given."""
        if self.locality is None:
            n = runtime.topology.n_slots if runtime.topology is not None \
                else runtime.slots
            self.locality = LocalityMap(n_slots=max(n, 1))
        if self.planner.locality is None:
            self.planner.locality = self.locality

    def location_for(self, task) -> str:
        if self.locality is None:
            return HOST
        return self.locality.location_for(task.meta.get("slot_ids"))

    # ------------------------------------------------------------ puts
    def stage_payload(self, value: Any, locations: List[str]):
        """Stage a channel put when it crosses the threshold; returns the
        StagedRef, or the value itself when it is small (or already a
        ref).  Stage-level puts register a replica at EVERY producing
        member's pod — each member's piece lives there."""
        if isinstance(value, StagedRef) or value is None:
            return value
        from repro.staging.store import encode
        data = encode(value)                 # encoded ONCE: measures AND
        if len(data) < self.threshold_bytes:     # feeds the put below
            return value
        with self._lock:
            ref = self.store.put(value, location=(locations or [HOST])[0],
                                 data=data)
            for loc in (locations or [])[1:]:
                self.store.add_location(ref.digest, loc)
            locs = self.store.locations(ref.digest)
            return StagedRef(ref.digest, ref.nbytes, tuple(sorted(locs)))

    def stage_virtual(self, key: str, nbytes: int,
                      locations: List[str]) -> Optional[StagedRef]:
        """DES-mode put: a bookkeeping ref of declared size (no payload
        moves in sim).  Returns None when no size was declared."""
        if nbytes < max(self.threshold_bytes, 1):
            return None
        with self._lock:
            ref = self.store.put_virtual(key, nbytes,
                                         location=(locations or [HOST])[0])
            for loc in (locations or [])[1:]:
                self.store.add_location(ref.digest, loc)
            locs = self.store.locations(ref.digest)
            return StagedRef(ref.digest, ref.nbytes, tuple(sorted(locs)))

    # ------------------------------------------------------------ takes
    def on_take(self, ref: StagedRef, *, n_consumers: int,
                broadcast: bool):
        """Adjust holds when a consumer binding takes a staged put.

        FIFO: the channel's put hold transfers to the taker, so the blob
        dies when the LAST consumer task releases (retain n-1 extra).
        Broadcast: the channel keeps its hold (any future stream may still
        take); each consumer task gets its own hold (retain n).
        """
        with self._lock:
            extra = n_consumers if broadcast else n_consumers - 1
            if extra > 0:
                self.store.retain(ref, extra)
            elif extra < 0:                # 0-task (control) stage on FIFO
                self.store.release(ref)

    # ------------------------------------------------------------ manifests
    def manifest_input(self, task, port: str, ref: StagedRef):
        """Record that ``task`` needs ``ref`` dereferenced onto ``port``
        before launch (the executor's stage-in pass reads this)."""
        task.meta.setdefault("staged_refs", []).append(("input", port, ref))

    def acquire_stage_in(self, task, item: Any) -> StagedRef:
        """Stage one ``stage_in`` declaration for ``task``: put-or-retain
        by content, so N member tasks declaring the same input share ONE
        blob and each holds a reference."""
        value = item() if callable(item) else item
        with self._lock:
            ref = self.store.put(value, location=HOST)
        idx = sum(1 for e in task.meta.get("staged_refs", ())
                  if e[0] == "staged_in")
        task.meta.setdefault("staged_refs", []).append(
            ("staged_in", idx, ref))
        return ref

    def clone_manifest(self, orig, clone):
        """Route a speculative twin through the SAME staging manifests as
        its original: the clone holds its own reference on every ref (so
        either twin's terminal release is balanced) and its stage-in pass
        plans/executes — and charges to the clone's ``t_data`` — the same
        transfers, to the CLONE's granted pod."""
        entries = orig.meta.get("staged_refs") or []
        if not entries:
            return
        with self._lock:
            for _kind, _key, ref in entries:
                self.store.retain(ref)
        clone.meta["staged_refs"] = list(entries)

    # ------------------------------------------------------------ failures
    def on_pod_lost(self, pod: str):
        """The pod's memory is gone: invalidate its replicas.  Blobs keep
        serving from other replicas / host / spill — the next consumer in
        that pod copies instead of linking."""
        with self._lock:
            self.store.drop_location(pod)

    def on_topology_compacted(self, n_slots: int):
        """Shrink-recarve renumbered the slot ids: pod-keyed replica
        bookkeeping is stale wholesale (conservative reset — consumers
        fall back to host/spill copies), and the locality map re-keys to
        the new slot count."""
        with self._lock:
            self.store.drop_pod_locations()
            if self.locality is not None:
                # keep the pilot's pod-name prefix: a federated pilot that
                # compacts must not fall back into the shared unprefixed
                # namespace (its pods would alias another pilot's)
                self.locality = LocalityMap(
                    n_slots=max(n_slots, 1),
                    slots_per_pod=self.locality.slots_per_pod,
                    prefix=self.locality.prefix)
                self.planner.locality = self.locality

    # ------------------------------------------------------------ gc
    def gc_spill(self, journal=None, *, keep_durable: bool = True) -> int:
        """Session-close disk reclaim: delete zero-ref spill files the
        journal never references.  ``keep_durable=False`` drops the
        journal keep-set too (zero-ref files go regardless of journaled
        refs — ends restartability).  Returns files deleted."""
        referenced = (journal.load_digests()
                      if keep_durable and journal is not None
                      else frozenset())
        return self.store.gc_spill(referenced)

    # ------------------------------------------------------------ stage-in
    def stage_in(self, task, mode: str) -> float:
        """Execute every planned transfer for ``task`` to its granted pod.

        Runs between ``pop_ready`` and kernel launch (DES: on the drain
        loop before the finish-event push; real: on the worker thread
        before the kernel).  Returns the seconds charged to ``t_data`` —
        modeled cost in sim, measured wall time in real mode.  Dereferenced
        values land in ``task.meta["staged_values"]`` (by digest) and
        ``task.meta["staged_in_values"]`` (declaration order).
        """
        entries = task.meta.get("staged_refs")
        if not entries:
            return 0.0
        dst = self.location_for(task)
        t0 = time.perf_counter()
        modeled = 0.0
        values: Dict[str, Any] = task.meta.setdefault("staged_values", {})
        in_values: List[Any] = []
        transfers = []
        # plan under the lock (replica reads must be consistent); EXECUTE
        # outside it — worker threads copying different blobs must overlap
        # (the store and planner stats lock themselves)
        with self._lock:
            if mode == "sim":
                # a journal-replayed virtual ref has no live blob in the
                # restarted store; re-register it from the ref's own
                # metadata (virtual blobs carry no payload — only nbytes
                # and replica locations matter)
                for _kind, _key, ref in entries:
                    if not self.store.has(ref.digest):
                        self.store.register_virtual(ref)
            plans = [(kind, ref, self.planner.plan(ref, dst))
                     for kind, _key, ref in entries]
        for kind, ref, spec in plans:
            value = self.planner.execute(spec)
            modeled += spec.cost_s
            values[ref.digest] = value
            if kind == "staged_in":
                in_values.append(value)
            transfers.append({"digest": ref.digest[:10],
                              "nbytes": ref.nbytes, "mode": spec.mode,
                              "src": spec.src, "dst": spec.dst,
                              "cost_s": round(spec.cost_s, 6)})
        if in_values:
            task.meta["staged_in_values"] = in_values
        task.meta["transfers"] = \
            task.meta.get("transfers", []) + transfers
        t_data = (time.perf_counter() - t0) if mode == "real" else modeled
        task.t_data += t_data
        return t_data

    def resolve(self, task, value: Any) -> Any:
        """Top-level ref -> its staged-in value (nested refs stay lazy)."""
        if isinstance(value, StagedRef):
            staged = task.meta.get("staged_values", {})
            if value.digest in staged:
                return staged[value.digest]
            return self.store.get(value, location=self.location_for(task))
        return value

    def finish(self, task):
        """Terminal-state hook: drop the task's holds exactly once (a
        retried task keeps its refs until its FINAL attempt ends), and
        drop the decoded payloads pinned on the task — otherwise every
        consumer task would keep its inputs resident for the whole run,
        defeating the byte budget.  Returns the released digests (empty
        when this call was a no-op) so the executor can journal the
        release — the sanitizer's S303 balance check audits it."""
        entries = task.meta.get("staged_refs")
        if not entries or task.meta.get("staging_released"):
            return []
        task.meta["staging_released"] = True
        task.meta.pop("staged_values", None)
        task.meta.pop("staged_in_values", None)
        with self._lock:
            for _kind, _key, ref in entries:
                self.store.release(ref)
        return [ref.digest for _kind, _key, ref in entries]

    # ------------------------------------------------------------ placement
    def _ref_pods(self, task) -> set:
        pods = set()
        for _kind, _key, ref in task.meta.get("staged_refs", ()):
            pods |= self.store.locations(ref.digest) or set(ref.locations)
        return pods

    def preferred_ids(self, task, free_ids: List[int]) -> List[int]:
        """Order free slot ids so ids in pods that already hold the task's
        input replicas come first (locality-aware placement)."""
        if not self.prefer_local or self.locality is None \
                or not task.meta.get("staged_refs"):
            return list(free_ids)
        pods = self._ref_pods(task)
        if not pods:
            return list(free_ids)
        return sorted(free_ids,
                      key=lambda s: (self.locality.pod_of(s) not in pods, s))

    def prefers(self, task, free_ids: Optional[List[int]]) -> bool:
        """True when some free slot sits in a pod that already holds this
        task's inputs — the frontier scheduler runs such tasks first."""
        if not self.prefer_local or self.locality is None or not free_ids:
            return False
        pods = self._ref_pods(task)
        return bool(pods) and any(
            self.locality.pod_of(s) in pods for s in free_ids)

    # ------------------------------------------------------------ summary
    def summary(self) -> Dict[str, Any]:
        return {"store": dict(self.store.stats),
                "mem_bytes": self.store.mem_bytes,
                "peak_mem_bytes": self.store.peak_mem_bytes,
                "transfers": self.planner.summary()}


class TaskStagingView:
    """Per-task facade kernels see as ``ctx["staging"]``: explicit staging
    of bulk outputs (``put``) and lazy dereference of nested refs
    (``get``), with the work charged to THIS task's ``t_data``."""

    def __init__(self, layer: StagingLayer, task):
        self._layer = layer
        self._task = task

    def put(self, value: Any) -> StagedRef:
        """Stage a bulk output; embed the returned ref in the result in
        place of the payload (consumers deref lazily via ``get``)."""
        loc = self._layer.location_for(self._task)
        with self._layer._lock:
            ref = self._layer.store.put(value, location=loc)
        return ref

    def get(self, ref: StagedRef) -> Any:
        t0 = time.perf_counter()
        dst = self._layer.location_for(self._task)
        with self._layer._lock:
            spec = self._layer.planner.plan(ref, dst)
        value = self._layer.planner.execute(spec)
        dt = time.perf_counter() - t0
        self._task.t_data += dt
        # this deref ran INSIDE the kernel's wall-clock window; record it
        # so the executor can subtract it from t_exec (t_exec and t_data
        # must stay disjoint in the TTC decomposition)
        meta = self._task.meta
        meta["t_data_kernel"] = meta.get("t_data_kernel", 0.0) + dt
        return value
