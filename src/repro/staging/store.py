"""Content-addressed object store: hash-keyed blobs with replica tracking.

The EnTK paper's Kernel abstraction carries explicit staging directives
(``upload_input_data``/``copy_input_data``/``link_input_data``/
``download_output_data``); this store is the substrate those directives
resolve against at fleet scale.  Every staged payload is canonically
encoded, hashed, and kept exactly once (N ensemble members declaring the
same input blob share one entry — the paper's *link* semantics), with:

  replica tracking   per-location (pod / slot-submesh id) replica sets, the
                     input the transfer planner (transfer.py) uses to pick
                     link vs copy vs materialize
  ref-counting       every consumer holds a reference; the blob (and its
                     spill file) is dropped when the last consumer releases
  spill-to-disk      past ``byte_budget`` the least-recently-used blobs drop
                     their in-memory bytes; content-addressed spill files
                     are written through at put time, so a restarted run
                     can re-materialize journaled refs WITHOUT re-staging
  virtual blobs      DES (sim) mode stages bookkeeping-only refs with a
                     declared ``nbytes`` and no payload, so t_data and
                     locality are modeled at scale without moving bytes

A :class:`StagedRef` is the value that travels through channels and the
journal in place of the payload: ``(digest, nbytes, locations)`` — small,
JSON-encodable (ports.py), and resolvable from any location.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Set, Tuple

HOST = "host"          # location of data staged outside any pilot slot


@dataclass(frozen=True)
class StagedRef:
    """Content-addressed handle to a staged payload.

    ``locations`` is the replica set snapshot at creation time (the store
    tracks the live set); it is what survives a journal round-trip, so a
    restarted planner still knows where the blob once lived even before
    the spill file is re-registered.
    """
    digest: str
    nbytes: int
    locations: Tuple[str, ...] = ()

    def __repr__(self):
        return (f"StagedRef({self.digest[:10]}…, {self.nbytes}B, "
                f"@{list(self.locations)})")


@dataclass
class _Blob:
    nbytes: int
    data: Optional[bytes] = None       # None once spilled (or virtual)
    value: Any = None                  # decoded cache: the "link" fast path
    has_value: bool = False
    virtual: bool = False
    spilled: bool = False
    refcount: int = 0
    locations: Set[str] = field(default_factory=set)


def encode(value: Any) -> bytes:
    """Canonical encoding: sorted-key JSON when the value survives the
    round trip UNCHANGED (digest stable across dict insertion orders and
    processes), pickle otherwise.  The round-trip check matters for
    correctness, not just fidelity: without it, ``{1: "a"}`` and
    ``{"1": "a"}`` would collide on one digest, and tuples would decode
    as lists on the copy/materialize path while same-pod links returned
    the original object."""
    try:
        data = json.dumps(value, sort_keys=True, separators=(",", ":"))
        if json.loads(data) == value:
            return b"J" + data.encode()
    except (TypeError, ValueError):
        pass
    return b"P" + pickle.dumps(value, protocol=4)


def decode(data: bytes) -> Any:
    if data[:1] == b"J":
        return json.loads(data[1:].decode())
    return pickle.loads(data[1:])


def digest_of(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


class ObjectStore:
    """Hash-keyed blob store with ref-counts, replicas, and disk spill.

    ``byte_budget`` bounds the *in-memory* payload bytes; past it the
    least-recently-used blobs spill (their bytes drop from memory — the
    write-through spill file under ``spill_dir`` already holds them).
    Without a ``spill_dir`` the budget is advisory (nothing can be dropped
    safely); ``stats["over_budget"]`` counts the violations instead.
    """

    def __init__(self, byte_budget: int = 256 << 20,
                 spill_dir: Optional[str] = None):
        self.byte_budget = int(byte_budget)
        self.spill_dir = spill_dir
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)
        self._blobs: "OrderedDict[str, _Blob]" = OrderedDict()  # LRU order
        self._mem_bytes = 0            # running: bytes resident in memory
        self._lock = threading.RLock()
        self.stats: Dict[str, int] = {
            "puts": 0, "dedup_hits": 0, "bytes_put": 0, "spills": 0,
            "materializations": 0, "releases": 0, "evictions": 0,
            "over_budget": 0}
        self.peak_mem_bytes = 0

    # ------------------------------------------------------------ queries
    @property
    def mem_bytes(self) -> int:
        """Bytes resident in memory — a running counter (puts happen per
        channel put; an O(blobs) scan here would make staging O(n²))."""
        return self._mem_bytes

    def __len__(self):
        return len(self._blobs)

    def has(self, digest: str) -> bool:
        """Known in memory, as a virtual blob, or as a spill file."""
        with self._lock:
            if digest in self._blobs:
                return True
        return self._spill_path_exists(digest)

    def in_memory(self, digest: str) -> bool:
        with self._lock:
            b = self._blobs.get(digest)
            return b is not None and not b.spilled

    def spilled(self, digest: str) -> bool:
        with self._lock:
            b = self._blobs.get(digest)
            if b is not None:
                return b.spilled
        return self._spill_path_exists(digest)

    def locations(self, digest: str) -> Set[str]:
        with self._lock:
            b = self._blobs.get(digest)
            return set(b.locations) if b is not None else set()

    def refcount(self, digest: str) -> int:
        with self._lock:
            b = self._blobs.get(digest)
            return b.refcount if b is not None else 0

    # ------------------------------------------------------------ put
    def put(self, value: Any, location: Optional[str] = None, *,
            data: Optional[bytes] = None) -> StagedRef:
        """Stage a payload; returns a ref the caller holds (refcount +1).

        Content-addressed: a second put of equal content lands on the same
        blob (``dedup_hits``) — this is what makes N members sharing one
        input pay for it once.  ``data`` passes pre-encoded bytes so a
        caller that already measured the payload does not encode twice.
        """
        if data is None:
            data = encode(value)
        d = digest_of(data)
        with self._lock:
            b = self._blobs.get(d)
            if b is None:
                b = _Blob(nbytes=len(data), data=data, value=value,
                          has_value=True)
                self._blobs[d] = b
                self._mem_bytes += len(data)
                self.stats["puts"] += 1
                self.stats["bytes_put"] += len(data)
                self._write_through(d, data)
                self._enforce_budget()
            else:
                self._blobs.move_to_end(d)
                self.stats["dedup_hits"] += 1
                if not b.has_value:
                    b.value, b.has_value = value, True
            b.refcount += 1
            if location:
                b.locations.add(location)
            self.peak_mem_bytes = max(self.peak_mem_bytes, self.mem_bytes)
            return StagedRef(d, b.nbytes, tuple(sorted(b.locations)))

    def put_virtual(self, key: str, nbytes: int,
                    location: Optional[str] = None) -> StagedRef:
        """Stage a payload-free blob of declared size (DES mode): the
        digest derives from ``key`` so replay is deterministic."""
        d = digest_of(b"V" + key.encode())
        with self._lock:
            b = self._blobs.get(d)
            if b is None:
                b = _Blob(nbytes=int(nbytes), virtual=True)
                self._blobs[d] = b
                self.stats["puts"] += 1
                self.stats["bytes_put"] += int(nbytes)
            else:
                self.stats["dedup_hits"] += 1
            b.refcount += 1
            if location:
                b.locations.add(location)
            return StagedRef(d, b.nbytes, tuple(sorted(b.locations)))

    def add_location(self, digest: str, location: str):
        """Record a new replica (a completed transfer landed the blob
        there); unknown digests are re-registered from their spill file."""
        with self._lock:
            b = self._register_if_spilled(digest)
            if b is not None and location:
                b.locations.add(location)

    def drop_location(self, location: str):
        """Invalidate every replica at ``location`` (the pod died: its
        memory is gone).  Blobs whose ONLY replica lived there survive —
        the in-memory bytes / spill file / other replicas still serve
        consumers, just never as a link into the dead pod."""
        with self._lock:
            for b in self._blobs.values():
                b.locations.discard(location)

    def drop_pod_locations(self):
        """Invalidate every non-HOST replica (topology compaction: slot
        ids — and therefore pod names — renumbered, so pod-keyed replica
        bookkeeping is stale wholesale)."""
        with self._lock:
            for b in self._blobs.values():
                b.locations.intersection_update({HOST})

    def register_virtual(self, ref: StagedRef):
        """Re-register a journal-replayed virtual ref (DES restart): the
        blob never had a payload, so its nbytes and replica locations
        reconstruct it completely."""
        with self._lock:
            if ref.digest not in self._blobs:
                self._blobs[ref.digest] = _Blob(
                    nbytes=ref.nbytes, virtual=True,
                    locations=set(ref.locations))

    # ------------------------------------------------------------ get
    def get(self, ref_or_digest, location: Optional[str] = None,
            *, fresh: bool = False) -> Any:
        """Resolve a blob to its value.

        ``fresh=False`` returns the shared decoded object (the *link* path
        — zero work; consumers must treat staged inputs as read-only).
        ``fresh=True`` decodes a new object from bytes (the *copy* path).
        Spilled blobs re-load from disk (*materialize*) first.  Virtual
        blobs resolve to None.
        """
        d = ref_or_digest.digest if isinstance(ref_or_digest, StagedRef) \
            else ref_or_digest
        with self._lock:
            b = self._register_if_spilled(d)
            if b is None:
                raise KeyError(f"unknown blob {d[:10]}…")
            if b.virtual:
                if location:
                    b.locations.add(location)
                return None
            data = b.data
            if data is None:                       # spilled: materialize
                data = self._read_spill(d)
                b.data, b.spilled = data, False
                self._mem_bytes += b.nbytes
                self._blobs.move_to_end(d)
                self.stats["materializations"] += 1
                self._enforce_budget()
            if location:
                b.locations.add(location)
            if not fresh and b.has_value:
                self._blobs.move_to_end(d)     # link = a use: keep hot
                return b.value                 # blobs off the spill list
        # decode OUTSIDE the lock: concurrent worker threads copying
        # different blobs must not serialize on each other's deserialize
        value = decode(data)
        with self._lock:
            b = self._blobs.get(d)
            if b is not None and not b.has_value:
                b.value, b.has_value = value, True
        return value

    # ------------------------------------------------------------ refcount
    def retain(self, ref_or_digest, n: int = 1):
        d = ref_or_digest.digest if isinstance(ref_or_digest, StagedRef) \
            else ref_or_digest
        with self._lock:
            b = self._blobs.get(d)
            if b is not None:
                b.refcount += n

    def release(self, ref_or_digest, n: int = 1):
        """Drop ``n`` holds; at zero the blob leaves memory.  The
        content-addressed spill file is NOT deleted — it is the durable
        cache a journal replay re-materializes from after a crash (use
        :meth:`clear_spill` to reclaim disk).  Unknown digests (e.g. a
        post-restart consumer releasing a ref whose holds died with the
        previous process) are a no-op."""
        d = ref_or_digest.digest if isinstance(ref_or_digest, StagedRef) \
            else ref_or_digest
        with self._lock:
            b = self._blobs.get(d)
            if b is None:
                return
            b.refcount -= n
            self.stats["releases"] += 1
            if b.refcount <= 0:
                if not b.virtual and b.data is not None:
                    self._mem_bytes -= b.nbytes
                del self._blobs[d]
                self.stats["evictions"] += 1

    def clear_spill(self):
        """Explicit disk reclaim: delete every spill file (ends the
        restartability of journaled refs)."""
        if not self.spill_dir:
            return
        with self._lock:
            for fn in os.listdir(self.spill_dir):
                if fn.endswith(".blob"):
                    os.unlink(os.path.join(self.spill_dir, fn))

    def gc_spill(self, referenced=frozenset()) -> int:
        """Reclaim spill files that nothing can ever need again: zero-ref
        (no live consumer holds the blob) AND not in ``referenced`` (the
        digests the journal names — deleting those would break replay of
        journaled refs).  Returns the number of files deleted.  Live
        blobs whose bytes exist only on disk keep their files."""
        if not self.spill_dir:
            return 0
        n = 0
        with self._lock:
            for fn in os.listdir(self.spill_dir):
                if not fn.endswith(".blob"):
                    continue
                digest = fn[:-len(".blob")]
                if digest in referenced:
                    continue
                b = self._blobs.get(digest)
                if b is not None and b.refcount > 0:
                    continue
                os.unlink(os.path.join(self.spill_dir, fn))
                self.stats["spill_gcs"] = self.stats.get("spill_gcs", 0) + 1
                n += 1
        return n

    # ------------------------------------------------------------ spill
    def spill(self, digest: str) -> bool:
        """Explicitly drop a blob's bytes from memory (keeps the spill
        file / virtual bookkeeping).  Returns True if it spilled."""
        with self._lock:
            b = self._blobs.get(digest)
            if b is None or b.spilled:
                return False
            if b.virtual:
                b.spilled = True
                self.stats["spills"] += 1
                return True
            if not self._spill_path_exists(digest):
                return False               # nowhere durable to put it
            b.data, b.value, b.has_value = None, None, False
            b.spilled = True
            self._mem_bytes -= b.nbytes
            self.stats["spills"] += 1
            return True

    def _enforce_budget(self):
        if self.mem_bytes <= self.byte_budget:
            return
        if not self.spill_dir:
            self.stats["over_budget"] += 1
            return
        for d in list(self._blobs):        # LRU first
            if self.mem_bytes <= self.byte_budget:
                break
            b = self._blobs[d]
            if not b.virtual and not b.spilled:
                self.spill(d)

    # ------------------------------------------------------------ disk
    def _spill_path(self, digest: str) -> Optional[str]:
        return os.path.join(self.spill_dir, f"{digest}.blob") \
            if self.spill_dir else None

    def _spill_path_exists(self, digest: str) -> bool:
        p = self._spill_path(digest)
        return p is not None and os.path.exists(p)

    def _write_through(self, digest: str, data: bytes):
        p = self._spill_path(digest)
        if p and not os.path.exists(p):
            tmp = p + ".tmp"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, p)             # atomic: no torn spill files

    def _read_spill(self, digest: str) -> bytes:
        with open(self._spill_path(digest), "rb") as f:
            return f.read()

    def _register_if_spilled(self, digest: str) -> Optional[_Blob]:
        """A digest known only as a spill file (journal replay after a
        restart) gets a live entry so replicas/refcounts work again."""
        b = self._blobs.get(digest)
        if b is None and self._spill_path_exists(digest):
            nbytes = os.path.getsize(self._spill_path(digest))
            b = _Blob(nbytes=nbytes, data=None, spilled=True)
            self._blobs[digest] = b
        return b

    def __repr__(self):
        return (f"ObjectStore({len(self._blobs)} blobs, "
                f"{self.mem_bytes}B in memory, budget {self.byte_budget}B)")
