"""repro.staging — content-addressed data staging with locality-aware
transfers and t_data accounting.

The paper decomposes TTC into execution, overhead, and data movement
(``t_data``); its Kernel abstraction carries explicit staging directives.
This package models that subsystem at fleet scale:

  store.py      content-addressed ObjectStore (hash-keyed blobs,
                ref-counted, spill-to-disk past a byte budget) with
                per-pod replica tracking; ``StagedRef`` handles
  transfer.py   ``LocalityMap`` + ``TransferPlanner``: link when producer
                and consumer share a pod, copy across pods, materialize
                from spilled blobs — each charged to ``t_data``
  ports.py      ``StagingLayer``: Channel puts of large values become
                staged refs, transparently dereferenced into
                ``ctx["inputs"]`` between ``pop_ready`` and kernel launch;
                journaled refs replay without re-staging

Enable it per pilot::

    from repro.staging import LocalityMap, StagingLayer
    rt = PilotRuntime(slots=8, mode="real",
                      staging=StagingLayer(
                          locality=LocalityMap(8, slots_per_pod=4),
                          spill_dir="/tmp/blobs", threshold_bytes=1 << 12))
"""
from repro.staging.ports import (  # noqa: F401
    StagingLayer,
    TaskStagingView,
    decode_refs,
    encode_refs,
    iter_refs,
    payload_nbytes,
)
from repro.staging.store import HOST, ObjectStore, StagedRef  # noqa: F401
from repro.staging.transfer import (  # noqa: F401
    LocalityMap,
    TransferPlanner,
    TransferSpec,
)
