"""While-aware HLO cost model for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE — a
scanned-layer model or microbatch accumulation loop under-reports FLOPs and
bytes by the trip count (verified empirically: a 10-step scan of matmuls
reports 1x the matmul FLOPs).  Collective bytes are absent entirely.  So we
parse the post-partitioning HLO text (``compiled.as_text()``, per-device
shapes) ourselves:

  * computations reachable from ENTRY via while/call/conditional are
    traversed; ``while`` bodies/conditions are weighted by the trip count
    recovered from the loop condition's comparison constant;
  * fusions contribute operand+result bytes (XLA's own convention);
  * dot FLOPs = 2 * prod(result dims) * prod(contraction dims);
  * collective on-wire bytes = result bytes x kind factor (ring all-reduce
    moves ~2x payload; gather/scatter/a2a/permute ~1x).

Everything is per-device (the module is already partitioned).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_ARR_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")


def _arrays_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _ARR_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims or [1])
               for dt, dims in _arrays_in(type_str))


@dataclass
class Instr:
    name: str
    rtype: str
    op: str
    rest: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)


_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "add-dependency", "opt-barrier", "partition-id",
             "replica-id", "iota", "copy-start", "copy-done"}

# ops whose known names we must split out of `rest`
_OP_RE = re.compile(
    r"^(all-gather-start|all-gather-done|all-gather|all-reduce-start|"
    r"all-reduce-done|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute-start|collective-permute-done|collective-permute|"
    r"dynamic-update-slice|dynamic-slice|get-tuple-element|"
    r"[\w\-]+)\(")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if (not line.startswith(" ") and line.rstrip().endswith("{")
                and "->" in line):
            s = line.strip()
            is_entry = s.startswith("ENTRY")
            if is_entry:
                s = s[len("ENTRY"):].strip()
            name = s.split("(", 1)[0].strip().lstrip("%").strip()
            if name:
                cur = Computation(name)
                comps[name] = cur
                if is_entry:
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "<type> <op>(...), attrs"; type may be a tuple "(a, b)"
        if rhs.startswith("("):
            depth = 0
            for i, ch in enumerate(rhs):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            rtype, rest = rhs[:i + 1], rhs[i + 1:].strip()
        else:
            sp = rhs.find(" ")
            rtype, rest = rhs[:sp], rhs[sp + 1:].strip()
        om = _OP_RE.match(rest)
        op = om.group(1) if om else rest.split("(")[0].strip()
        args = rest[rest.find("(") + 1:]
        # operand names up to the closing paren of the arg list
        depth = 1
        end = 0
        for i, ch in enumerate(args):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                end = i
                break
        operands = _OPND_RE.findall(args[:end]) if end else []
        ins = Instr(name, rtype, op, rest, operands)
        cur.instrs.append(ins)
        cur.types[name] = rtype
    return comps, entry


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(comp: Computation) -> int:
    """Heuristic: the loop bound is the max s32 constant in the condition."""
    best = 1
    for ins in comp.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    result_elems = math.prod(
        [math.prod(dims or [1]) for _, dims in _arrays_in(ins.rtype)] or [0])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not m or not ins.operands:
        return 2.0 * result_elems  # fallback
    cdims = [int(d) for d in m.group(1).split(",") if d]
    lhs_t = comp.types.get(ins.operands[0], "")
    arrs = _arrays_in(lhs_t)
    if not arrs:
        return 2.0 * result_elems
    lhs_dims = arrs[0][1]
    contract = math.prod([lhs_dims[d] for d in cdims if d < len(lhs_dims)]
                         or [1])
    return 2.0 * result_elems * contract


def _conv_flops(comp: Computation, ins: Instr) -> float:
    result_elems = math.prod(
        [math.prod(dims or [1]) for _, dims in _arrays_in(ins.rtype)] or [0])
    m = re.search(r"window=\{size=([\dx]+)", ins.rest)
    ksize = math.prod(int(x) for x in m.group(1).split("x")) if m else 1
    fg = re.search(r"feature_group_count=(\d+)", ins.rest)
    groups = int(fg.group(1)) if fg else 1
    in_feat = 1
    if len(ins.operands) > 1:
        arrs = _arrays_in(comp.types.get(ins.operands[1], ""))
        if arrs:  # kernel [spatial..., in/groups, out]
            in_feat = arrs[0][1][-2] if len(arrs[0][1]) >= 2 else 1
    return 2.0 * result_elems * ksize * in_feat


@dataclass
class Costs:
    flops: float = 0.0               # dot + conv FLOPs (MXU work)
    bytes_accessed: float = 0.0      # operand+result bytes at fusion level
    collectives: Dict[str, Dict[str, float]] = field(
        default_factory=lambda: defaultdict(
            lambda: {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0}))

    @property
    def collective_wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.collectives.values())

    def scaled(self, k: float) -> "Costs":
        c = Costs(self.flops * k, self.bytes_accessed * k)
        for kind, v in self.collectives.items():
            c.collectives[kind] = {kk: vv * k for kk, vv in v.items()}
        return c

    def add(self, other: "Costs"):
        self.flops += other.flops
        self.bytes_accessed += other.bytes_accessed
        for kind, v in other.collectives.items():
            mine = self.collectives[kind]
            for kk, vv in v.items():
                mine[kk] += vv


def _fusion_bytes(comps: Dict[str, Computation], comp: Computation,
                  ins: Instr) -> float:
    """Bytes accessed by a fusion: parameters consumed only through
    dynamic-slice count the slice bytes (loop-carried stacked buffers are
    sliced per iteration, not read fully); a dynamic-update-slice root
    aliases its buffer in place, so it writes only the update bytes."""
    called_name = _attr(ins.rest, "calls")
    called = comps.get(called_name) if called_name else None
    if called is None:
        b = _type_bytes(ins.rtype)
        for o in ins.operands:
            b += _type_bytes(comp.types.get(o, ""))
        return b

    # --- parameter reads ---------------------------------------------------
    param_names: Dict[str, int] = {}
    for fi in called.instrs:
        if fi.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", fi.rest)
            if m:
                param_names[fi.name] = int(m.group(1))
    uses: Dict[str, List[Instr]] = defaultdict(list)
    dus_buffers = set()
    for fi in called.instrs:
        for o in fi.operands:
            if o in param_names:
                uses[o].append(fi)
        if fi.op == "dynamic-update-slice" and fi.operands:
            if fi.operands[0] in param_names:
                dus_buffers.add(fi.operands[0])
    total = 0.0
    for pname, idx in param_names.items():
        if idx >= len(ins.operands):
            continue
        full = _type_bytes(comp.types.get(ins.operands[idx], ""))
        us = uses.get(pname, [])
        if not us:
            continue
        if all(u.op == "dynamic-slice" for u in us):
            total += sum(_type_bytes(u.rtype) for u in us)
        elif pname in dus_buffers and all(
                u.op == "dynamic-update-slice" for u in us):
            pass  # aliased in-place buffer: writes counted at the root
        else:
            total += full

    # --- result writes -----------------------------------------------------
    root = next((fi for fi in called.instrs
                 if fi.rest and fi is called.instrs[-1]), None)
    roots = [root] if root is not None else []
    if root is not None and root.op == "tuple":
        roots = [next((fi for fi in called.instrs if fi.name == o), None)
                 for o in root.operands]
    res = 0.0
    for r in roots:
        if r is None:
            res += 0
        elif r.op == "dynamic-update-slice" and len(r.operands) >= 2:
            res += _type_bytes(called.types.get(r.operands[1], ""))
        else:
            res += _type_bytes(r.rtype)
    if not roots:
        res = _type_bytes(ins.rtype)
    return total + res


def _comp_costs(comps: Dict[str, Computation], name: str,
                memo: Dict[str, Costs]) -> Costs:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    total = Costs()
    memo[name] = total
    if comp is None:
        return total
    for ins in comp.instrs:
        if ins.op in _SKIP_OPS:
            continue
        if ins.op == "while":
            body = _attr(ins.rest, "body")
            cond = _attr(ins.rest, "condition")
            trips = _trip_count(comps[cond]) if cond in comps else 1
            if body in comps:
                total.add(_comp_costs(comps, body, memo).scaled(trips))
            continue
        if ins.op == "call":
            to = _attr(ins.rest, "to")
            if to in comps:
                total.add(_comp_costs(comps, to, memo))
            continue
        if ins.op == "conditional":
            for br in re.findall(r"%([\w.\-]+)",
                                 ins.rest[ins.rest.find(")"):]):
                if br in comps:
                    total.add(_comp_costs(comps, br, memo))
            continue
        kind = ins.op.replace("-start", "")
        if kind in COLLECTIVE_KINDS and not ins.op.endswith("-done"):
            b = _type_bytes(ins.rtype)
            # -start ops return (operand, result, ...) tuples: halve
            if ins.op.endswith("-start"):
                b = b / 2
            c = total.collectives[kind]
            c["count"] += 1
            c["result_bytes"] += b
            c["wire_bytes"] += b * _WIRE_FACTOR[kind]
            total.bytes_accessed += b
            continue
        if ins.op.endswith("-done"):
            continue
        if ins.op == "dot":
            total.flops += _dot_flops(comp, ins)
        elif ins.op == "convolution":
            total.flops += _conv_flops(comp, ins)
        # bytes at fusion/instruction boundary
        if ins.op == "fusion":
            b = _fusion_bytes(comps, comp, ins)
        elif ins.op == "dynamic-slice":
            b = 2 * _type_bytes(ins.rtype)
        elif ins.op == "dynamic-update-slice" and len(ins.operands) >= 2:
            b = 2 * _type_bytes(comp.types.get(ins.operands[1], ""))
        else:
            b = _type_bytes(ins.rtype)
            for o in ins.operands:
                b += _type_bytes(comp.types.get(o, ""))
        total.bytes_accessed += b
    memo[name] = total
    return total


def module_costs(hlo_text: str) -> Costs:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return Costs()
    return _comp_costs(comps, entry, {})


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return dict(module_costs(hlo_text).collectives)


def total_collective_bytes(hlo_text: str) -> float:
    return module_costs(hlo_text).collective_wire_bytes
