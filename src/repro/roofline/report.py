"""Three-term roofline report per (arch x shape x mesh) from dry-run costs.

  compute term    = dot_FLOPs_per_device / peak_FLOP/s
  memory term     = bytes_accessed_per_device / HBM_bw
  collective term = collective_wire_bytes_per_device / ICI_bw

All terms are per-device seconds for one step (the HLO is already
partitioned, so per-device quantities come straight from the module).
MODEL_FLOPS uses the 6*N*D (train) / 2*N*D (inference) convention with
N = active params.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import HW
from repro.roofline.hlo_costs import Costs


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO flops x chips)
    roofline_frac: float         # useful-compute time / max(t_*)
    collectives: Dict[str, Dict[str, float]]
    memory_stats: Optional[Dict[str, float]] = None
    # decode cells: bytes optimality (ideal = params+cache read once)
    ideal_bytes_per_dev: Optional[float] = None
    mem_ideal_frac: Optional[float] = None

    def to_dict(self):
        return asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6*N*D for training; 2*N*D per generated/processed token otherwise."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


def make_row(cfg: ModelConfig, shape: ShapeSpec, mesh_name: str, chips: int,
             costs: Costs, memory_stats=None,
             ideal_bytes_total: Optional[float] = None) -> RooflineRow:
    t_c = costs.flops / HW.peak_flops
    t_m = costs.bytes_accessed / HW.hbm_bw
    t_x = costs.collective_wire_bytes / HW.ici_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = costs.flops * chips
    useful = mf / hlo_total if hlo_total else 0.0
    t_max = max(t_c, t_m, t_x)
    # "roofline fraction": how much of the step time is the *useful compute*
    # lower bound.  useful_time = MODEL_FLOPS/(chips*peak); achieved step
    # time >= t_max  =>  fraction = useful_time / t_max.
    useful_time = mf / (chips * HW.peak_flops)
    frac = useful_time / t_max if t_max else 0.0
    ideal_pd = (ideal_bytes_total / chips) if ideal_bytes_total else None
    mem_frac = (ideal_pd / costs.bytes_accessed
                if ideal_pd and costs.bytes_accessed else None)
    return RooflineRow(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_dev=costs.flops, bytes_per_dev=costs.bytes_accessed,
        coll_bytes_per_dev=costs.collective_wire_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_flops_total=mf, useful_ratio=useful,
        roofline_frac=frac,
        collectives={k: dict(v) for k, v in costs.collectives.items()},
        memory_stats=memory_stats,
        ideal_bytes_per_dev=ideal_pd, mem_ideal_frac=mem_frac)


def format_table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':10s} "
           f"{'t_comp(ms)':>10s} {'t_mem(ms)':>10s} {'t_coll(ms)':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.mesh:10s} "
            f"{r.t_compute*1e3:10.2f} {r.t_memory*1e3:10.2f} "
            f"{r.t_collective*1e3:10.2f} {r.bottleneck:>10s} "
            f"{r.useful_ratio:7.3f} {100*r.roofline_frac:6.1f}%")
    return "\n".join(lines)
