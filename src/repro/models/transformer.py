"""Composable model definition: parameter init, forward (train/prefill) and
decode step for every assigned architecture family.

Layer stacks are scanned over *pattern periods*: the scan unit is one full
cycle of ``cfg.layer_pattern`` (so per-layer attention kinds stay static and
the chunked attention can prune kv ranges); remainder layers are unrolled in
``tail``.  Heterogeneous stacks (recurrentgemma) set ``scan_layers=False`` and
unroll entirely.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain_batch, constrain_logits
from repro.models import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------- init

def _init_block(cfg: ModelConfig, key, kind: str, *, cross: bool = False,
                enc: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": L.init_norm(cfg)}
    if kind in ("global", "local", "enc"):
        p["attn"] = L.init_attn(cfg, ks[0])
        if cfg.post_norms:
            p["ln1_post"] = L.init_norm(cfg)
        if cross:
            p["lnx"] = L.init_norm(cfg)
            p["xattn"] = L.init_attn(cfg, ks[1], cross=True)
        p["ln2"] = L.init_norm(cfg)
        if cfg.num_experts and not enc:
            p["moe"] = L.init_moe(cfg, ks[2])
        else:
            p["mlp"] = L.init_mlp(cfg, ks[2])
        if cfg.post_norms:
            p["ln2_post"] = L.init_norm(cfg)
    elif kind == "rec":
        p["rec"] = L.init_rglru(cfg, ks[0])
        p["ln2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(cfg, ks[1])
    elif kind == "mamba":
        p["mamba"] = L.init_mamba(cfg, ks[0])
    else:
        raise ValueError(kind)
    return p


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(period, num_scanned_groups, num_tail_layers)."""
    period = len(cfg.layer_pattern)
    if not cfg.scan_layers:
        return period, 0, cfg.num_layers
    G = cfg.num_layers // period
    return period, G, cfg.num_layers - G * period


def init_params(cfg: ModelConfig, key) -> Params:
    kE, kH, kB, kT, kEnc = jax.random.split(key, 5)
    D, V = cfg.d_model, cfg.vocab_size
    params: Params = {
        "embed": {"tok": L._normal(kE, (V, D), 0.02, L._pd(cfg))},
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = L._normal(kH, (D, V), 0.02, L._pd(cfg))

    period, G, n_tail = _layout(cfg)
    cross = cfg.encoder_layers > 0

    def init_group(k):
        sub = {}
        for s in range(period):
            sub[f"sub_{s}"] = _init_block(
                cfg, jax.random.fold_in(k, s), cfg.layer_pattern[s],
                cross=cross)
        return sub

    if G:
        params["blocks"] = jax.vmap(init_group)(jax.random.split(kB, G))
    tail = {}
    for j in range(n_tail):
        i = G * period + j
        tail[f"block_{j}"] = _init_block(
            cfg, jax.random.fold_in(kT, j), cfg.layer_kind(i), cross=cross)
    if tail:
        params["tail"] = tail

    if cfg.encoder_layers:
        def init_enc(k):
            return {"sub_0": _init_block(cfg, k, "enc", enc=True)}
        params["enc"] = {
            "blocks": jax.vmap(init_enc)(
                jax.random.split(kEnc, cfg.encoder_layers)),
            "final_norm": L.init_norm(cfg),
        }
    return params


# ---------------------------------------------------------------- blocks

def forward_block(cfg: ModelConfig, bp: Params, h, kind: str, *, positions,
                  seg_ids, mem, mesh, cache_len: Optional[int]):
    """Returns (h, aux, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("global", "local", "enc"):
        xin = L.apply_norm(cfg, bp["ln1"], h)
        if cache_len:
            a, kv = _attn_with_cache(cfg, bp["attn"], xin, kind=kind,
                                     positions=positions, seg_ids=seg_ids,
                                     mesh=mesh, cache_len=cache_len)
            cache = kv
        else:
            a = L.apply_attn(cfg, bp["attn"], xin, kind=kind,
                             positions=positions, seg_ids=seg_ids, mesh=mesh)
        if cfg.post_norms:
            a = L.apply_norm(cfg, bp["ln1_post"], a)
        h = h + a
        if "xattn" in bp and mem is not None:
            xin = L.apply_norm(cfg, bp["lnx"], h)
            if cache_len:
                xa, xkv = _cross_with_cache(cfg, bp["xattn"], xin, mem)
                cache.update(xkv)
            else:
                xa = L.apply_attn(cfg, bp["xattn"], xin, kind="cross",
                                  positions=positions, mem=mem, mesh=mesh)
            h = h + xa
        xin = L.apply_norm(cfg, bp["ln2"], h)
        if "moe" in bp:
            y, aux = L.apply_moe(cfg, bp["moe"], xin, mesh=mesh)
        else:
            y = L.apply_mlp(cfg, bp["mlp"], xin)
        if cfg.post_norms:
            y = L.apply_norm(cfg, bp["ln2_post"], y)
        h = h + y
    elif kind == "rec":
        xin = L.apply_norm(cfg, bp["ln1"], h)
        if cache_len:
            m, cache = L.apply_rglru(cfg, bp["rec"], xin, mesh=mesh,
                                     return_state=True)
        else:
            m = L.apply_rglru(cfg, bp["rec"], xin, mesh=mesh)
        h = h + m
        y = L.apply_mlp(cfg, bp["mlp"], L.apply_norm(cfg, bp["ln2"], h))
        h = h + y
    elif kind == "mamba":
        xin = L.apply_norm(cfg, bp["ln1"], h)
        if cache_len:
            m, cache = L.apply_mamba(cfg, bp["mamba"], xin, mesh=mesh,
                                     return_state=True)
        else:
            m = L.apply_mamba(cfg, bp["mamba"], xin, mesh=mesh)
        h = h + m
    else:
        raise ValueError(kind)
    return h, aux, cache


def _attn_with_cache(cfg, p, x, *, kind, positions, seg_ids, mesh, cache_len):
    """Prefill: compute attention AND return the kv cache (roped keys)."""
    B, S, _ = x.shape
    q, k, v = L._qkv(cfg, p, x, positions, kind)
    causal = kind != "enc"
    window = cfg.sliding_window if kind == "local" else 0
    from repro.kernels.flash_attention.ops import flash_attention
    o = flash_attention(q, k, v, causal=causal, window=window,
                        softcap=cfg.attn_softcap,
                        scale=cfg.attn_scale or None,
                        seg_q=seg_ids, seg_kv=seg_ids)
    out = o.reshape(B, S, cfg.q_dim) @ L.cast(cfg, p["wo"])
    if kind == "local" and cfg.sliding_window:
        W = cfg.sliding_window
        take = min(W, S)
        ks, vs = k[:, -take:], v[:, -take:]
        pos_tail = jnp.arange(S - take, S, dtype=jnp.int32)
        slots = pos_tail % W
        kc = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(ks)
        vc = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(vs)
        pc = jnp.full((W,), -1, jnp.int32).at[slots].set(pos_tail)
        cache = {"k": kc, "v": vc, "pos": pc}
    else:
        pad = cache_len - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": kc, "v": vc}
    return out, cache


def _cross_with_cache(cfg, p, x, mem):
    B, S, _ = x.shape
    Sm = mem.shape[1]
    q = (x @ L.cast(cfg, p["wq"])).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (mem @ L.cast(cfg, p["wk"])).reshape(B, Sm, cfg.num_kv_heads,
                                             cfg.head_dim)
    v = (mem @ L.cast(cfg, p["wv"])).reshape(B, Sm, cfg.num_kv_heads,
                                             cfg.head_dim)
    from repro.kernels.flash_attention.ops import flash_attention
    o = flash_attention(q, k, v, causal=False, softcap=cfg.attn_softcap,
                        scale=cfg.attn_scale or None)
    out = o.reshape(B, S, cfg.q_dim) @ L.cast(cfg, p["wo"])
    return out, {"xk": k, "xv": v}


def decode_block(cfg: ModelConfig, bp: Params, h, cache: Params, kind: str,
                 *, positions, mesh):
    """Single-token step.  h: (B,1,D).  Returns (h, new_cache)."""
    new_cache = dict(cache)
    if kind in ("global", "local"):
        xin = L.apply_norm(cfg, bp["ln1"], h)
        sub = {k: cache[k] for k in ("k", "v", "pos") if k in cache}
        a, upd = L.attn_decode(cfg, bp["attn"], xin, sub, positions,
                               kind=kind, mesh=mesh)
        new_cache.update(upd)
        if cfg.post_norms:
            a = L.apply_norm(cfg, bp["ln1_post"], a)
        h = h + a
        if "xattn" in bp and "xk" in cache:
            xin = L.apply_norm(cfg, bp["lnx"], h)
            xa = L.attn_decode_cross(cfg, bp["xattn"], xin,
                                     {"xk": cache["xk"], "xv": cache["xv"]})
            h = h + xa
        xin = L.apply_norm(cfg, bp["ln2"], h)
        if "moe" in bp:
            y, _ = L.apply_moe(cfg, bp["moe"], xin, mesh=mesh)
        else:
            y = L.apply_mlp(cfg, bp["mlp"], xin)
        if cfg.post_norms:
            y = L.apply_norm(cfg, bp["ln2_post"], y)
        h = h + y
    elif kind == "rec":
        xin = L.apply_norm(cfg, bp["ln1"], h)
        m, upd = L.rglru_decode(cfg, bp["rec"], xin,
                                {"h": cache["h"], "conv": cache["conv"]})
        new_cache.update(upd)
        h = h + m
        h = h + L.apply_mlp(cfg, bp["mlp"], L.apply_norm(cfg, bp["ln2"], h))
    elif kind == "mamba":
        xin = L.apply_norm(cfg, bp["ln1"], h)
        m, upd = L.mamba_decode(cfg, bp["mamba"], xin,
                                {"h": cache["h"], "conv": cache["conv"]})
        new_cache.update(upd)
        h = h + m
    else:
        raise ValueError(kind)
    return h, new_cache


# ---------------------------------------------------------------- embed/head

def embed_tokens(cfg: ModelConfig, params: Params, tokens, positions):
    e = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(L._dt(cfg))
    if cfg.emb_scale:
        e = e * jnp.asarray(math.sqrt(cfg.d_model), L._dt(cfg))
    if cfg.rope_theta == 0:  # absolute sinusoidal positions (whisper)
        e = e + L.sinusoidal_pos(positions, cfg.d_model).astype(L._dt(cfg))
    return e


def lm_logits(cfg: ModelConfig, params: Params, h, *, mesh=None):
    """Full logits (serve path; training uses the fused chunked loss)."""
    w = params["embed"]["tok"] if cfg.tie_embeddings else params["head"]
    if cfg.tie_embeddings:
        logits = h.astype(jnp.float32) @ w.astype(jnp.float32).T
    else:
        logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    logits = constrain_logits(cfg, mesh, logits)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


# ---------------------------------------------------------------- encoder

def encode(cfg: ModelConfig, params: Params, enc_frames, *, mesh=None,
           remat: bool = False, batch_kind: str = "train"):
    B, S, _ = enc_frames.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = enc_frames.astype(L._dt(cfg))
    if cfg.rope_theta == 0:
        h = h + L.sinusoidal_pos(pos, cfg.d_model).astype(L._dt(cfg))
    h = constrain_batch(cfg, mesh, h, batch_kind)

    def body(carry, bp):
        hh = carry
        hh, _, _ = forward_block(cfg, bp["sub_0"], hh, "enc", positions=pos,
                                 seg_ids=None, mem=None, mesh=mesh,
                                 cache_len=None)
        hh = constrain_batch(cfg, mesh, hh, batch_kind)
        return hh, None

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    h, _ = lax.scan(body, h, params["enc"]["blocks"])
    return L.apply_norm(cfg, params["enc"]["final_norm"], h)


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None  # "full": save nothing


# ---------------------------------------------------------------- forward

def forward(cfg: ModelConfig, params: Params, tokens, *, positions=None,
            seg_ids=None, vision_embeds=None, enc_frames=None, mesh=None,
            remat: bool = False, cache_len: Optional[int] = None,
            batch_kind: str = "train"):
    """Returns dict with h (B,S,D final-normed), aux (scalar), cache (or None).

    ``cache_len``: when set, collect a decode cache (prefill mode); caches
    for global-attention layers are padded to this length.
    """
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    h = embed_tokens(cfg, params, tokens, positions)
    if vision_embeds is not None and cfg.vision_tokens:
        vt = vision_embeds.shape[1]
        h = jnp.concatenate([vision_embeds.astype(h.dtype), h[:, vt:]], 1)
    h = constrain_batch(cfg, mesh, h, batch_kind)
    mem = None
    if enc_frames is not None and cfg.encoder_layers:
        mem = encode(cfg, params, enc_frames, mesh=mesh, remat=remat,
                     batch_kind=batch_kind)

    period, G, n_tail = _layout(cfg)
    aux = jnp.zeros((), jnp.float32)
    collect = cache_len is not None
    cache: Params = {}

    if G:
        def body(carry, bp):
            hh, ax = carry
            cg = {}
            for s in range(period):
                kind = cfg.layer_pattern[s]
                hh, a, c = forward_block(cfg, bp[f"sub_{s}"], hh, kind,
                                         positions=positions, seg_ids=seg_ids,
                                         mem=mem, mesh=mesh,
                                         cache_len=cache_len)
                ax = ax + a
                hh = constrain_batch(cfg, mesh, hh, batch_kind)
                if collect:
                    cg[f"sub_{s}"] = c
            return (hh, ax), (cg if collect else None)

        if remat:
            body = jax.checkpoint(body, policy=_remat_policy(cfg))
        (h, aux), blocks_cache = lax.scan(body, (h, aux), params["blocks"])
        if collect:
            cache["blocks"] = blocks_cache

    if n_tail:
        tail_cache = {}
        for j in range(n_tail):
            i = G * period + j
            kind = cfg.layer_kind(i)
            blk = lambda hh, bp, kind=kind: forward_block(
                cfg, bp, hh, kind, positions=positions, seg_ids=seg_ids,
                mem=mem, mesh=mesh, cache_len=cache_len)
            if remat:
                blk = jax.checkpoint(blk, policy=_remat_policy(cfg))
            h, a, c = blk(h, params["tail"][f"block_{j}"])
            h = constrain_batch(cfg, mesh, h, batch_kind)
            aux = aux + a
            if collect:
                tail_cache[f"block_{j}"] = c
        if collect:
            cache["tail"] = tail_cache

    h = L.apply_norm(cfg, params["final_norm"], h)
    return {"h": h, "aux": aux, "cache": cache if collect else None}


# ---------------------------------------------------------------- decode

def decode_step(cfg: ModelConfig, params: Params, cache: Params, tokens,
                positions, *, mesh=None):
    """One token for the whole batch.  tokens: (B,1); positions: (B,) —
    per-row offsets: rows may sit at different sequence positions (see
    layers.attn_decode), which is what lets the continuous-batching server
    admit a freshly prefilled request into a running decode wave.
    Returns (logits (B,1,V), new cache)."""
    B = tokens.shape[0]
    h = embed_tokens(cfg, params, tokens, positions[:, None])
    h = constrain_batch(cfg, mesh, h, "serve")
    period, G, n_tail = _layout(cfg)
    new_cache: Params = {}

    if G:
        def body(carry, xs):
            hh = carry
            bp, cg = xs
            ncg = {}
            for s in range(period):
                kind = cfg.layer_pattern[s]
                hh, nc = decode_block(cfg, bp[f"sub_{s}"], hh, cg[f"sub_{s}"],
                                      kind, positions=positions, mesh=mesh)
                ncg[f"sub_{s}"] = nc
            hh = constrain_batch(cfg, mesh, hh, "serve")
            return hh, ncg

        h, nbc = lax.scan(body, h, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = nbc

    if n_tail:
        nt = {}
        for j in range(n_tail):
            i = G * period + j
            kind = cfg.layer_kind(i)
            h, nc = decode_block(cfg, params["tail"][f"block_{j}"], h,
                                 cache["tail"][f"block_{j}"], kind,
                                 positions=positions, mesh=mesh)
            nt[f"block_{j}"] = nc
        new_cache["tail"] = nt

    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = lm_logits(cfg, params, h, mesh=mesh)
    return logits, new_cache


# ---------------------------------------------------------------- cache init

def _block_cache_zeros(cfg: ModelConfig, kind: str, B: int, cache_len: int,
                       cross: bool):
    dt = L._dt(cfg)
    KH, Dh = cfg.num_kv_heads, cfg.head_dim
    if kind in ("global", "local"):
        if kind == "local" and cfg.sliding_window:
            W = min(cfg.sliding_window, cache_len)
            c = {"k": jnp.zeros((B, W, KH, Dh), dt),
                 "v": jnp.zeros((B, W, KH, Dh), dt),
                 "pos": jnp.full((W,), -1, jnp.int32)}
        else:
            c = {"k": jnp.zeros((B, cache_len, KH, Dh), dt),
                 "v": jnp.zeros((B, cache_len, KH, Dh), dt)}
        if cross:
            c["xk"] = jnp.zeros((B, cfg.encoder_seq, KH, Dh), dt)
            c["xv"] = jnp.zeros((B, cfg.encoder_seq, KH, Dh), dt)
        return c
    if kind == "rec":
        W = cfg.lru_width_
        return {"h": jnp.zeros((B, W), jnp.float32),
                "conv": jnp.zeros((B, cfg.ssm_conv - 1, W), dt)}
    if kind == "mamba":
        return {"h": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), dt)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, B: int, cache_len: int) -> Params:
    period, G, n_tail = _layout(cfg)
    cross = cfg.encoder_layers > 0
    cache: Params = {}
    if G:
        def one(_):
            return {f"sub_{s}": _block_cache_zeros(
                cfg, cfg.layer_pattern[s], B, cache_len, cross)
                for s in range(period)}
        cache["blocks"] = jax.vmap(one)(jnp.arange(G))
    if n_tail:
        cache["tail"] = {
            f"block_{j}": _block_cache_zeros(
                cfg, cfg.layer_kind(G * period + j), B, cache_len, cross)
            for j in range(n_tail)}
    return cache
