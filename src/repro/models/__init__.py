from repro.models.transformer import (  # noqa: F401
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    lm_logits,
)
