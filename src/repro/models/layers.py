"""Model substrate layers: norms, rope, MLP variants, GQA attention, MoE,
Mamba-1 mixer, RG-LRU mixer — pure-functional (params are pytrees of arrays).

Conventions:
  * params stored in ``cfg.param_dtype``; compute in ``cfg.dtype``
    (norm/softmax/scan accumulation in float32).
  * activations layout (B, S, D); attention heads (B, S, H, head_dim).
  * ``mesh`` is threaded explicitly; ``None`` means single-device (tests).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba.ops import selective_scan, selective_step
from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.rglru.ops import linear_scan

Params = Dict[str, Any]

RGLRU_C = 8.0  # Griffin's recurrent-gate temperature


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pd(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def cast(cfg: ModelConfig, w):
    return w.astype(_dt(cfg))


def _normal(key, shape, std, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------- norms

def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), _pd(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _pd(cfg))
    return p


def apply_norm(cfg: ModelConfig, p: Params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        out = xf * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_head_norm(x, scale, eps: float = 1e-6):
    """qk-norm: rmsnorm over head_dim with a learned (head_dim,) scale."""
    xf = x.astype(jnp.float32)
    xf = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- positions

def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S)."""
    D = x.shape[-1]
    half = D // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv    # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d: int):
    """Absolute sinusoidal embeddings: positions (...,) -> (..., d)."""
    half = d // 2
    inv = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                  * (math.log(10_000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------- MLP

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    D = cfg.d_model
    std_in = 0.02
    std_out = 0.02 / math.sqrt(2 * cfg.num_layers)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": _normal(k1, (D, d_ff), std_in, _pd(cfg)),
         "wo": _normal(k2, (d_ff, D), std_out, _pd(cfg))}
    if cfg.mlp in ("swiglu", "geglu"):
        p["wg"] = _normal(k3, (D, d_ff), std_in, _pd(cfg))
    return p


def _mlp_act(cfg: ModelConfig, hi, hg):
    if cfg.mlp == "swiglu":
        return jax.nn.silu(hg) * hi
    if cfg.mlp == "geglu":
        return jax.nn.gelu(hg, approximate=True) * hi
    if cfg.mlp == "relu2":
        return jnp.square(jax.nn.relu(hi))
    if cfg.mlp == "gelu":
        return jax.nn.gelu(hi, approximate=True)
    raise ValueError(cfg.mlp)


def apply_mlp(cfg: ModelConfig, p: Params, x):
    hi = x @ cast(cfg, p["wi"])
    hg = x @ cast(cfg, p["wg"]) if "wg" in p else None
    return _mlp_act(cfg, hi, hg) @ cast(cfg, p["wo"])


# ---------------------------------------------------------------- attention

def init_attn(cfg: ModelConfig, key, cross: bool = False) -> Params:
    D, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    std = 0.02
    std_out = 0.02 / math.sqrt(2 * cfg.num_layers)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"wq": _normal(k1, (D, qd), std, _pd(cfg)),
         "wk": _normal(k2, (D, kvd), std, _pd(cfg)),
         "wv": _normal(k3, (D, kvd), std, _pd(cfg)),
         "wo": _normal(k4, (qd, D), std_out, _pd(cfg))}
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((cfg.head_dim,), _pd(cfg))
        p["k_norm"] = jnp.ones((cfg.head_dim,), _pd(cfg))
    return p


def _theta_for(cfg: ModelConfig, kind: str) -> float:
    if kind == "global" and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _qkv(cfg: ModelConfig, p: Params, x, positions, kind: str):
    B, S, _ = x.shape
    q = (x @ cast(cfg, p["wq"])).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (x @ cast(cfg, p["wk"])).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ cast(cfg, p["wv"])).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    theta = _theta_for(cfg, kind)
    if theta:  # theta == 0 -> absolute sinusoidal positions (added upstream)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def apply_attn(cfg: ModelConfig, p: Params, x, *, kind: str, positions,
               seg_ids=None, mem=None, mesh=None):
    """Self- or cross-attention.  kind: global | local | enc | cross."""
    B, S, _ = x.shape
    if kind == "cross":
        q = (x @ cast(cfg, p["wq"])).reshape(B, S, cfg.num_heads, cfg.head_dim)
        Sm = mem.shape[1]
        k = (mem @ cast(cfg, p["wk"])).reshape(B, Sm, cfg.num_kv_heads, cfg.head_dim)
        v = (mem @ cast(cfg, p["wv"])).reshape(B, Sm, cfg.num_kv_heads, cfg.head_dim)
        o = flash_attention(q, k, v, causal=False, window=0,
                            softcap=cfg.attn_softcap,
                            scale=cfg.attn_scale or None)
    else:
        q, k, v = _qkv(cfg, p, x, positions, kind)
        causal = kind != "enc"
        window = cfg.sliding_window if kind == "local" else 0
        o = flash_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_softcap,
                            scale=cfg.attn_scale or None,
                            seg_q=seg_ids, seg_kv=seg_ids)
    return o.reshape(B, S, cfg.q_dim) @ cast(cfg, p["wo"])


# -- decode (single new token against a cache) ------------------------------

def _decode_attention(cfg: ModelConfig, q, kc, vc, mask):
    """q: (B,1,H,D); kc/vc: (B,Sc,KH,D); mask: broadcastable to (B,1,Sc)."""
    B, _, H, Dh = q.shape
    KH = kc.shape[2]
    G = H // KH
    scale = cfg.attn_scale or Dh ** -0.5
    qf = q.astype(jnp.float32).reshape(B, KH, G, Dh) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kc.astype(jnp.float32))
    if cfg.attn_softcap:
        s = jnp.tanh(s / cfg.attn_softcap) * cfg.attn_softcap
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    pden = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bhgs,bshd->bhgd", e / jnp.maximum(pden, 1e-30),
                   vc.astype(jnp.float32))
    return o.reshape(B, 1, H * Dh).astype(q.dtype)


def attn_decode(cfg: ModelConfig, p: Params, x, cache: Params, positions,
                *, kind: str, mesh=None) -> Tuple[jax.Array, Params]:
    """x: (B,1,D); positions: (B,) — PER-ROW cache positions: each batch
    row writes its k/v at its own offset and attends under its own causal
    mask, so a continuous-batching server can admit requests into a live
    decode wave at unequal sequence offsets.  Sliding-window local layers
    remain batch-synchronized (positions[0]): their ring cache carries one
    shared ``pos`` vector with no batch dimension.  When all rows share a
    position the per-row path is numerically identical to the old
    synchronized one.  Returns (out (B,1,D), updated cache)."""
    B = x.shape[0]
    q, k, v = _qkv(cfg, p, x, positions[:, None], kind)

    if kind == "cross":
        raise ValueError("use attn_decode_cross")
    if kind == "local" and cfg.sliding_window:
        pos = positions[0]               # ring cache: batch-synchronized
        W = cache["k"].shape[1]
        slot = pos % W
        kc = lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        vc = lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        pc = lax.dynamic_update_slice_in_dim(
            cache["pos"], pos[None].astype(jnp.int32), slot, axis=0)
        mask = (pc <= pos) & (pc > pos - W) & (pc >= 0)
        mask = jnp.broadcast_to(mask[None, :], (B, W))
        out = _decode_attention(cfg, q, kc, vc, mask)
        new_cache = {"k": kc, "v": vc, "pos": pc}
    else:
        write = jax.vmap(lambda c, u, pp:
                         lax.dynamic_update_slice_in_dim(c, u, pp, axis=0))
        kc = write(cache["k"], k, positions)
        vc = write(cache["v"], v, positions)
        S = kc.shape[1]
        mask = jnp.arange(S)[None, :] <= positions[:, None]
        out = _decode_attention(cfg, q, kc, vc, mask)
        new_cache = {"k": kc, "v": vc}
    return out @ cast(cfg, p["wo"]), new_cache


def attn_decode_cross(cfg: ModelConfig, p: Params, x, cache: Params):
    """Cross-attention decode: kv precomputed at prefill (static)."""
    B = x.shape[0]
    q = (x @ cast(cfg, p["wq"])).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    Sm = cache["xk"].shape[1]
    mask = jnp.ones((B, Sm), dtype=bool)
    out = _decode_attention(cfg, q, cache["xk"], cache["xv"], mask)
    return out @ cast(cfg, p["wo"])


# ---------------------------------------------------------------- MoE

def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def init_moe(cfg: ModelConfig, key) -> Params:
    D, F, E = cfg.d_model, cfg.expert_ff, cfg.num_experts
    std = 0.02
    std_out = 0.02 / math.sqrt(2 * cfg.num_layers)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"router": _normal(k1, (D, E), std, jnp.float32),
         "wi": _normal(k2, (E, D, F), std, _pd(cfg)),
         "wo": _normal(k3, (E, F, D), std_out, _pd(cfg))}
    if cfg.mlp in ("swiglu", "geglu"):
        p["wg"] = _normal(k4, (E, D, F), std, _pd(cfg))
    return p


def _moe_local(cfg: ModelConfig, p: Params, xt, e_base, E_local: int,
               capacity_factor: float):
    """Sort+scatter dispatch for the local expert slice [e_base, e_base+E_local).

    xt: (T, D) local tokens.  ``p["wi"/"wg"/"wo"]`` hold the E_local-sized
    slice already (shard_map in_specs deliver the local shard); ``e_base``
    may be traced (lax.axis_index).  Returns (y (T, D) partial sum over
    local experts, aux load-balance loss over the full expert population).
    """
    T, D = xt.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    wts, idx = lax.top_k(probs, k)                               # (T, k)
    wts = wts / jnp.maximum(wts.sum(-1, keepdims=True), 1e-9)

    eids = idx.reshape(-1)                                       # (T*k,)
    tids = jnp.repeat(jnp.arange(T), k)
    wv = wts.reshape(-1)

    # aux loss (switch-style), computed over full expert population
    f = jnp.zeros((E,), jnp.float32).at[eids].add(1.0) / (T * k)
    pbar = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pbar)

    el = eids - e_base
    inrange = (el >= 0) & (el < E_local)
    sort_key = jnp.where(inrange, el, E_local)
    order = jnp.argsort(sort_key, stable=True)
    el_s = sort_key[order]
    tid_s = tids[order]
    w_s = wv[order]

    counts = jnp.zeros((E_local + 1,), jnp.int32).at[sort_key].add(1)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k) - offs[el_s]

    cap_block = 128 if T * k // max(E_local, 1) >= 128 else 8
    C = max(cap_block,
            _round_up(int(math.ceil(T * k / E * capacity_factor)), cap_block))
    keep = (pos_in_e < C) & (el_s < E_local)
    slot = jnp.where(keep, el_s * C + pos_in_e, E_local * C)

    xe = jnp.zeros((E_local * C + 1, D), xt.dtype)
    xe = xe.at[slot].set(xt[tid_s] * keep[:, None].astype(xt.dtype))
    xe = xe[:-1].reshape(E_local, C, D)
    group_sizes = jnp.minimum(counts[:E_local], C)

    hi = gmm(xe, cast(cfg, p["wi"]), group_sizes)
    hg = gmm(xe, cast(cfg, p["wg"]), group_sizes) if "wg" in p else None
    h = _mlp_act(cfg, hi, hg)
    ye = gmm(h, cast(cfg, p["wo"]), group_sizes)

    flat = jnp.concatenate([ye.reshape(E_local * C, D),
                            jnp.zeros((1, D), ye.dtype)])
    back = flat[slot] * (keep & inrange[order])[:, None].astype(ye.dtype)
    y = jnp.zeros((T, D), xt.dtype).at[tid_s].add(
        back * w_s[:, None].astype(ye.dtype))
    return y, aux


def apply_moe(cfg: ModelConfig, p: Params, x, *, mesh=None,
              capacity_factor: float = 1.25):
    """Returns (y, aux_loss).  EP via shard_map when mesh has a 'model' axis
    and the profile is tp_ep; otherwise dispatch is local per data shard
    (expert weights TP-sharded by GSPMD for the grok-style profile)."""
    B, S, D = x.shape
    E = cfg.num_experts

    if mesh is None:
        y, aux = _moe_local(cfg, p, x.reshape(-1, D), 0, E, capacity_factor)
        return y.reshape(B, S, D), aux

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if cfg.sharding_profile == "tp_ep":
        mdl = mesh.shape["model"]
        E_local = E // mdl

        def f(xb, pl):
            T = xb.shape[0] * xb.shape[1]
            j = lax.axis_index("model")
            y, aux = _moe_local(cfg, pl, xb.reshape(T, D),
                                j * E_local, E_local, capacity_factor)
            y = lax.psum(y, "model")
            aux = lax.pmean(aux, data_axes)
            return y.reshape(xb.shape), aux

        pspecs = {"router": P(None, None), "wi": P("model", None, None),
                  "wo": P("model", None, None)}
        if "wg" in p:
            pspecs["wg"] = P("model", None, None)
        return jax.shard_map(
            f, mesh=mesh,
            in_specs=(P(data_axes, None, None), pspecs),
            out_specs=(P(data_axes, None, None), P()),
            check_vma=False)(x, p)

    # tp profile (few big experts): dispatch local per data shard; expert
    # matmuls sharded over "model" by GSPMD (auto axes inside shard_map).
    # NOTE: three attempts to make the boundary gather move bf16 instead of
    # f32 (tree-level cast, optimization_barrier'd cast, manual
    # all_gather-inside) all trip an XLA SPMD-partitioner CHECK failure
    # ("invalid binary instruction opcode copy") at 256 partitions — the
    # f32 gather stands on this backend; EXPERIMENTS.md §Perf grok.
    def f(xb, pl):
        T = xb.shape[0] * xb.shape[1]
        y, aux = _moe_local(cfg, pl, xb.reshape(T, D), 0, E, capacity_factor)
        aux = lax.pmean(aux, data_axes)
        return y.reshape(xb.shape), aux

    pspecs = jax.tree.map(lambda _: P(), p)
    return jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(data_axes, None, None), pspecs),
        out_specs=(P(data_axes, None, None), P()),
        axis_names=set(data_axes),
        check_vma=False)(x, p)


# ---------------------------------------------------------------- conv1d

def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x: (B,S,C); w: (cw, C); b: (C,).

    Implemented as cw shifted elementwise multiply-accumulates instead of
    ``lax.conv_general_dilated``: XLA lowers the depthwise conv *backward*
    into a full CxC cross-channel correlation (measured 9e15 FLOPs for
    falcon-mamba's 8192 channels — see EXPERIMENTS.md §Perf falcon/step 1);
    the shift-mul form is pure VPU work with an equally cheap transpose.
    """
    cw, C = w.shape
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    acc = xf * wf[cw - 1]
    for j in range(1, cw):
        shifted = jnp.pad(xf[:, :-j, :], ((0, 0), (j, 0), (0, 0)))
        acc = acc + shifted * wf[cw - 1 - j]
    return (acc + b.astype(jnp.float32)).astype(x.dtype)


def conv1d_step(x1, buf, w, b):
    """Single-token conv step.  x1: (B,C); buf: (B,cw-1,C) past inputs.
    Returns (y (B,C), new buf)."""
    cw, C = w.shape
    wf = w.astype(jnp.float32)
    full = jnp.concatenate([buf, x1[:, None, :]], axis=1)  # (B, cw, C)
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32), wf)
    y = (y + b.astype(jnp.float32)).astype(x1.dtype)
    return y, full[:, 1:]


# ---------------------------------------------------------------- RG-LRU

def init_rglru(cfg: ModelConfig, key) -> Params:
    D, W = cfg.d_model, cfg.lru_width_
    std = 0.02
    std_out = 0.02 / math.sqrt(2 * cfg.num_layers)
    ks = jax.random.split(key, 6)
    # Lambda init so that a^c is in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    root = u ** (1.0 / RGLRU_C)
    a_param = jnp.log(root / (1.0 - root))          # logit
    return {
        "wx": _normal(ks[1], (D, W), std, _pd(cfg)),
        "wy": _normal(ks[2], (D, W), std, _pd(cfg)),
        "conv_w": _normal(ks[3], (cfg.ssm_conv, W), std, _pd(cfg)),
        "conv_b": jnp.zeros((W,), _pd(cfg)),
        "wa": _normal(ks[4], (W, W), std, _pd(cfg)),
        "wi_g": _normal(ks[5], (W, W), std, _pd(cfg)),
        "a_param": a_param.astype(jnp.float32),
        "wo": _normal(jax.random.fold_in(key, 7), (W, D), std_out, _pd(cfg)),
    }


def _rglru_gates(p: Params, xb):
    """Returns (a, x_eff) for h_t = a_t h_{t-1} + x_eff_t (float32)."""
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["wi_g"].astype(jnp.float32))
    log_a = RGLRU_C * r * jax.nn.log_sigmoid(p["a_param"])[None]
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i * xf


def apply_rglru(cfg: ModelConfig, p: Params, x, *, mesh=None,
                h0=None, conv_buf=None, return_state: bool = False):
    """Griffin recurrent mixer.  x: (B,S,D)."""
    B, S, _ = x.shape
    W = cfg.lru_width_
    xb = x @ cast(cfg, p["wx"])
    yb = jax.nn.gelu(x @ cast(cfg, p["wy"]), approximate=True)
    if conv_buf is None:
        xb = causal_conv1d(xb, p["conv_w"], p["conv_b"])
        new_buf = None
    else:  # stateful prefill continuation (unused in training)
        raise NotImplementedError
    a, x_eff = _rglru_gates(p, xb)
    h0 = h0 if h0 is not None else jnp.zeros((B, W), jnp.float32)
    h, h_last = linear_scan(x_eff, a, h0)
    out = (h.astype(_dt(cfg)) * yb) @ cast(cfg, p["wo"])
    if return_state:
        # conv state: last (cw-1) pre-conv inputs
        pre = x @ cast(cfg, p["wx"])
        buf = pre[:, -(cfg.ssm_conv - 1):, :]
        return out, {"h": h_last, "conv": buf}
    return out


def rglru_decode(cfg: ModelConfig, p: Params, x, cache: Params):
    """x: (B,1,D).  cache: {"h": (B,W) f32, "conv": (B,cw-1,W)}."""
    x1 = x[:, 0, :]
    xb1 = x1 @ cast(cfg, p["wx"])
    yb1 = jax.nn.gelu(x1 @ cast(cfg, p["wy"]), approximate=True)
    xc, new_buf = conv1d_step(xb1, cache["conv"], p["conv_w"], p["conv_b"])
    a, x_eff = _rglru_gates(p, xc[:, None, :])
    h = a[:, 0] * cache["h"] + x_eff[:, 0]
    out = (h.astype(_dt(cfg)) * yb1) @ cast(cfg, p["wo"])
    return out[:, None, :], {"h": h, "conv": new_buf}


# ---------------------------------------------------------------- Mamba

def init_mamba(cfg: ModelConfig, key) -> Params:
    D, di, n, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    std = 0.02
    std_out = 0.02 / math.sqrt(2 * cfg.num_layers)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    dt = jnp.exp(jax.random.uniform(ks[0], (di,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))         # inverse softplus
    return {
        "in_proj": _normal(ks[1], (D, 2 * di), std, _pd(cfg)),
        "conv_w": _normal(ks[2], (cfg.ssm_conv, di), std, _pd(cfg)),
        "conv_b": jnp.zeros((di,), _pd(cfg)),
        "x_proj": _normal(ks[3], (di, dr + 2 * n), std, _pd(cfg)),
        "dt_proj": _normal(ks[4], (dr, di), dr ** -0.5, _pd(cfg)),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _normal(ks[5], (di, D), std_out, _pd(cfg)),
    }


def _mamba_bcdt(cfg: ModelConfig, p: Params, xin):
    n, dr = cfg.ssm_state, cfg.dt_rank_
    xdbc = xin @ cast(cfg, p["x_proj"])
    dt_r, Bm, Cc = jnp.split(xdbc, [dr, dr + n], axis=-1)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"][None])
    return dt, Bm, Cc


def apply_mamba(cfg: ModelConfig, p: Params, x, *, mesh=None,
                return_state: bool = False):
    B, S, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ cast(cfg, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = causal_conv1d(xin, p["conv_w"], p["conv_b"])
    pre_conv = jnp.split(x @ cast(cfg, p["in_proj"]), 2, axis=-1)[0] \
        if return_state else None
    xin = jax.nn.silu(xin)
    dt, Bm, Cc = _mamba_bcdt(cfg, p, xin)
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((B, di, n), jnp.float32)
    y, h_last = selective_scan(xin, dt, A, Bm, Cc, p["D"], h0)
    y = y * jax.nn.silu(z)
    out = y @ cast(cfg, p["out_proj"])
    if return_state:
        buf = pre_conv[:, -(cfg.ssm_conv - 1):, :]
        return out, {"h": h_last, "conv": buf}
    return out


def mamba_decode(cfg: ModelConfig, p: Params, x, cache: Params):
    """x: (B,1,D).  cache: {"h": (B,di,n) f32, "conv": (B,cw-1,di)}."""
    x1 = x[:, 0, :]
    xz = x1 @ cast(cfg, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_buf = conv1d_step(xin, cache["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dt, Bm, Cc = _mamba_bcdt(cfg, p, xc)
    A = -jnp.exp(p["A_log"])
    y, h = selective_step(xc, dt, A, Bm, Cc, p["D"], cache["h"])
    y = y * jax.nn.silu(z)
    out = (y @ cast(cfg, p["out_proj"]))[:, None, :]
    return out, {"h": h, "conv": new_buf}
