from repro.serve.engine import (  # noqa: F401
    BatchedServer,
    Request,
    build_prefill_step,
    build_serve_step,
    cache_specs,
)
