"""Serving substrate: prefill/decode step builders, cache specs, and a
host-side continuous-batching scheduler (per-step admit/evict over a live
decode wave) used by the serving example and the ensemble serving plugins
(repro.serving builds whole PST applications on top of it).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward, init_cache, lm_logits


def build_prefill_step(cfg: ModelConfig, mesh=None,
                       cache_len: Optional[int] = None):
    def prefill_step(params, batch):
        out = forward(cfg, params, batch["tokens"],
                      vision_embeds=batch.get("vision_embeds"),
                      enc_frames=batch.get("enc_frames"),
                      mesh=mesh, cache_len=cache_len, batch_kind="serve")
        logits = lm_logits(cfg, params, out["h"][:, -1:], mesh=mesh)
        if cache_len is None:
            return {"logits": logits}
        return {"logits": logits, "cache": out["cache"]}
    return prefill_step


def build_serve_step(cfg: ModelConfig, mesh=None):
    """decode: one new token for the whole batch against the cache."""
    def serve_step(params, cache, tokens, positions):
        return decode_step(cfg, params, cache, tokens, positions, mesh=mesh)
    return serve_step


def cache_specs(cfg: ModelConfig, B: int, cache_len: int):
    """ShapeDtypeStructs of the decode cache (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, B, cache_len))


# ---------------------------------------------------------------- requests

@dataclass
class Request:
    rid: int
    prompt: Any                      # token array (S,)
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    done_at: float = 0.0
    sla: str = "throughput"          # serving SLA class (repro.serving.sla)


def _merge_rows(old, new, mask, *, axis):
    """Select ``new``'s batch rows where ``mask`` is set, ``old``'s
    elsewhere, for every leaf of a cache subtree (``axis`` is the batch
    axis: 1 for the scanned ``blocks`` subtree, 0 for ``tail``)."""
    def sel(o, n):
        shape = [1] * o.ndim
        shape[axis] = o.shape[axis]
        return jnp.where(mask.reshape(shape), n, o)
    return jax.tree_util.tree_map(sel, old, new)


class BatchedServer:
    """Host-side continuous-batching server over fixed decode slots.

    ``run()`` keeps ONE decode wave alive for the whole queue: each step it
    (1) admits queued requests into free slots — group prefill, then merge
    only the joiner rows into the live cache — (2) decodes one token for
    every occupied slot at its own per-row cache position, and (3) evicts
    each request the step it reaches its ``max_new_tokens``, freeing the
    slot for the next admission.  Per-row positions come from
    ``models.layers.attn_decode``; sliding-window local layers keep a
    batch-synchronized ring cache (one position vector, no batch dim), so
    configs containing them fall back to the legacy synchronized-wave loop
    (evict-at-own-length still holds; no mid-wave admission).

    ``clock`` stamps Request.submitted_at/done_at: ``time.perf_counter``
    in real runs, a virtual-time callable in DES runs (repro.serving).
    ``prefill_fn``/``step_fn`` let tests inject deterministic stand-ins
    for the jitted model functions.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch: int, prompt_len: int,
                 max_len: int, mesh=None, clock=time.perf_counter,
                 prefill_fn=None, step_fn=None):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.B, self.S0, self.Smax = batch, prompt_len, max_len
        self.clock = clock
        # sliding-window ring caches are batch-synchronized -> wave mode
        self.continuous = not (cfg.sliding_window and any(
            cfg.layer_kind(i) == "local" for i in range(cfg.num_layers)))
        if prefill_fn is not None or step_fn is not None:
            self.prefill, self.step = prefill_fn, step_fn
        elif mesh is not None:
            # pin the distributed layout: params/cache stay sharded across
            # decode steps (cache donated), logits replicated for sampling
            from repro.dist.sharding import cache_shardings, state_shardings
            p_sh = state_shardings(cfg, mesh, params)
            c_sh = cache_shardings(cfg, mesh,
                                   cache_specs(cfg, batch, max_len))
            self.prefill = jax.jit(
                build_prefill_step(cfg, mesh, cache_len=max_len),
                in_shardings=(p_sh, None),
                out_shardings={"logits": None, "cache": c_sh})
            self.step = jax.jit(
                build_serve_step(cfg, mesh),
                in_shardings=(p_sh, c_sh, None, None),
                out_shardings=(None, c_sh), donate_argnums=(1,))
        else:
            self.prefill = jax.jit(
                build_prefill_step(cfg, mesh, cache_len=max_len))
            self.step = jax.jit(build_serve_step(cfg, mesh))
        self.queue: collections.deque = collections.deque()
        self.stats = {"served": 0, "decode_steps": 0, "prefills": 0,
                      "slot_steps": 0}

    def submit(self, reqs: List[Request]):
        for r in reqs:
            if self.S0 + r.max_new_tokens > self.Smax:
                raise ValueError(
                    f"request {r.rid}: prompt_len {self.S0} + "
                    f"max_new_tokens {r.max_new_tokens} exceeds cache "
                    f"length {self.Smax}")
            r.submitted_at = self.clock()
            self.queue.append(r)

    def run(self) -> List[Request]:
        return self._run_continuous() if self.continuous \
            else self._run_waves()

    # -------------------------------------------------- continuous batching
    def _admit(self, slots, cache, positions, last):
        """Fill free slots from the queue: one group prefill for all
        joiners, merged row-wise into the live cache."""
        joiners = []
        for i in range(self.B):
            if slots[i] is None and self.queue:
                slots[i] = self.queue.popleft()
                joiners.append(i)
        if not joiners:
            return cache
        joinset = set(joiners)
        tokens = jnp.stack(
            [jnp.asarray(slots[i].prompt[:self.S0])
             if i in joinset else jnp.zeros((self.S0,), jnp.int32)
             for i in range(self.B)])
        out = self.prefill(self.params, {"tokens": tokens})
        self.stats["prefills"] += 1
        fresh = out["cache"]
        if cache is None:
            cache = fresh
        else:
            mask = jnp.asarray([i in joinset for i in range(self.B)])
            merged = {}
            if "blocks" in cache:      # scanned: leaves (G, B, ...)
                merged["blocks"] = _merge_rows(
                    cache["blocks"], fresh["blocks"], mask, axis=1)
            if "tail" in cache:        # unscanned: leaves (B, ...)
                merged["tail"] = _merge_rows(
                    cache["tail"], fresh["tail"], mask, axis=0)
            cache = merged
        first = jax.device_get(jnp.argmax(out["logits"][:, 0], axis=-1))
        for i in joiners:
            last[i] = int(first[i])
            positions[i] = self.S0
        return cache

    def _run_continuous(self) -> List[Request]:
        done: List[Request] = []
        slots: List[Optional[Request]] = [None] * self.B
        positions = [0] * self.B     # next cache write offset per slot
        last = [0] * self.B          # last decoded token per slot (host)
        cache = None
        while self.queue or any(s is not None for s in slots):
            cache = self._admit(slots, cache, positions, last)
            logits, cache = self.step(
                self.params, cache, jnp.asarray(last, jnp.int32)[:, None],
                jnp.asarray(positions, jnp.int32))
            self.stats["decode_steps"] += 1
            nxt = jax.device_get(jnp.argmax(logits[:, 0], axis=-1))
            for i, r in enumerate(slots):
                if r is None:
                    continue
                r.out_tokens.append(int(nxt[i]))
                last[i] = int(nxt[i])
                positions[i] += 1
                self.stats["slot_steps"] += 1
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.done_at = self.clock()
                    done.append(r)
                    self.stats["served"] += 1
                    slots[i] = None      # evict: slot free next admission
        return done

    # -------------------------------------------------- legacy wave loop
    def _run_waves(self) -> List[Request]:
        done: List[Request] = []
        while self.queue:
            wave = [self.queue.popleft()
                    for _ in range(min(self.B, len(self.queue)))]
            tokens = jnp.stack(
                [jnp.asarray(r.prompt[:self.S0]) for r in wave] +
                [jnp.zeros((self.S0,), jnp.int32)] * (self.B - len(wave)))
            out = self.prefill(self.params, {"tokens": tokens})
            self.stats["prefills"] += 1
            cache = out["cache"]
            last = jnp.argmax(out["logits"][:, 0], axis=-1)
            nsteps = max(r.max_new_tokens for r in wave)
            for t in range(nsteps):
                pos = jnp.full((self.B,), self.S0 + t, jnp.int32)
                logits, cache = self.step(self.params, cache,
                                          last[:, None], pos)
                last = jnp.argmax(logits[:, 0], axis=-1)
                self.stats["decode_steps"] += 1
                host = jax.device_get(last)
                for i, r in enumerate(wave):
                    if t < r.max_new_tokens:
                        r.out_tokens.append(int(host[i]))
                        self.stats["slot_steps"] += 1
            for r in wave:
                r.done_at = self.clock()
            done.extend(wave)
            self.stats["served"] += len(wave)
        return done
