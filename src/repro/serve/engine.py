"""Serving substrate: prefill/decode step builders, cache specs, and a
host-side batched-request scheduler (continuous-batching-lite) used by the
serving example and the ensemble serving plugins.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, forward, init_cache, lm_logits


def build_prefill_step(cfg: ModelConfig, mesh=None,
                       cache_len: Optional[int] = None):
    def prefill_step(params, batch):
        out = forward(cfg, params, batch["tokens"],
                      vision_embeds=batch.get("vision_embeds"),
                      enc_frames=batch.get("enc_frames"),
                      mesh=mesh, cache_len=cache_len, batch_kind="serve")
        logits = lm_logits(cfg, params, out["h"][:, -1:], mesh=mesh)
        if cache_len is None:
            return {"logits": logits}
        return {"logits": logits, "cache": out["cache"]}
    return prefill_step


def build_serve_step(cfg: ModelConfig, mesh=None):
    """decode: one new token for the whole batch against the cache."""
    def serve_step(params, cache, tokens, positions):
        return decode_step(cfg, params, cache, tokens, positions, mesh=mesh)
    return serve_step


def cache_specs(cfg: ModelConfig, B: int, cache_len: int):
    """ShapeDtypeStructs of the decode cache (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, B, cache_len))


# ---------------------------------------------------------------- requests

@dataclass
class Request:
    rid: int
    prompt: Any                      # token array (S,)
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    done_at: float = 0.0


class BatchedServer:
    """Host-side batched serving loop over fixed-size decode slots.

    Greedy decoding over synchronized batch positions (slot-parallel).  This
    is the serving driver used by examples/serve_batched.py; the ensemble
    layer schedules *many* of these as tasks.
    """

    def __init__(self, cfg: ModelConfig, params, *, batch: int, prompt_len: int,
                 max_len: int, mesh=None):
        self.cfg, self.params, self.mesh = cfg, params, mesh
        self.B, self.S0, self.Smax = batch, prompt_len, max_len
        if mesh is not None:
            # pin the distributed layout: params/cache stay sharded across
            # decode steps (cache donated), logits replicated for sampling
            from repro.dist.sharding import cache_shardings, state_shardings
            p_sh = state_shardings(cfg, mesh, params)
            c_sh = cache_shardings(cfg, mesh,
                                   cache_specs(cfg, batch, max_len))
            self.prefill = jax.jit(
                build_prefill_step(cfg, mesh, cache_len=max_len),
                in_shardings=(p_sh, None),
                out_shardings={"logits": None, "cache": c_sh})
            self.step = jax.jit(
                build_serve_step(cfg, mesh),
                in_shardings=(p_sh, c_sh, None, None),
                out_shardings=(None, c_sh), donate_argnums=(1,))
        else:
            self.prefill = jax.jit(
                build_prefill_step(cfg, mesh, cache_len=max_len))
            self.step = jax.jit(build_serve_step(cfg, mesh))
        self.queue: collections.deque = collections.deque()
        self.stats = {"served": 0, "decode_steps": 0, "prefills": 0}

    def submit(self, reqs: List[Request]):
        for r in reqs:
            r.submitted_at = time.perf_counter()
            self.queue.append(r)

    def run(self) -> List[Request]:
        done: List[Request] = []
        while self.queue:
            wave = [self.queue.popleft()
                    for _ in range(min(self.B, len(self.queue)))]
            tokens = jnp.stack(
                [jnp.asarray(r.prompt[:self.S0]) for r in wave] +
                [jnp.zeros((self.S0,), jnp.int32)] * (self.B - len(wave)))
            out = self.prefill(self.params, {"tokens": tokens})
            self.stats["prefills"] += 1
            cache = out["cache"]
            last = jnp.argmax(out["logits"][:, 0], axis=-1)
            nsteps = max(r.max_new_tokens for r in wave)
            for t in range(nsteps):
                pos = jnp.full((self.B,), self.S0 + t, jnp.int32)
                logits, cache = self.step(self.params, cache,
                                          last[:, None], pos)
                last = jnp.argmax(logits[:, 0], axis=-1)
                self.stats["decode_steps"] += 1
                host = jax.device_get(last)
                for i, r in enumerate(wave):
                    if t < r.max_new_tokens:
                        r.out_tokens.append(int(host[i]))
            for r in wave:
                r.done_at = time.perf_counter()
            done.extend(wave)
            self.stats["served"] += len(wave)
        return done
