"""The paper's validation workload (§4.3): mkfile + ccount kernels."""
from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core.kernel_plugin import register_kernel


@register_kernel("misc.mkfile",
                 description="create a buffer/file of random characters")
def mkfile(args, ctx):
    n = int(args.get("bytes", 1 << 20))
    seed = int(args.get("seed", 0))
    rng = np.random.default_rng(seed)
    data = rng.integers(97, 123, n, dtype=np.uint8)  # a..z
    path = args.get("path")
    if args.get("to_disk", False):
        fd, path = tempfile.mkstemp(prefix="enmd_mkfile_")
        with os.fdopen(fd, "wb") as f:
            f.write(data.tobytes())
        return {"path": path, "bytes": n}
    return {"data": data, "bytes": n}


@register_kernel("misc.ccount",
                 description="character count over a mkfile output")
def ccount(args, ctx):
    src = args.get("input")
    if src is None:
        deps = ctx.get("dep_results") or {}
        src = next(iter(deps.values()), None)
    if src is None:
        staged = ctx.get("staged_inputs") or []
        src = staged[0] if staged else None
    if isinstance(src, dict) and "data" in src:
        data = src["data"]
    elif isinstance(src, dict) and "path" in src:
        data = np.fromfile(src["path"], dtype=np.uint8)
    elif isinstance(src, str):
        data = np.fromfile(src, dtype=np.uint8)
    else:
        raise ValueError("ccount: no input")
    counts = np.bincount(data, minlength=256)
    return {"total": int(counts.sum()),
            "distinct": int((counts > 0).sum()),
            "top": int(np.argmax(counts))}
