"""Kernel plugin registry: importing this package registers all plugins."""
from repro.plugins import lm           # noqa: F401
from repro.plugins import re_exchange  # noqa: F401
from repro.plugins import serve        # noqa: F401
from repro.plugins import synthetic    # noqa: F401
from repro.plugins import toy          # noqa: F401
