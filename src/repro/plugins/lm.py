"""LM kernel plugins: the real science workloads of this reproduction.

The paper's MD engines (Amber/Gromacs) become JAX model steps on the
assigned architectures.  Reduced configs run on CPU; full configs are what
the dry-run lowers.  Step functions and live train states are cached in
module stores keyed by (ensemble, member) — the in-memory analogue of the
paper's staged files.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.core.kernel_plugin import register_kernel
from repro.data import SyntheticLM
from repro.train import TrainHyper, build_eval_step, build_train_step, \
    make_train_state

# live member states (the "staging area"); keyed by (ensemble_id, member_id)
STATE_STORE: Dict[Tuple[str, int], Any] = {}
_STEP_CACHE: Dict[Tuple, Any] = {}


def resolve_cfg(name: str):
    if name.startswith("reduced:"):
        return reduced(get_config(name.split(":", 1)[1]))
    return get_config(name)


def _steps(cfg, kind: str, hyper: TrainHyper = TrainHyper()):
    key = (cfg.name, kind, hyper)
    if key not in _STEP_CACHE:
        if kind == "train":
            _STEP_CACHE[key] = jax.jit(build_train_step(cfg, hyper=hyper))
        else:
            _STEP_CACHE[key] = jax.jit(build_eval_step(cfg))
    return _STEP_CACHE[key]


def _shape(args, cfg) -> ShapeSpec:
    return ShapeSpec("task", "train",
                     int(args.get("seq", 64)), int(args.get("batch", 4)))


@register_kernel("lm.train", description="train an LM for n steps")
def lm_train(args, ctx):
    cfg = resolve_cfg(args.get("arch", "reduced:gemma2-2b"))
    hyper = TrainHyper(base_lr=float(args.get("lr", 3e-4)), warmup=2,
                       total_steps=int(args.get("total_steps", 1000)),
                       schedule=args.get("schedule", "cosine"))
    sid = (args.get("ensemble", "default"), int(args.get("member", 0)))
    state = STATE_STORE.get(sid)
    if state is None:
        state = make_train_state(
            cfg, jax.random.PRNGKey(int(args.get("seed", 0)) + sid[1]))
    step = _steps(cfg, "train", hyper)
    data = SyntheticLM(cfg, _shape(args, cfg),
                       seed=int(args.get("data_seed", 0)))
    start = int(jax.device_get(state["step"]))
    m = {}
    for i in range(int(args.get("steps", 2))):
        state, m = step(state, data.batch_at(start + i))
    STATE_STORE[sid] = state
    return {"loss": float(m.get("loss", np.nan)),
            "step": int(jax.device_get(state["step"])),
            "member": sid[1]}


@register_kernel("lm.eval", description="eval an LM member")
def lm_eval(args, ctx):
    cfg = resolve_cfg(args.get("arch", "reduced:gemma2-2b"))
    sid = (args.get("ensemble", "default"), int(args.get("member", 0)))
    state = STATE_STORE.get(sid)
    if state is None:
        raise RuntimeError(f"no live state for member {sid}")
    step = _steps(cfg, "eval")
    data = SyntheticLM(cfg, _shape(args, cfg),
                       seed=int(args.get("data_seed", 1)))
    out = step(state["params"], data.batch_at(int(args.get("batch_idx", 0))))
    return {"loss": float(out["loss"]), "member": sid[1]}


@register_kernel("lm.checkpoint", description="checkpoint a member state")
def lm_checkpoint(args, ctx):
    from repro.checkpoint import Checkpointer
    sid = (args.get("ensemble", "default"), int(args.get("member", 0)))
    state = STATE_STORE[sid]
    ck = Checkpointer(args["dir"], keep=int(args.get("keep", 2)))
    path = ck.save(state, int(jax.device_get(state["step"])))
    return {"path": path}


@register_kernel("lm.decode", description="batched greedy decode")
def lm_decode(args, ctx):
    from repro.serve import BatchedServer, Request
    cfg = resolve_cfg(args.get("arch", "reduced:gemma2-2b"))
    sid = (args.get("ensemble", "default"), int(args.get("member", 0)))
    state = STATE_STORE.get(sid)
    if state is None:
        from repro.models import init_params
        params = init_params(cfg, jax.random.PRNGKey(0))
    else:
        params = state["params"]
    S0 = int(args.get("prompt_len", 8))
    B = int(args.get("batch", 2))
    srv = BatchedServer(cfg, params, batch=B, prompt_len=S0,
                        max_len=S0 + int(args.get("new_tokens", 4)) + 1)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, S0),
                    max_new_tokens=int(args.get("new_tokens", 4)))
            for i in range(int(args.get("requests", 2)))]
    srv.submit(reqs)
    done = srv.run()
    return {"served": len(done), "stats": srv.stats}
