"""Calibrated synthetic kernels for the scaling benchmarks (Fig. 7-10).

In sim (DES) mode the kernel supplies ``sim_duration`` and the runtime
advances a virtual clock — orchestration overheads stay real, execution time
is modeled (documented in DESIGN.md §8.5).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.kernel_plugin import register_kernel


@register_kernel("synthetic.sleep", description="busy-wait for `seconds`")
def sleep(args, ctx):
    time.sleep(float(args.get("seconds", 0.0)))
    return {"slept": float(args.get("seconds", 0.0))}


@register_kernel("synthetic.flops", description="dense matmul burner")
def flops(args, ctx):
    n = int(args.get("n", 256))
    reps = int(args.get("reps", 1))
    rng = np.random.default_rng(int(args.get("seed", 0)))
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)
    for _ in range(reps):
        a = np.tanh(a @ b)
    return {"checksum": float(a.sum()), "flops": 2.0 * n ** 3 * reps}


@register_kernel("synthetic.noop", description="empty task (overhead probe)")
def noop(args, ctx):
    return {}


@register_kernel("synthetic.echo",
                 description="returns `value` + any bound input ports")
def echo(args, ctx):
    """Data-flow probe: result carries the payload and whatever arrived on
    the task's input ports (ctx["inputs"], see core/flow.py)."""
    out = {"value": args.get("value")}
    inputs = ctx.get("inputs") or {}
    if inputs:
        out["inputs"] = inputs
    return out


@register_kernel("synthetic.fail", idempotent=True,
                 description="fails `fail_times` times, then succeeds")
def fail(args, ctx):
    task = ctx.get("task")
    fail_times = int(args.get("fail_times", 1))
    if task is not None and task.attempts <= fail_times:
        raise RuntimeError(f"injected failure (attempt {task.attempts})")
    return {"recovered_after": fail_times}
