"""Serving kernels: traffic-window source + continuous-batch decode.

Both kernels regenerate their window's requests from the seedable
TrafficModel carried in ``arguments["model"]`` (a dataclass dict) — no
request payloads travel through the graph.  In DES mode neither function
body runs (the task's ``sim_duration`` models it); in real mode
``serve.decode`` drives an actual jitted BatchedServer over a small model.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.core.kernel_plugin import register_kernel

# real-mode decode params cache: one tiny model per (arch, seed), shared
# across the many per-window decode tasks of a run
_PARAMS_CACHE: Dict[Any, Any] = {}


def _serve_cfg(arch):
    if arch:
        from repro.plugins.lm import resolve_cfg
        return resolve_cfg(arch)
    from repro.configs.base import ModelConfig
    return ModelConfig(name="serve-tiny", family="dense", num_layers=2,
                       d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                       d_ff=64, vocab_size=256, layer_pattern=("global",))


@register_kernel("serve.source",
                 description="regenerate one traffic window's requests")
def serve_source(args, ctx):
    from repro.serving.traffic import TrafficModel
    m = TrafficModel(**args["model"])
    sla = args.get("sla")
    reqs = m.requests(int(args["window"]), sla)
    return {"window": int(args["window"]), "sla": sla, "n": len(reqs),
            "prompt_tokens": sum(r.prompt_tokens for r in reqs),
            "nbytes": m.batch_nbytes(reqs)}


@register_kernel("serve.decode",
                 description="continuous-batch decode one traffic window")
def serve_decode(args, ctx):
    import jax

    from repro.serve import BatchedServer, Request
    from repro.serving.traffic import TrafficModel

    m = TrafficModel(**args["model"])
    reqs = m.requests(int(args["window"]), args.get("sla"))
    if not reqs:
        return {"served": 0, "tokens": 0}
    cfg = _serve_cfg(args.get("arch"))
    key = (cfg.name, int(args.get("param_seed", 0)))
    if key not in _PARAMS_CACHE:
        from repro.models import init_params
        _PARAMS_CACHE[key] = init_params(
            cfg, jax.random.PRNGKey(key[1]))
    S0 = int(args.get("prompt_len", 8))
    max_new = max(r.max_new_tokens for r in reqs)
    srv = BatchedServer(cfg, _PARAMS_CACHE[key],
                        batch=int(args.get("decode_slots", 4)),
                        prompt_len=S0, max_len=S0 + max_new)
    srv.submit([Request(rid=r.rid,
                        prompt=np.random.default_rng(r.rid).integers(
                            0, cfg.vocab_size, S0),
                        max_new_tokens=r.max_new_tokens, sla=r.sla)
                for r in reqs])
    done = srv.run()
    return {"served": len(done),
            "tokens": sum(len(r.out_tokens) for r in done),
            "stats": srv.stats}
