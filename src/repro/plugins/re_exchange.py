"""Replica-exchange kernels: the paper's Amber temperature-exchange analogue.

Members train at different "temperatures" (learning rates).  The exchange
kernel gathers member losses and proposes even/odd neighbor swaps with a
Metropolis criterion — the standard parallel-tempering move applied to the
hyperparameter dimension (population-based training, RE-style).

Placement: when the exchange task runs under a mesh-aware pilot
(PilotRuntime built with a SlotTopology), the scheduler grants it slot
submeshes and the PST AppManager passes ``ctx["submesh"]`` — the jax Mesh
from ``PilotRuntime.submesh_for(task)``.  With ``args["device"]`` set, the
swap is computed on that submesh's devices (the on-device
``metropolis_swap_device`` path) instead of host numpy.

Staging: under a ``repro.staging`` pilot the member traffic arrives staged
instead of passed by value — bulk member fields (trajectories, states) are
``StagedRef`` handles nested in the result dicts.  The exchange reads only
the scalar ``member``/``loss`` fields, leaves every nested ref untouched,
and reports the traffic it avoided as ``staged_avoided_bytes`` (the t_data
the swap decision did NOT cost; a ref-valued ``loss`` is dereferenced via
``ctx["staging"]`` and charged to this task's t_data).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.kernel_plugin import register_kernel
from repro.staging.ports import iter_refs
from repro.staging.store import StagedRef


def metropolis_swaps(losses, temps, cycle: int, seed: int = 0):
    """Even/odd neighbor swap proposals on a 1-D replica chain.

    Returns (new_temps, accepted_pairs).  Energies = losses; acceptance
    p = min(1, exp((E_i - E_j) * (1/T_i - 1/T_j))).
    """
    losses = np.asarray(losses, dtype=np.float64)
    temps = np.asarray(temps, dtype=np.float64).copy()
    n = len(losses)
    rng = np.random.default_rng((seed, cycle))
    accepted = []
    start = cycle % 2
    for i in range(start, n - 1, 2):
        j = i + 1
        d = (losses[i] - losses[j]) * (1.0 / temps[i] - 1.0 / temps[j])
        if math.log(max(rng.random(), 1e-12)) < d:
            temps[i], temps[j] = temps[j], temps[i]
            accepted.append((i, j))
    return temps, accepted


def _device_swaps(losses, temps, cycle: int, seed: int, submesh):
    """On-device swap on the exchange task's granted submesh (one member
    per slot submesh; the exchange itself is a scalar-vector program, placed
    on the submesh's first device)."""
    import jax
    import jax.numpy as jnp

    from repro.core.ensemble import metropolis_swap_device

    key = jax.random.fold_in(jax.random.PRNGKey(seed), cycle)
    dev = next(iter(np.asarray(submesh.devices).flat)) \
        if submesh is not None else None
    old32 = np.asarray(temps, dtype=np.float32)
    with jax.default_device(dev):
        new_t, _ = metropolis_swap_device(
            jnp.asarray(losses, jnp.float32), jnp.asarray(old32), cycle, key)
    new32 = np.asarray(jax.device_get(new_t), dtype=np.float32)
    # the device decides; the swap is applied host-side in float64 so
    # temperatures stay exact across cycles (swap detection must compare in
    # float32 — comparing against the float64 originals would flag every
    # non-representable temperature as swapped)
    new_temps = np.asarray(temps, dtype=np.float64).copy()
    accepted = []
    for i in range(cycle % 2, len(new_temps) - 1, 2):
        if new32[i] != old32[i] or new32[i + 1] != old32[i + 1]:
            new_temps[i], new_temps[i + 1] = new_temps[i + 1], new_temps[i]
            accepted.append((i, i + 1))
    return new_temps, accepted


@register_kernel("re.exchange",
                 description="Metropolis temperature exchange over members")
def re_exchange(args, ctx):
    ens = args.get("ensemble", "default")
    n = int(args["replicas"])
    cycle = int(args.get("cycle", 0))
    temps = list(map(float, args["temps"]))
    losses = [None] * n
    # primary source: the ports API — a "members" input port carrying the
    # simulation stage's {task: result} dict (flow.StageFuture/Channel);
    # fall back to raw task dependencies for un-annotated graphs
    sources = []
    for payload in (ctx.get("inputs") or {}).values():
        if isinstance(payload, dict):
            sources.extend(payload.values())
    sources.extend((ctx.get("dep_results") or {}).values())
    avoided_bytes = 0
    staging = ctx.get("staging")
    for res in sources:
        if isinstance(res, dict) and "member" in res and "loss" in res:
            loss = res["loss"]
            if isinstance(loss, StagedRef):     # unusual: staged scalar
                loss = staging.get(loss) if staging is not None else \
                    float("nan")
            losses[int(res["member"])] = float(loss)
            # bulk fields (trajectories, member state) stay LAZY: the
            # exchange decision never dereferences them, so their bytes
            # never hit this task's t_data
            avoided_bytes += sum(r.nbytes
                                 for key, v in res.items() if key != "loss"
                                 for r in iter_refs(v))
    explicit = args.get("losses")
    for i in range(n):
        if losses[i] is None and explicit is not None \
                and explicit[i] is not None:
            losses[i] = float(explicit[i])
        if losses[i] is None:
            losses[i] = float("nan")
    if args.get("device"):
        new_temps, accepted = _device_swaps(
            losses, temps, cycle, int(args.get("seed", 0)),
            ctx.get("submesh"))
    else:
        new_temps, accepted = metropolis_swaps(losses, temps, cycle,
                                               int(args.get("seed", 0)))
    out = {"temps": [float(t) for t in new_temps],
           "accepted": accepted, "losses": losses, "cycle": cycle}
    if avoided_bytes:
        out["staged_avoided_bytes"] = int(avoided_bytes)
    return out
