"""Replica-exchange kernels: the paper's Amber temperature-exchange analogue.

Members train at different "temperatures" (learning rates).  The exchange
kernel gathers member losses and proposes even/odd neighbor swaps with a
Metropolis criterion — the standard parallel-tempering move applied to the
hyperparameter dimension (population-based training, RE-style).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import numpy as np

from repro.core.kernel_plugin import register_kernel
from repro.plugins.lm import STATE_STORE


def metropolis_swaps(losses, temps, cycle: int, seed: int = 0):
    """Even/odd neighbor swap proposals on a 1-D replica chain.

    Returns (new_temps, accepted_pairs).  Energies = losses; acceptance
    p = min(1, exp((E_i - E_j) * (1/T_i - 1/T_j))).
    """
    losses = np.asarray(losses, dtype=np.float64)
    temps = np.asarray(temps, dtype=np.float64).copy()
    n = len(losses)
    rng = np.random.default_rng((seed, cycle))
    accepted = []
    start = cycle % 2
    for i in range(start, n - 1, 2):
        j = i + 1
        d = (losses[i] - losses[j]) * (1.0 / temps[i] - 1.0 / temps[j])
        if math.log(max(rng.random(), 1e-12)) < d:
            temps[i], temps[j] = temps[j], temps[i]
            accepted.append((i, j))
    return temps, accepted


@register_kernel("re.exchange",
                 description="Metropolis temperature exchange over members")
def re_exchange(args, ctx):
    ens = args.get("ensemble", "default")
    n = int(args["replicas"])
    cycle = int(args.get("cycle", 0))
    temps = list(map(float, args["temps"]))
    losses = [None] * n
    # primary source: the simulation tasks this exchange depends on
    for res in (ctx.get("dep_results") or {}).values():
        if isinstance(res, dict) and "member" in res and "loss" in res:
            losses[int(res["member"])] = float(res["loss"])
    explicit = args.get("losses")
    for i in range(n):
        if losses[i] is None and explicit is not None \
                and explicit[i] is not None:
            losses[i] = float(explicit[i])
        if losses[i] is None:
            losses[i] = float("nan")
    new_temps, accepted = metropolis_swaps(losses, temps, cycle,
                                           int(args.get("seed", 0)))
    return {"temps": [float(t) for t in new_temps],
            "accepted": accepted, "losses": losses, "cycle": cycle}
