"""Persistent task-state journal (the MongoDB analogue, per DESIGN.md §2).

Append-only JSONL of task transitions.  On restart, ``replay`` marks DONE
tasks so the executor skips re-running them — this is the checkpoint/restart
path for pattern state (model state itself is checkpointed by
repro.checkpoint at the kernel level).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, Optional

from repro.runtime.states import TaskGraph, TaskState

# names currently claimed by OPEN journal_from_env journals in this
# process: a second runtime asking for the same name gets a "-2" suffix
# instead of interleaving records into the first one's file.  close()
# releases the claim, so sequential runs (and crash-replay reopens) keep
# the original name.
_claimed_names: set = set()
_claim_lock = threading.Lock()


def journal_from_env(name: str, tag: Optional[str] = None) -> "Journal":
    """Journal writing ``$REPRO_JOURNAL_DIR/<name>.jsonl``, or a no-op
    journal when the env var is unset — lets smoke runs opt into journal
    capture (CI sanitizes the captured files) without new CLI flags.

    When several runtimes live in one process (a federated fleet, or two
    benchmarks back to back) and ask for the same ``name`` while the first
    journal is still open, later callers get a distinct ``<name>-<k>``
    suffix — two pilots never write the same file.  ``tag`` stamps every
    record with a ``pilot`` field (see :class:`Journal`)."""
    base = os.environ.get("REPRO_JOURNAL_DIR")
    if not base:
        return Journal(None, tag=tag)
    with _claim_lock:
        unique, k = name, 1
        while unique in _claimed_names:
            k += 1
            unique = f"{name}-{k}"
        _claimed_names.add(unique)
    j = Journal(os.path.join(base, f"{unique}.jsonl"), tag=tag)
    j._claimed_name = unique
    return j


class Journal:
    #: optional callable(rec: dict) invoked for every record written —
    #: the live-sanitizer hook (analysis.JournalSanitizer.observe).  Also
    #: fires when ``path`` is None, so in-memory runs can be checked.
    observer = None
    #: optional zero-arg callable returning the authoritative run clock.
    #: A sim-mode RuntimeSession sets it to ``lambda: session.vnow`` so
    #: EVERY record (task, run-level, flow) carries a ``vt`` field beside
    #: the wall ``t`` — sim journals are time-faithful on the clock the
    #: DES actually ran on, which is what repro.obs decomposes over.
    vclock = None
    #: name claimed in _claimed_names (journal_from_env only)
    _claimed_name: Optional[str] = None

    def __init__(self, path: Optional[str], *, tag: Optional[str] = None):
        self.path = path
        #: when set (the fleet sets it to the pilot name), every record
        #: carries ``"pilot": tag`` — the sanitizer scopes session_start
        #: resets per pilot, and merged-journal tooling can de-interleave.
        self.tag = tag
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)
            # a crash can leave a torn final line with no newline; terminate
            # it so records appended after restart parse on their own lines
            # (the torn fragment itself is skipped by the replay parsers)
            if self._fh.tell():
                with open(path, "rb") as probe:
                    probe.seek(-1, os.SEEK_END)
                    if probe.read(1) != b"\n":
                        self._fh.write("\n")

    def _emit(self, rec: dict):
        if self.tag is not None:
            rec.setdefault("pilot", self.tag)
        if self.vclock is not None:
            rec.setdefault("vt", self.vclock())
        if self._fh is not None:
            self._fh.write(json.dumps(rec, default=str) + "\n")
        if self.observer is not None:
            self.observer(rec)

    def record(self, task, event: str, **extra):
        if self._fh is None and self.observer is None:
            return
        rec = {"t": time.time(), "task": task.name, "event": event,
               "state": task.state.value, "attempts": task.attempts}
        if task.error:
            rec["error"] = task.error
        if event == "finished" and task.result is not None:
            try:                 # persist results a restart can replay —
                json.dumps(task.result)   # callbacks (apply_exchange,
                rec["result"] = task.result   # should_continue) need them
            except (TypeError, ValueError):
                pass             # non-JSON results replay as None
        rec.update(extra)
        self._emit(rec)
        return rec

    def record_event(self, event: str, **extra):
        """Run-level (taskless) record: session_start, pod_lost,
        pod_revived, topology compaction.  Replay parsers that key on
        ``task`` skip these."""
        if self._fh is None and self.observer is None:
            return
        rec = {"t": time.time(), "event": event, **extra}
        self._emit(rec)

    def record_flow(self, event: str, channel: str, producer: str,
                    value=None, consumer: Optional[str] = None,
                    digest: Optional[str] = None,
                    nbytes: Optional[int] = None,
                    mode: Optional[str] = None):
        """Persist a data-flow event (core.flow): ``channel_put`` carries
        the put value (when JSON-serializable), ``channel_take`` the
        consumer->producer binding.  Replay uses these so coupled pipelines
        see identical inputs after a restart.

        Staged puts (repro.staging) journal their ref *encoded* as the
        value AND carry ``digest``/``nbytes`` explicitly, so a coupled
        restart re-binds consumers to the content-addressed blob (spill
        file) without re-staging the payload."""
        if self._fh is None and self.observer is None:
            return
        rec = {"t": time.time(), "event": event, "channel": channel,
               "producer": producer}
        if consumer is not None:
            rec["consumer"] = consumer
        if mode is not None:
            rec["mode"] = mode
        if digest is not None:
            rec["digest"] = digest
            if nbytes is not None:
                rec["nbytes"] = int(nbytes)
        if event == "channel_put":
            try:
                # only values that survive the JSON round-trip UNCHANGED
                # are authoritative on replay (a tuple would come back as
                # a list — different type than the original run delivered);
                # lossy payloads are omitted and the restart recomputes
                # them from replayed task results
                if json.loads(json.dumps(value)) == value:
                    rec["value"] = value
            except (TypeError, ValueError):
                pass
        self._emit(rec)

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None
        if self._claimed_name is not None:
            with _claim_lock:
                _claimed_names.discard(self._claimed_name)
            self._claimed_name = None

    # -------------------------------------------------------------- replay
    # attempt-terminating events whose records seed Task.history on restart
    # ("preempted" is an eviction, not a failure — it still counts an
    # attempt, so a restart resumes with the right epoch numbering, but
    # faults.FAILED_OUTCOMES excludes it: no pod blame)
    _ATTEMPT_EVENTS = ("failed", "pod_lost", "worker_died",
                       "heartbeat_timeout", "preempted")

    def load_state(self):
        """Parse the journal once: ``(done, results, history)``.

        ``done``/``results`` replay finished tasks (as before).
        ``history`` maps task name -> list of failed-attempt records
        ``{"attempt", "pod", "outcome"}`` for tasks NOT done — the
        retry-remembering set: a run that crashed mid-retry restarts with
        its attempt count and failing-pod exclusions intact instead of a
        fresh retry budget."""
        done: set = set()
        results: Dict[str, object] = {}
        history: Dict[str, list] = {}
        if not self.path or not os.path.exists(self.path):
            return done, results, history
        with open(self.path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn write at crash: ignore
                ev = rec.get("event")
                if ev == "finished" and rec.get("state") == "DONE":
                    done.add(rec["task"])
                    if "result" in rec:
                        results[rec["task"]] = rec["result"]
                elif ev in self._ATTEMPT_EVENTS and "task" in rec:
                    history.setdefault(rec["task"], []).append(
                        {"attempt": int(rec.get("attempts", 1)),
                         "pod": rec.get("pod"), "outcome": ev})
        # dedupe per (attempt): terminal failure writes both a
        # reason record and a "failed" record for the same attempt
        for name, entries in history.items():
            seen, uniq = set(), []
            for h in entries:
                if h["attempt"] not in seen:
                    seen.add(h["attempt"])
                    uniq.append(h)
            history[name] = uniq
        return done, results, history

    def load_done(self):
        """(set of DONE task names, name->result) — see :meth:`load_state`."""
        done, results, _ = self.load_state()
        return done, results

    def load_digests(self) -> set:
        """Every staged-blob digest any journal record references — the
        KEEP set for spill-file GC: deleting a referenced blob's spill
        file would end the restartability of journaled refs."""
        digests: set = set()
        if not self.path or not os.path.exists(self.path):
            return digests
        with open(self.path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                d = rec.get("digest")
                if d:
                    digests.add(d)
        return digests

    def load_flow(self):
        """Parse data-flow records: ``(puts, takes)`` where puts maps
        ``(channel, producer_key) -> value`` and takes maps
        ``(channel, consumer_key) -> producer_key`` (last record wins)."""
        puts: Dict[tuple, object] = {}
        takes: Dict[tuple, str] = {}
        if not self.path or not os.path.exists(self.path):
            return puts, takes
        with open(self.path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn write at crash: ignore
                ev = rec.get("event")
                if ev == "channel_put":
                    # records without "value" (non-JSON payload) carry no
                    # authoritative value: the restart recomputes the put
                    # from replayed stage results instead
                    if "value" in rec:
                        puts[(rec["channel"], rec["producer"])] = \
                            rec["value"]
                elif ev == "channel_take":
                    takes[(rec["channel"], rec["consumer"])] = \
                        rec["producer"]
        return puts, takes

    def replay(self, graph: TaskGraph) -> int:
        """Mark tasks recorded DONE as done; returns #skipped."""
        done, results = self.load_done()
        n = 0
        for name in done:
            t = graph.tasks.get(name)
            if t is not None and not t.state.terminal:
                t.state = TaskState.DONE
                t.result = results.get(name, t.result)
                n += 1
        return n
