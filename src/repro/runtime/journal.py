"""Persistent task-state journal (the MongoDB analogue, per DESIGN.md §2).

Append-only JSONL of task transitions.  On restart, ``replay`` marks DONE
tasks so the executor skips re-running them — this is the checkpoint/restart
path for pattern state (model state itself is checkpointed by
repro.checkpoint at the kernel level).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional

from repro.runtime.states import TaskGraph, TaskState


class Journal:
    def __init__(self, path: Optional[str]):
        self.path = path
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)

    def record(self, task, event: str, **extra):
        if self._fh is None:
            return
        rec = {"t": time.time(), "task": task.name, "event": event,
               "state": task.state.value, "attempts": task.attempts}
        if task.error:
            rec["error"] = task.error
        if event == "finished" and task.result is not None:
            try:                 # persist results a restart can replay —
                json.dumps(task.result)   # callbacks (apply_exchange,
                rec["result"] = task.result   # should_continue) need them
            except (TypeError, ValueError):
                pass             # non-JSON results replay as None
        rec.update(extra)
        self._fh.write(json.dumps(rec, default=str) + "\n")

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None

    # -------------------------------------------------------------- replay
    def load_done(self):
        """Parse the journal once: (set of DONE task names, name->result).

        Sessions load this at open and apply it per ``submit`` — dynamically
        injected tasks replay the same way as prebuilt graphs."""
        done: set = set()
        results: Dict[str, object] = {}
        if not self.path or not os.path.exists(self.path):
            return done, results
        with open(self.path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn write at crash: ignore
                if rec.get("event") == "finished" and \
                        rec.get("state") == "DONE":
                    done.add(rec["task"])
                    if "result" in rec:
                        results[rec["task"]] = rec["result"]
        return done, results

    def replay(self, graph: TaskGraph) -> int:
        """Mark tasks recorded DONE as done; returns #skipped."""
        done, results = self.load_done()
        n = 0
        for name in done:
            t = graph.tasks.get(name)
            if t is not None and not t.state.terminal:
                t.state = TaskState.DONE
                t.result = results.get(name, t.result)
                n += 1
        return n
