"""Failure model for the pilot runtime: pod death as a NORMAL event.

The paper's pilot decouples workload from resource management; production
fleets lose pods constantly, and the follow-on EnTK work ("Harnessing the
Power of Many") makes ensemble-layer fault tolerance a first-class
requirement.  scitq's ``Execution``/``WorkerPing`` design is the exemplar
shape: every attempt is a remembered row carrying the worker it ran on, a
ping monitor declares silent workers offline, and retries are re-placed
AWAY from the worker that failed.  This module is that shape for our slots:

  FaultInjector     deterministic pod-kill schedule (chaos testing).  Time
                    is "seconds since run start" — the VIRTUAL clock in DES
                    mode, wall-clock elapsed in real mode — so the same
                    injector drives both.  Kills either name a pod or leave
                    the victim to the scheduler (which picks the busiest
                    live pod, deterministically).  ``respawn_after``
                    models a replacement pod joining the fleet: the dead
                    pod's slot ids return, with NO data replicas (a fresh
                    pod remembers nothing).

  FailureDetector   heartbeat bookkeeping for real mode.  Worker-thread
                    death (the thread exits without running its completion
                    bookkeeping — e.g. a ``SystemExit`` escaping the task
                    isolation boundary) is detected structurally by the
                    drain loop; the detector adds the *hung* case: a task
                    whose heartbeat goes stale past ``heartbeat_timeout``
                    is declared lost even though its thread is alive, and
                    its eventual completion is ignored (launch epochs).

The executor turns a pod death into: fail the in-flight attempts on that
pod (recorded in ``Task.history`` with the pod), retire the pod's slot
ids (capacity shrinks; with a device topology the shrink re-carves at the
next quiescent point), drop the pod's staged-data replicas, and re-grant
retries EXCLUDING the failing pod.
"""
from __future__ import annotations

import heapq
import itertools
import time
from typing import List, Optional, Sequence, Tuple

KILL = "kill"
REVIVE = "revive"

# attempt outcomes that mark the pod as failing for retry exclusion
FAILED_OUTCOMES = ("failed", "pod_lost", "worker_died", "heartbeat_timeout")


class FaultInjector:
    """Deterministic schedule of pod failures (and respawns).

    ``kill_every``: periodic kills starting at ``first_kill`` (defaults to
    one period in).  ``kill_at``: explicit ``(time, pod)`` pairs (pod may
    be None — the scheduler picks the victim).  ``max_kills`` bounds the
    periodic stream.  ``respawn_after``: seconds after each kill at which
    a replacement pod (same slot ids, no replicas) joins the fleet.
    """

    def __init__(self, *, kill_every: Optional[float] = None,
                 first_kill: Optional[float] = None,
                 kill_at: Sequence[Tuple[float, Optional[str]]] = (),
                 pods: Optional[Sequence[str]] = None,
                 max_kills: Optional[int] = None,
                 respawn_after: Optional[float] = None):
        if kill_every is not None and kill_every <= 0:
            raise ValueError("kill_every must be positive")
        self.kill_every = kill_every
        self.respawn_after = respawn_after
        self.max_kills = max_kills
        self._pods = list(pods) if pods else []
        self._pod_i = 0
        self._seq = itertools.count()
        # (time, seq, kind, pod) — seq breaks ties deterministically
        self._events: List[Tuple[float, int, str, Optional[str]]] = []
        for t, pod in kill_at:
            heapq.heappush(self._events,
                           (float(t), next(self._seq), KILL, pod))
        self._next_periodic = (first_kill if first_kill is not None
                               else kill_every)
        self.n_kills = 0          # kills actually fired (periodic + explicit)

    # ------------------------------------------------------------ schedule
    def kill_now(self, pod: Optional[str] = None):
        """Inject an immediate kill (fires at the next scheduling step)."""
        heapq.heappush(self._events, (0.0, next(self._seq), KILL, pod))

    def schedule_revive(self, pod: str, now: float):
        if self.respawn_after is not None:
            heapq.heappush(self._events,
                           (now + self.respawn_after, next(self._seq),
                            REVIVE, pod))

    # ------------------------------------------------------------ queries
    def _periodic_live(self) -> bool:
        return (self.kill_every is not None
                and (self.max_kills is None
                     or self.n_kills < self.max_kills))

    def next_time(self) -> Optional[float]:
        """Earliest pending event time (None when nothing is scheduled)."""
        times = []
        if self._events:
            times.append(self._events[0][0])
        if self._periodic_live():
            times.append(self._next_periodic)
        return min(times) if times else None

    def pending_revive(self) -> bool:
        """True when a replacement pod is scheduled to join (the scheduler
        must keep waiting rather than cancel capacity-starved tasks)."""
        return any(kind == REVIVE for _, _, kind, _ in self._events)

    # ------------------------------------------------------------ firing
    def _next_pod_hint(self) -> Optional[str]:
        if not self._pods:
            return None
        pod = self._pods[self._pod_i % len(self._pods)]
        self._pod_i += 1
        return pod

    def pop_due(self, now: float) -> List[Tuple[str, Optional[str]]]:
        """Events due at or before ``now``, in time order, consuming them.
        Returns ``(kind, pod)`` pairs; a kill's pod may be None (caller
        picks the victim)."""
        out: List[Tuple[str, Optional[str]]] = []
        while True:
            t_ev = self._events[0][0] if self._events else None
            t_per = (self._next_periodic if self._periodic_live()
                     else None)
            if t_per is not None and (t_ev is None or t_per <= t_ev):
                if t_per > now:
                    break
                self._next_periodic = t_per + self.kill_every
                self.n_kills += 1
                out.append((KILL, self._next_pod_hint()))
                continue
            if t_ev is None or t_ev > now:
                break
            _, _, kind, pod = heapq.heappop(self._events)
            if kind == KILL:
                self.n_kills += 1
            out.append((kind, pod))
        return out


class FailureDetector:
    """Heartbeat staleness policy (real mode).

    Workers beat at attempt start (and kernels may beat via
    ``Task.beat()`` during long executions); ``stale`` declares an
    attempt lost when its last beat is older than ``heartbeat_timeout``.
    ``None`` disables staleness checks — worker-thread *death* is always
    detected regardless (it needs no timeout)."""

    def __init__(self, heartbeat_timeout: Optional[float] = None):
        self.heartbeat_timeout = heartbeat_timeout

    def beat(self, task, now: Optional[float] = None):
        task.meta["heartbeat"] = (now if now is not None
                                  else time.perf_counter())

    def stale(self, task, now: float) -> bool:
        if self.heartbeat_timeout is None:
            return False
        last = task.meta.get("heartbeat") or task.t_started
        return (now - last) > self.heartbeat_timeout
