"""Adaptive execution strategies — the paper's §5 future work ("transition
from static workload-resource mapping to adaptive mapping", Ref [41]):
time-ordered resource decisions driven by observed workload state.

``AdaptiveSlotStrategy`` watches per-phase utilization and resizes the pilot
between pattern phases: shrink when slots idle (freeing allocation for other
pilots), grow up to a cap when the ready backlog would overflow the current
width.  It plugs into any pattern run as a callback."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.resource_handler import Pilot


@dataclass
class AdaptiveSlotStrategy:
    min_slots: int
    max_slots: int
    target_utilization: float = 0.85
    grow_factor: float = 2.0

    def decide(self, *, utilization: float, backlog: int,
               slots: int) -> int:
        """Return the slot count for the next phase."""
        if backlog > slots and utilization >= self.target_utilization:
            want = min(int(slots * self.grow_factor), self.max_slots,
                       max(backlog, slots))
        elif utilization < self.target_utilization / 2:
            want = max(self.min_slots, slots // 2)
        else:
            want = slots
        return max(self.min_slots, min(want, self.max_slots))

    def apply(self, pilot: Pilot, *, utilization: float, backlog: int) -> int:
        want = self.decide(utilization=utilization, backlog=backlog,
                           slots=pilot.slots)
        if want != pilot.slots:
            pilot.resize(want)
        return want
