"""Adaptive execution strategies — the paper's §5 future work ("transition
from static workload-resource mapping to adaptive mapping", Ref [41]):
time-ordered resource decisions driven by observed workload state.

``AdaptiveSlotStrategy`` watches utilization and resizes the pilot: shrink
when slots idle (freeing allocation for other pilots), grow up to a cap when
the ready backlog would overflow the current width.  It plugs in two ways:

  between runs   call ``decide``/``apply`` with per-phase profiling numbers
  live           pass ``strategy=`` to ``AppManager``: it calls ``apply``
                 at every stage completion with the session's LIVE
                 per-pipeline queue depths (``per_pipeline``) and a
                 demand-aware utilization, so the pilot re-sizes while
                 pipelines are still streaming."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.resource_handler import Pilot


@dataclass
class AdaptiveSlotStrategy:
    min_slots: int
    max_slots: int
    target_utilization: float = 0.85
    grow_factor: float = 2.0

    def decide(self, *, utilization: float, backlog: int,
               slots: int) -> int:
        """Return the slot count for the next phase."""
        if backlog > slots and utilization >= self.target_utilization:
            want = min(int(slots * self.grow_factor), self.max_slots,
                       max(backlog, slots))
        elif utilization < self.target_utilization / 2:
            want = max(self.min_slots, slots // 2)
        else:
            want = slots
        return max(self.min_slots, min(want, self.max_slots))

    def apply(self, pilot: Pilot, *, utilization: float, backlog: int,
              per_pipeline: Optional[Dict[str, int]] = None) -> int:
        """Resize ``pilot`` (any object with ``slots``/``resize``, so a bare
        PilotRuntime works too).  ``per_pipeline`` carries live per-pipeline
        queue depths when called from a running AppManager session; the
        default policy decides on the total, subclasses may weigh pipelines
        individually."""
        want = self.decide(utilization=utilization, backlog=backlog,
                           slots=pilot.slots)
        if want != pilot.slots:
            try:
                pilot.resize(want)
            except ValueError:
                # infeasible width (e.g. not a re-carvable multiple of a
                # mesh-backed pilot's slot topology): an adaptive decision
                # is advisory — hold the current width rather than kill
                # the session from inside a completion callback
                return pilot.slots
        return want
