"""Pilot runtime executor: application-level scheduling of tasks onto the
pilot's slots (the RADICAL-Pilot analogue).

Two modes:
  real - tasks execute their callables on a slot thread pool (JAX work
         serializes on the device; orchestration concurrency is real).
  sim  - discrete-event simulation: task ``duration`` advances a virtual
         clock.  Scheduler/bookkeeping overheads are still measured on the
         real clock — this is how the Fig.7-10 scaling benches reproduce the
         paper's overhead measurements at 2560 tasks without hours of
         wall-clock sleep.

Incremental scheduling: a :class:`RuntimeSession` is a long-lived scheduling
context over one pilot.  ``submit()`` injects tasks at any time — including
from an ``on_task_done`` callback fired as each task completes — and
``drain()`` runs until everything submitted is terminal.  This is what lets
the PST ``AppManager`` (repro.core.pst) multiplex many pipelines over ONE
pilot session with no global barrier and no per-cycle graph teardown: a
completed exchange in ensemble A schedules A's next cycle immediately while
ensemble B is still simulating.  ``PilotRuntime.run(graph)`` is now a thin
wrapper: one session, one bulk submit, one drain.

Fault tolerance (repro.runtime.faults): pod death is a NORMAL event, not an
abort.  A ``FaultInjector`` kills pods on the run clock (virtual in sim,
wall-clock elapsed in real); real mode additionally detects worker-thread
death structurally and hung tasks via heartbeat staleness.  A pod loss
fails the in-flight attempts on that pod — each recorded in
``Task.history`` with the pod it ran on (the scitq Execution-table shape) —
retires the pod's slot ids (capacity shrinks; with a device topology the
fleet shrink-recarves at the next quiescent point), drops the pod's staged
replicas, and re-grants bounded retries EXCLUDING the failing pod.  Every
launch carries an *epoch* (the attempt number); completions whose epoch no
longer matches the task's live epoch are zombies and are ignored, so an
abandoned attempt can never double-release slots or overwrite a retry.
Journal records (``pod_lost``/``worker_died``/``heartbeat_timeout``) replay
into ``Task.history`` on restart, so a run crashed mid-retry resumes with
its attempt count and pod exclusions intact.

Straggler mitigation via speculative duplicates (sim): clones route through
the SAME staging manifests as their originals, so a clone's input transfers
charge t_data exactly like the original's — the TTC decomposition stays
disjoint.  Elastic pilot resize mid-run; journal for restart (dynamically
injected tasks are journaled with a ``submitted`` record so a restarted
session can tell replayed structure from new work).

Mesh-aware slots: with a ``topology`` (repro.dist.topology.SlotTopology) the
pilot's slots are *device submeshes* — a task occupying ``slots`` pilot slots
is granted that many slot ids (``task.meta["slot_ids"]``) and can build its
JAX mesh via ``runtime.submesh_for(task)``.  This ties the paper's pilot-slot
abstraction to device placement: e.g. one replica-exchange member per pod of
the 2x16x16 production mesh.

Data staging: with a ``staging`` layer (repro.staging.StagingLayer) tasks
carrying staged refs (``task.meta["staged_refs"]``) have their transfers
planned and executed between ``pop_ready`` and kernel launch, charged to
the task's ``t_data``; slot ids are granted locality-aware (free slots in
pods that already hold the task's input replicas first) and the scheduling
pass orders the frontier so input-local tasks run before tasks that would
have to copy.  Slot-id accounting turns on even without a device topology
(abstract ids) so locality — and pod-level fault exclusion — works on
plain pilots.
"""
from __future__ import annotations

import heapq
import statistics
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Union

from repro.runtime.faults import REVIVE, FailureDetector
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState


def _staged_extra(t: Task) -> Dict[str, Any]:
    """``scheduled``-record annotation: the staged-input digests this
    attempt holds, so the sanitizer's S303 check can pair every hold
    with its eventual ``staged_release``."""
    digs = [ref.digest for _kind, _key, ref in t.meta.get("staged_refs", ())]
    return {"staged": digs} if digs else {}


@dataclass
class RuntimeProfile:
    """TTC decomposition (paper eq. 1-2)."""
    ttc: float = 0.0                   # makespan (virtual in sim mode)
    t_exec: float = 0.0                # sum of task execution times
    t_data: float = 0.0                # upload/download time
    t_rts_overhead: float = 0.0        # scheduling/dispatch (T_RP analogue)
    n_tasks: int = 0
    n_failed: int = 0
    n_canceled: int = 0
    n_retries: int = 0
    n_speculative: int = 0
    n_pod_lost: int = 0                # attempts lost to pod/worker failure
    n_preempted: int = 0               # attempts evicted for higher priority
    slot_busy: float = 0.0             # aggregate busy slot-seconds
    events: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.slot_busy / max(self.ttc, 1e-12)


class PilotRuntime:
    def __init__(self, slots: Optional[int] = None, *, mode: str = "real",
                 topology=None,
                 journal: Optional[Journal] = None,
                 staging=None,
                 faults=None,
                 heartbeat_timeout: Optional[float] = None,
                 max_retries: int = 2,
                 straggler_factor: float = 0.0,
                 min_straggler_samples: int = 5,
                 sanitize: bool = False,
                 preempt: bool = False,
                 tracer=None,
                 on_schedule: Optional[Callable] = None):
        assert mode in ("real", "sim")
        if slots is None:
            if topology is None:
                raise ValueError("need slots= or topology=")
            slots = topology.n_slots
        self.slots = slots
        self.mode = mode
        self.topology = topology
        if topology is not None and slots > topology.n_slots:
            raise ValueError(f"{slots} slots > {topology.n_slots} submeshes")
        # free slot ids: tracked when the slots are device submeshes, when
        # a staging layer needs slot locality, and when a fault model needs
        # pod membership (a pod is a group of slot ids)
        self._free_ids: Optional[List[int]] = (
            list(range(topology.n_slots))[::-1] if topology is not None
            else list(range(slots))[::-1]
            if (staging is not None or faults is not None
                or heartbeat_timeout is not None)
            else None)
        # abstract ids ever minted and not retired (free + held): resize
        # must never re-mint an id a running task still holds
        self._minted: Optional[set] = \
            set(self._free_ids) if (topology is None
                                    and self._free_ids is not None) else None
        self.staging = staging
        if staging is not None:
            staging.bind_runtime(self)
        self.journal = journal or Journal(None)
        # live invariant checking (repro.analysis): every record the
        # journal emits ALSO flows through the sanitizer, which raises
        # DiagnosticError at the exact record that breaks an invariant.
        # Priming digests a pre-existing journal so prior segments' puts
        # and epochs are known (else every replayed take looks unbound).
        self.sanitizer = None
        if sanitize:
            from repro.analysis.sanitizer import JournalSanitizer
            self.sanitizer = JournalSanitizer(strict=True)
            self.sanitizer.prime(self.journal.path)
            self.journal.observer = self.sanitizer.observe
        self.faults = faults
        self.detector = FailureDetector(heartbeat_timeout) \
            if heartbeat_timeout is not None else None
        # pod-failure bookkeeping: retired ids stay OUT of the free pool
        # (and out of re-minting) until the pod revives or the topology
        # compacts them away at a quiescent point
        self.dead_pods: set = set()
        self._dead_ids: set = set()
        self._dead_pod_ids: Dict[str, List[int]] = {}
        self._drop_pending = False
        self.max_retries = max_retries
        # priority preemption: a ready task with priority > 0 that cannot
        # fit may evict RUNNING lower-priority idempotent tasks through
        # the abandon/requeue path (epoch-stamped — the completion of a
        # preempted attempt is an inert zombie).  Preemption is not a
        # failure: it neither blames the pod nor consumes retry budget.
        self.preempt = preempt
        # flight recorder (repro.obs.Tracer): every attempt/park/fault
        # becomes a span on the run's authoritative clock; None = untraced
        # (hook sites pay one attribute read)
        self.tracer = tracer
        self.straggler_factor = straggler_factor
        self.min_straggler_samples = min_straggler_samples
        # called as on_schedule(runtime, graph, vnow) before every
        # scheduling step (vnow None in real mode) — the hook adaptive
        # strategies use to resize() the pilot MID-run
        self.on_schedule = on_schedule
        self._resize_to: Optional[int] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------ elastic
    def resize(self, slots: int):
        """Elastic pilot resize; takes effect at the next scheduling step.

        Growing past the carved submesh count re-carves the topology (e.g.
        2 pods -> 4 half-pods): validated here, applied at the first
        scheduling step where no task holds a slot id.
        """
        if self.topology is not None and slots > self.topology.n_slots:
            self.topology.recarve(slots)      # raises if not re-carvable
        with self._lock:
            self._resize_to = slots

    def _apply_resize(self) -> int:
        """Apply a pending resize; returns the capacity delta (real mode
        must credit/debit its free-slot counter by it)."""
        with self._lock:
            if self._resize_to is None:
                return 0
            if self.topology is not None \
                    and self._resize_to > self.topology.n_slots:
                # re-carve only when every live slot id is free: ids change
                # meaning, so in-flight tasks must drain first (the resize
                # stays pending and re-tries each scheduling step); retired
                # ids of a dead pod must compact away first too
                n_live = self.topology.n_slots - len(self._dead_ids)
                if self._dead_ids or len(self._free_ids) < n_live:
                    return 0
                self.topology = self.topology.recarve(self._resize_to)
                self._free_ids = list(range(self.topology.n_slots))[::-1]
            delta = self._resize_to - self.slots
            if self.topology is None and self._free_ids is not None:
                # abstract (staging-only) ids track capacity directly:
                # grow mints the lowest ids not currently outstanding
                # (NEVER an id a running task holds — that would alias two
                # tasks onto one locality domain — nor a dead pod's id),
                # shrink retires free ones (held ids return to a pool the
                # capacity gate no longer admits)
                if delta > 0:
                    new, i = [], 0
                    while len(new) < delta:
                        if i not in self._minted and i not in self._dead_ids:
                            new.append(i)
                        i += 1
                    self._minted.update(new)
                    self._free_ids[:0] = new[::-1]
                elif delta < 0:
                    drop = set(sorted(self._free_ids,
                                      reverse=True)[:-delta])
                    self._free_ids = [i for i in self._free_ids
                                     if i not in drop]
                    self._minted -= drop
            delta_out = delta
            self.slots = self._resize_to
            self._resize_to = None
            return delta_out

    # ------------------------------------------------------------ pods
    #: pod-name namespace for pilots WITHOUT a staging locality map —
    #: repro.federation sets it per pilot ("p1:") so two pilots' pod names
    #: never collide in a shared exclusion set / fault injector / journal
    _pod_prefix = ""

    def _pod_of(self, slot_id: int) -> str:
        """Locality domain of a slot id (staging's map when bound, else a
        one-slot-per-pod convention — so fault exclusion works without a
        staging layer)."""
        if self.staging is not None and self.staging.locality is not None:
            return self.staging.locality.pod_of(int(slot_id))
        return f"{self._pod_prefix}pod{int(slot_id)}"

    def _task_pod(self, t: Task) -> Optional[str]:
        ids = t.meta.get("slot_ids")
        if not ids:
            return None
        return self._pod_of(min(ids))

    def _all_live_ids(self) -> List[int]:
        if self.topology is not None:
            return [i for i in range((self.topology.n_slots))
                    if i not in self._dead_ids]
        if self._minted is not None:
            return sorted(self._minted)
        return []

    def live_pods(self) -> List[str]:
        return sorted({self._pod_of(i) for i in self._all_live_ids()})

    def _pod_ids(self, pod: str) -> List[int]:
        return [i for i in self._all_live_ids() if self._pod_of(i) == pod]

    def _retire_ids(self, ids: List[int], pod: str):
        """Take a dead pod's slot ids out of circulation."""
        self.dead_pods.add(pod)
        self._dead_pod_ids[pod] = list(ids)
        self._dead_ids.update(ids)
        if self._free_ids is not None:
            dead = set(ids)
            self._free_ids = [i for i in self._free_ids if i not in dead]
        if self._minted is not None:
            self._minted.difference_update(ids)

    def inject_pod_failure(self, pod: Optional[str] = None):
        """Kill a pod at the next scheduling step (chaos hook; creates a
        bare FaultInjector when the runtime has none)."""
        from repro.runtime.faults import FaultInjector
        if self.faults is None:
            self.faults = FaultInjector()
        self.faults.kill_now(pod)

    def _apply_topology_drop(self) -> bool:
        """Shrink-recarve after pod loss: compact the device topology to
        the surviving slots.  Slot ids renumber, so this applies only at a
        quiescent point (every live id free); staged replica locations
        keyed on old pod names reset conservatively."""
        with self._lock:
            if not self._drop_pending or self.topology is None:
                return False
            n_live = self.topology.n_slots - len(self._dead_ids)
            if self._free_ids is None or len(self._free_ids) < n_live:
                return False
            self.topology = self.topology.drop(sorted(self._dead_ids))
            n = self.topology.n_slots
            self._free_ids = list(range(n))[::-1]
            self._dead_ids.clear()
            self._dead_pod_ids.clear()
            self.dead_pods.clear()
            self.slots = min(self.slots, n)
            self._drop_pending = False
            self.journal.record_event("topology_compacted", n_slots=n)
            if self.staging is not None:
                self.staging.on_topology_compacted(n)
            return True

    # ------------------------------------------------------------ submeshes
    def _acquire_slots(self, t: Task):
        """Grant ``t.slots`` slot ids (no-op without id tracking).

        Called wherever busy-count is incremented; capacity gating
        (busy <= self.slots <= live submeshes) guarantees availability.
        With a staging layer the grant is locality-aware: free ids in pods
        that already hold the task's staged input replicas come first, so
        the stage-in pass resolves to *link* instead of *copy*.  A retry
        whose history blames specific pods is granted ids AWAY from them
        (availability still wins: excluded pods are used last, not never).
        """
        if self._free_ids is None:
            return
        order: Optional[List[int]] = None
        if self.staging is not None and t.meta.get("staged_refs"):
            order = self.staging.preferred_ids(t, self._free_ids)
        excl = t.excluded_pods() if t.history else ()
        if excl:
            base = order if order is not None else sorted(self._free_ids)
            order = [i for i in base if self._pod_of(i) not in excl] \
                + [i for i in base if self._pod_of(i) in excl]
        if order is not None:
            ids = order[:t.slots]
            for i in ids:
                self._free_ids.remove(i)
            t.meta["slot_ids"] = ids
        else:
            t.meta["slot_ids"] = [self._free_ids.pop()
                                  for _ in range(t.slots)]
        t.meta.pop("slots_released", None)

    # ------------------------------------------------------------ staging
    def _stage_in_task(self, t: Task) -> float:
        """Execute the task's planned input transfers (repro.staging) —
        runs between ``pop_ready`` and kernel launch.  Returns the
        seconds charged to t_data (0.0 without a staging layer)."""
        if self.staging is None or not t.meta.get("staged_refs"):
            return 0.0
        return self.staging.stage_in(t, self.mode)

    def _staging_finish(self, t: Task):
        """Terminal-state hook: release the task's staged-blob holds.
        The release is journaled (once, the finish() guard dedupes) so the
        sanitizer's S303 balance check can audit it post-hoc."""
        if self.staging is not None:
            released = self.staging.finish(t)
            if released:
                self.journal.record(t, "staged_release", digests=released)

    def _release_slots(self, t: Task):
        """Return t's slot ids exactly once (supersession may race a pop);
        ids of a dead pod stay retired instead of re-entering the pool."""
        if self._free_ids is None or "slot_ids" not in t.meta:
            return
        if t.meta.get("slots_released"):
            return
        t.meta["slots_released"] = True
        self._free_ids.extend(i for i in t.meta["slot_ids"]
                              if i not in self._dead_ids)

    def submesh_for(self, t: Task):
        """jax Mesh over the devices of the slots granted to ``t``."""
        if self.topology is None:
            raise ValueError("runtime has no device topology")
        return self.topology.submesh(t.meta["slot_ids"])

    # ------------------------------------------------------------ sessions
    def session(self, *, on_task_done: Optional[Callable] = None
                ) -> "RuntimeSession":
        """Open a long-lived incremental scheduling session."""
        return RuntimeSession(self, on_task_done=on_task_done)

    # ------------------------------------------------------------ run
    def run(self, graph: TaskGraph) -> RuntimeProfile:
        """Closed-world execution of a prebuilt graph (one-shot session)."""
        graph.validate()
        sess = RuntimeSession(self, graph=graph)
        # journal replay from the session's (single) parse of the file
        skipped = sum(sess._replay_task(t) for t in graph.tasks.values())
        if skipped:
            sess.prof.events.append({"event": "journal_skip", "n": skipped})
        return sess.drain()

    # ------------------------------------------------------------ shutdown
    def close(self, *, keep_durable: bool = True) -> int:
        """Close the runtime: GC spill files the staging layer can prove
        unreferenced (zero-ref blobs whose digest no journal record still
        names — deleting a journaled ref's file would end restartability),
        then close the journal.  ``keep_durable=False`` drops journaled
        digests from the keep set too (a run that will never be replayed).
        Returns the number of spill files reclaimed."""
        n = 0
        if self.staging is not None:
            n = self.staging.gc_spill(self.journal,
                                      keep_durable=keep_durable)
        self.journal.close()
        return n


class RuntimeSession:
    """Incremental scheduling over one pilot: ``submit()`` then ``drain()``.

    The session owns the live TaskGraph, the virtual clock (sim mode), and
    the busy-slot accounting, all of which persist across submissions.  An
    ``on_task_done(task, session)`` callback fires from inside the drain
    loop as each non-speculative task reaches a terminal state and may call
    :meth:`submit` to inject downstream work — dynamic injection is what
    turns the per-cycle barrier of the legacy plugins into streaming,
    per-pipeline progress.  Callbacks run on the drain thread; ``submit``
    is not thread-safe against a concurrent ``drain``.
    """

    def __init__(self, runtime: PilotRuntime, *, graph: Optional[TaskGraph]
                 = None, on_task_done: Optional[Callable] = None):
        self.rt = runtime
        self.graph = graph if graph is not None else TaskGraph()
        self.prof = RuntimeProfile()
        self.on_task_done = on_task_done
        self.vnow = 0.0                      # virtual clock (sim mode)
        self._t0: Optional[float] = None     # real clock at first drain
        self._cbq: deque = deque()           # terminal tasks awaiting callback
        # sim-mode state (persists across drains: the clock never resets)
        self._busy = 0
        self._heap: List = []                # (v_finish, seq, epoch, task)
        self._seq = 0
        self._durations: Dict[str, List[float]] = {}
        self._spec_launched: Dict[str, Task] = {}
        # real-mode state
        self._cv = threading.Condition(threading.Lock())
        self._free = {"n": runtime.slots}
        # workers still inside _execute_real: a task flips to a terminal
        # state BEFORE its completion bookkeeping (callback enqueue, slot
        # release) runs under the lock, so graph.done() alone must never
        # end the drain loop
        self._inflight = 0
        # live (task name, launch epoch) -> (worker thread, task): the
        # failure scan walks this; completion pops its own entry, and a
        # completion whose entry is GONE was abandoned (pod kill / stale
        # heartbeat) — its bookkeeping already happened, so it is a zombie
        # and returns without touching the accounting
        self._live_attempts: Dict[tuple, tuple] = {}
        self._zombie_threads: set = set()
        # journal replay set, loaded once per session
        self._replayed_done, self._replayed_results, \
            self._replayed_history = runtime.journal.load_state()
        # observability (repro.obs): sim sessions make the journal
        # time-faithful — every record carries a ``vt`` field on the
        # virtual clock beside its wall ``t`` — and the frontier stamps
        # each task's ready time for the t_sched decomposition term
        self.tracer = getattr(runtime, "tracer", None)
        if runtime.mode == "sim":
            runtime.journal.vclock = lambda: self.vnow
            self.graph.clock = lambda: self.vnow
        if self.tracer is not None:
            self.tracer.clock = ("virtual" if runtime.mode == "sim"
                                 else "wall")
            self._register_gauges()
        # segment marker: epoch/attempt invariants reset here (a restart
        # legitimately re-runs tasks from attempt one), and replay parsers
        # skip it (no "task" key)
        runtime.journal.record_event("session_start", mode=runtime.mode,
                                     slots=runtime.slots)

    @property
    def busy_slots(self) -> int:
        """Slots currently occupied by running tasks (live signal for
        adaptive strategies; reads the drain thread's own accounting)."""
        if self.rt.mode == "sim":
            return self._busy
        return self.rt.slots - self._free["n"]

    # ------------------------------------------------------- observability
    def _now(self) -> float:
        """The run's authoritative clock: virtual now in sim mode, wall
        seconds since the first drain in real mode (0.0 before it)."""
        if self.rt.mode == "sim":
            return self.vnow
        return 0.0 if self._t0 is None else time.perf_counter() - self._t0

    def _register_gauges(self):
        """Built-in gauges over live session state, sampled by the drain
        loops on clock ticks (repro.obs.MetricsTimeline)."""
        m = self.tracer.metrics
        g = self.graph
        m.gauge("frontier_depth", lambda: len(g._in_frontier))
        m.gauge("frontier_slots", g.frontier_slots)
        m.gauge("busy_slots", lambda: self.busy_slots)
        m.gauge("capacity_slots", lambda: self.rt.slots)
        m.gauge("unfinished_tasks", lambda: len(g) - g._n_terminal)
        m.gauge("retries", lambda: self.prof.n_retries)
        m.gauge("preempted", lambda: self.prof.n_preempted)
        staging = getattr(self.rt, "staging", None)
        if staging is not None:
            m.gauge("staging_hit_rate", lambda: staging.planner.hit_rate)

    def _sched_extra(self, t: Task) -> Dict[str, Any]:
        """Observability fields on a ``scheduled`` record: granted slot
        ids (same-slot overlap checking + per-slot trace rows), width,
        owning pipeline, and — on the FIRST attempt only — the dep edges
        the critical-path walk needs (retries keep the original's)."""
        extra = _staged_extra(t)
        ids = t.meta.get("slot_ids")
        if ids:
            extra["slot_ids"] = list(ids)
        if t.slots != 1:
            extra["width"] = t.slots
        if "pipeline" in t.meta:
            extra["pipeline"] = t.meta["pipeline"]
        if t.attempts == 1 and t.deps:
            extra["deps"] = list(t.deps)
        return extra

    # ------------------------------------------------------- dispatch hooks
    # Indirection points the federation layer (repro.federation) overrides
    # to route each task/pod to its owning pilot and to keep per-pilot
    # capacity accounts.  The base session has exactly one pilot, so they
    # all collapse to self.rt / the flat counters.

    def _rt_for(self, t: Task) -> PilotRuntime:
        """Runtime owning ``t``'s current attempt."""
        return self.rt

    def _rt_for_pod(self, pod: str) -> PilotRuntime:
        """Runtime owning pod ``pod`` (federation parses the pilot prefix
        out of the pod name)."""
        return self.rt

    def _occupy(self, t: Task):
        """Charge ``t``'s width to the sim busy account at launch."""
        self._busy += t.slots

    def _vacate(self, t: Task):
        """Return ``t``'s width to the sim busy account."""
        self._busy -= t.slots

    def _can_launch_real(self, t: Task) -> bool:
        """Capacity test for one real-mode launch (federation also binds
        the task to a pilot here)."""
        return t.slots <= self._free["n"]

    def _debit_free(self, t: Task):
        self._free["n"] -= t.slots

    def _credit_free(self, t: Task):
        self._free["n"] += t.slots

    def _credit_free_n(self, rt: PilotRuntime, n: int):
        """Credit ``n`` slots of capacity belonging to ``rt`` (resize,
        pod revival, kill-abandon deltas)."""
        self._free["n"] += n

    def _too_wide_sim(self, t: Task) -> bool:
        """True when no capacity this session will EVER have can host
        ``t`` (the cancel-unsatisfiable rule's width half)."""
        return t.slots > self.rt.slots

    def _too_wide_real(self, t: Task) -> bool:
        return t.slots > self._free["n"]

    def _fault_source(self):
        """Injector consulted by the drain loops (federation: an
        aggregate over every pilot's injector)."""
        return self.rt.faults

    def _housekeeping_sim(self):
        """Per-pass sim housekeeping: strategy hook, pending resizes,
        topology compaction."""
        rt = self.rt
        if rt.on_schedule is not None:
            rt.on_schedule(rt, self.graph, self.vnow)
        rt._apply_resize()
        rt._apply_topology_drop()

    def _housekeeping_real(self):
        rt = self.rt
        if rt.on_schedule is not None:
            rt.on_schedule(rt, self.graph, None)
        self._free["n"] += rt._apply_resize()   # elastic grow/shrink
        rt._apply_topology_drop()

    # ------------------------------------------------------------ submit
    def submit(self, tasks: Union[Task, Iterable[Task]], *,
               dynamic: bool = False) -> List[Task]:
        """Add tasks to the live graph.  Deps must already be in the graph
        (earlier submission or same batch) — incremental submission is
        therefore acyclic by construction.  Tasks recorded DONE in the
        journal are replayed (skipped) and still fire their callback."""
        batch = [tasks] if isinstance(tasks, Task) else list(tasks)
        names = {t.name for t in batch}
        skipped = 0
        for t in batch:
            for d in t.deps:
                if d not in self.graph.tasks and d not in names:
                    raise ValueError(f"{t.name}: unknown dep {d}")
            self.graph.add(t)
            if dynamic:
                self.rt.journal.record(t, "submitted", dynamic=True)
            if self._replay_task(t):
                skipped += 1
                self._queue_callback(t)
        if skipped:
            self.prof.events.append({"event": "journal_skip", "n": skipped})
        return batch

    def _replay_task(self, t: Task) -> bool:
        """Mark ``t`` DONE (with its recorded result) if the journal says
        it already finished; otherwise seed its attempt history from the
        journal's failure records — a run crashed mid-retry resumes with
        its attempt count and pod exclusions, not a fresh budget.  The
        single shared replay rule."""
        if t.name in self._replayed_done and not t.state.terminal:
            t.state = TaskState.DONE
            t.result = self._replayed_results.get(t.name, t.result)
            return True
        self._seed_history(t)
        return False

    def _seed_history(self, t: Task):
        if t.state.terminal or t.attempts or t.history:
            return
        entries = self._replayed_history.get(t.name)
        if not entries:
            return
        t.attempts = max(e["attempt"] for e in entries)
        for e in entries:
            t.history.append({"attempt": e["attempt"],
                              "pod": e.get("pod"), "slot_ids": [],
                              "outcome": e["outcome"]})

    # ------------------------------------------------------------ drain
    def drain(self) -> RuntimeProfile:
        """Run until every submitted task is terminal (callbacks included:
        work they inject is drained too).  Returns the session profile,
        cumulative across drains."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        if self.rt.mode == "sim":
            self._drain_sim()
            self.prof.ttc = self.vnow
        else:
            self._drain_real()
            self.prof.ttc = time.perf_counter() - self._t0
        self.prof.n_tasks = len(self.graph)
        self.prof.n_failed = sum(1 for t in self.graph.tasks.values()
                                 if t.state == TaskState.FAILED)
        self.prof.n_canceled = sum(1 for t in self.graph.tasks.values()
                                   if t.state == TaskState.CANCELED)
        return self.prof

    # ------------------------------------------------------------ staging
    def _locality_candidates(self, avail: int) -> List[Task]:
        """Bounded locality-ordered lookahead (staging pilots only): pop
        at most ``avail`` + headroom ready tasks — nothing at all when
        nothing can fit — and order input-local tasks first.  Shared by
        the sim and real drain loops; the caller launches what fits and
        hands the rest back."""
        graph, rt = self.graph, self.rt
        cands: List[Task] = []
        if avail <= 0:
            return cands
        min_w = graph.frontier_min_width()
        if min_w is None or min_w > avail:
            return cands
        while len(cands) < avail + 16:
            t = graph.pop_ready()
            if t is None:
                break
            cands.append(t)
        cands.sort(key=lambda c: (not rt.staging.prefers(
            c, rt._free_ids), c.tid))
        return cands

    # ------------------------------------------------------------ callbacks
    def _queue_callback(self, t: Task):
        if self.on_task_done is not None and t.speculative_of is None:
            self._cbq.append(t)

    def _flush_callbacks(self):
        while self._cbq:
            self.on_task_done(self._cbq.popleft(), self)

    # ------------------------------------------------------------ failures
    def _pick_victim(self) -> Optional[str]:
        """Deterministic kill-victim choice when the injector names none:
        the busiest live pod (most running attempts; lowest name breaks
        ties), falling back to the first live pod."""
        rt = self.rt
        counts: Dict[str, int] = {}
        if rt.mode == "sim":
            running = (t for _, _, epoch, t in self._heap
                       if t.meta.get("launch_epoch") == epoch
                       and t.state == TaskState.RUNNING)
        else:
            running = (t for _, t in self._live_attempts.values()
                       if t.state == TaskState.RUNNING)
        for t in running:
            tr = self._rt_for(t)
            p = tr._task_pod(t)
            if p is not None and p not in tr.dead_pods:
                counts[p] = counts.get(p, 0) + 1
        if counts:
            return max(sorted(counts), key=lambda p: counts[p])
        live = rt.live_pods()
        return live[0] if live else None

    def _revive_pod(self, pod: str) -> int:
        """A replacement pod joins under the dead pod's slot ids (fresh
        pod: no data replicas — staging dropped them at the kill).
        Returns the capacity gained (real mode credits its free count)."""
        rt, prof = self._rt_for_pod(pod), self.prof
        ids = rt._dead_pod_ids.pop(pod, None)
        if not ids:
            return 0
        rt.dead_pods.discard(pod)
        rt._dead_ids.difference_update(ids)
        if rt._minted is not None:
            rt._minted.update(ids)
        if rt._free_ids is not None:
            rt._free_ids.extend(sorted(ids, reverse=True))
        rt.slots += len(ids)
        if not rt._dead_ids:
            rt._drop_pending = False
        rt.journal.record_event("pod_revived", pod=pod, n_slots=len(ids))
        if self.tracer is not None:
            self.tracer.instant("pod", f"pod_revived:{pod}", self._now(),
                                pod=pod, n_slots=len(ids))
        prof.events.append({"event": "pod_revived", "pod": pod,
                            "n_slots": len(ids), "v": self.vnow})
        return len(ids)

    # ------------------------------------------------------------ sim mode
    def _overhead(self, fn):
        t0 = time.perf_counter()
        out = fn()
        self.prof.t_rts_overhead += time.perf_counter() - t0
        return out

    def _launch_sim(self, t: Task):
        self._occupy(t)
        rt = self._rt_for(t)
        rt._acquire_slots(t)
        # staged-input transfers execute here — between pop_ready and
        # launch — and extend the task's occupancy on the virtual clock
        t_data = rt._stage_in_task(t)
        t.meta["t_data_attempt"] = t_data   # this attempt's staged seconds
        t.attempts += 1
        t.error = None                 # a retry must not inherit the
        t.state = TaskState.RUNNING    # previous attempt's error
        t.t_scheduled = time.perf_counter()
        t.v_started = self.vnow
        t.meta["launch_epoch"] = t.attempts
        v_ready = t.meta.pop("v_ready", None)    # retry re-stamps afresh
        pod = rt._task_pod(t)
        journal = rt.journal
        if journal._fh is not None or journal.observer is not None:
            extra = self._sched_extra(t)
            if t_data:
                extra["t_data"] = t_data    # planned stage-in seconds
            if v_ready is not None:
                extra["v_ready"] = v_ready
            journal.record(t, "scheduled", pod=pod, **extra)
        if self.tracer is not None:
            self.tracer.task_begin(t, self.vnow, pod, t_data)
        heapq.heappush(self._heap,
                       (self.vnow + max(t.duration, 0.0) + t_data,
                        self._seq, t.attempts, t))
        self._seq += 1

    def _schedule_sim(self):
        rt, graph = self.rt, self.graph
        if rt.preempt:
            # high-priority head of line first: with the pilot saturated
            # by throughput work, the locality pass below would not even
            # pop a latency task (avail == 0)
            self._preempt_pass_sim()
        if rt.staging is not None:
            # locality-ordered pass: tasks whose staged inputs already
            # have a replica in a free pod run first (they link instead
            # of copy); head-of-line holds within the locality order
            # (stop at the first candidate that does not fit, same as
            # the seed)
            cands = self._locality_candidates(rt.slots - self._busy)
            for i, t in enumerate(cands):
                if rt.slots - self._busy >= t.slots:
                    self._launch_sim(t)
                else:
                    for c in cands[i:]:
                        graph.requeue(c)
                    break
            return
        while True:
            t = graph.pop_ready()          # incremental frontier, tid order
            if t is None:
                break
            if rt.slots - self._busy < t.slots:
                graph.requeue(t)           # same head-of-line rule as seed
                break
            self._launch_sim(t)

    def _finish_sim(self, t: Task):
        rt, graph, prof = self._rt_for(t), self.graph, self.prof
        t.record_attempt("done", pod=rt._task_pod(t))
        t.state = TaskState.DONE
        t.v_finished = self.vnow
        t.t_finished = time.perf_counter()
        prof.t_exec += t.duration
        prof.t_data += t.t_data
        prof.slot_busy += t.duration * t.slots
        self._durations.setdefault(t.stage, []).append(t.duration)
        # timing fields feed the sanitizer's S306 disjointness check: on
        # the virtual clock, the attempt's interval is EXACTLY its exec
        # time plus its staged-transfer time
        rt.journal.record(t, "finished", t_exec=max(t.duration, 0.0),
                          t_data=t.meta.get("t_data_attempt", 0.0),
                          v_started=t.v_started, v_finished=t.v_finished)
        if self.tracer is not None:
            self.tracer.task_end(t, self.vnow, "done")
        rt._staging_finish(t)
        if t.speculative_of:
            # the duplicate won: complete the straggling original
            # and kill it (freeing its slot now, if it held one — a
            # pod-lost original may be back in the frontier as NEW)
            orig = graph.tasks.get(t.speculative_of)
            if orig is not None and not orig.state.terminal:
                ort = self._rt_for(orig)
                was_running = orig.state == TaskState.RUNNING
                orig.record_attempt("superseded", pod=ort._task_pod(orig))
                orig.state = TaskState.DONE
                orig.v_finished = self.vnow
                if was_running:
                    orig.meta["slot_freed"] = True
                    self._vacate(orig)
                    ort._release_slots(orig)
                orig.meta["launch_epoch"] = None
                ort.journal.record(orig, "finished", by="speculative")
                if self.tracer is not None and was_running:
                    self.tracer.task_end(orig, self.vnow, "superseded")
                ort._staging_finish(orig)
                self._queue_callback(orig)
            self._spec_launched.pop(t.speculative_of, None)
        else:
            # original won: cancel its twin if any.  The twin's slot and
            # busy-count return at its heap pop; its journal record,
            # staged-input holds and t_data charge settle HERE — a
            # canceled clone still moved data
            twin = self._spec_launched.pop(t.name, None)
            if twin is not None and not twin.state.terminal:
                trt = self._rt_for(twin)
                twin.record_attempt("canceled", pod=trt._task_pod(twin))
                twin.state = TaskState.CANCELED
                trt.journal.record(twin, "canceled", by="original")
                if self.tracer is not None:
                    self.tracer.task_end(twin, self.vnow, "canceled")
                trt._staging_finish(twin)
                prof.t_data += twin.t_data
            self._queue_callback(t)

    def _apply_faults_sim(self):
        for kind, pod in self._fault_source().pop_due(self.vnow):
            if kind == REVIVE:
                self._revive_pod(pod)
            else:
                victim = pod if pod is not None else self._pick_victim()
                if victim is None \
                        or victim in self._rt_for_pod(victim).dead_pods:
                    continue
                self._kill_pod_sim(victim)

    def _kill_pod_sim(self, pod: str):
        rt, prof = self._rt_for_pod(pod), self.prof
        ids = rt._pod_ids(pod)
        if not ids:
            return
        idset = set(ids)
        rt._retire_ids(ids, pod)
        rt.slots = max(rt.slots - len(ids), 0)
        # slot ids are pilot-local integers, so the victim scan must also
        # match the owning runtime — id 3 on another pilot is a bystander
        victims = [t for _, _, epoch, t in self._heap
                   if t.meta.get("launch_epoch") == epoch
                   and t.state == TaskState.RUNNING
                   and self._rt_for(t) is rt
                   and idset.intersection(t.meta.get("slot_ids", ()))]
        for t in victims:
            self._abandon_sim(t, pod)
        if rt.staging is not None:
            rt.staging.on_pod_lost(pod)
        rt.journal.record_event("pod_lost", pod=pod, n_slots=len(ids),
                                v=self.vnow)
        if self.tracer is not None:
            self.tracer.instant("pod", f"pod_lost:{pod}", self.vnow,
                                pod=pod, n_slots=len(ids))
        prof.events.append({"event": "pod_lost", "pod": pod,
                            "n_slots": len(ids), "v": self.vnow})
        if rt.faults is not None and rt.faults.respawn_after is not None:
            rt.faults.schedule_revive(pod, self.vnow)
        elif rt.topology is not None:
            rt._drop_pending = True

    def _abandon_sim(self, t: Task, pod: str):
        """Fail one in-flight sim attempt on a dead pod: invalidate its
        launch epoch (the heap entry becomes a no-op), free its capacity,
        record the attempt against the pod, and retry or fail."""
        rt, prof = self._rt_for(t), self.prof
        t.meta["launch_epoch"] = None
        self._vacate(t)
        rt._release_slots(t)
        err = f"pod_lost: pod {pod} died at v={self.vnow:g}"
        t.record_attempt("pod_lost", pod=pod, error=err)
        t.error = err
        prof.n_pod_lost += 1
        rt.journal.record(t, "pod_lost", pod=pod)
        if self.tracer is not None:       # truncated span, never an overlap
            self.tracer.task_end(t, self.vnow, "pod_lost")
        if t.speculative_of is not None:
            # a clone needs no retry — the original is still running
            t.state = TaskState.CANCELED
            rt.journal.record(t, "canceled", by="pod_lost")
            rt._staging_finish(t)
            prof.t_data += t.t_data
            self._spec_launched.pop(t.speculative_of, None)
            return
        t.meta.pop("slot_ids", None)
        t.meta.pop("slots_released", None)
        if t.attempts <= rt.max_retries:
            t.state = TaskState.NEW     # re-enters the frontier; the next
            prof.n_retries += 1         # grant excludes this pod
        else:
            t.state = TaskState.FAILED
            t.v_finished = self.vnow
            rt.journal.record(t, "failed", pod=pod)
            rt._staging_finish(t)
            prof.t_data += t.t_data
            self._queue_callback(t)

    # ------------------------------------------------------- preemption
    # A ready high-priority task (serving's `latency` SLA class) that
    # cannot fit may evict running lower-priority idempotent attempts.
    # Eviction IS the abandon path: invalidate the launch epoch (the
    # in-flight completion becomes an inert zombie), free capacity,
    # record the attempt, requeue as NEW.  Unlike a pod failure it never
    # blames the pod (excluded_pods ignores "preempted") and never
    # consumes retry budget — a throughput task preempted N times still
    # has its full max_retries for real failures.

    def _preempt_enabled(self, t: Task) -> bool:
        """Gate for one preemption attempt on behalf of ready task ``t``
        (federation overrides: per-pilot capacity accounts need their own
        victim arithmetic)."""
        return self.rt.preempt and t.priority > 0

    def _preempt_victims(self, t: Task, need: int,
                         running) -> Optional[List[Task]]:
        """Pick victims freeing >= ``need`` slots for ``t``: strictly
        lower priority, idempotent, not speculation-involved.  Least
        work lost first (latest v_started).  None when the eligible pool
        cannot cover the deficit — then nothing is evicted."""
        cands = [v for v in running
                 if (v.priority < t.priority and v.idempotent
                     and v.speculative_of is None
                     and v.name not in self._spec_launched)]
        cands.sort(key=lambda v: (v.priority, -v.v_started, v.tid))
        chosen, freed = [], 0
        for v in cands:
            chosen.append(v)
            freed += v.slots
            if freed >= need:
                return chosen
        return None

    def _sim_running_tasks(self) -> List[Task]:
        return [v for _, _, epoch, v in self._heap
                if v.meta.get("launch_epoch") == epoch
                and v.state == TaskState.RUNNING]

    def _preempt_sim_for(self, t: Task) -> bool:
        """Free enough sim capacity for ``t`` by eviction; True when
        ``t`` fits afterwards (possibly without evicting anything)."""
        need = t.slots - (self.rt.slots - self._busy)
        if need <= 0:
            return True
        victims = self._preempt_victims(t, need, self._sim_running_tasks())
        if victims is None:
            return False
        for v in victims:
            self._preempt_sim(v)
        return True

    def _preempt_sim(self, v: Task):
        """Evict one running sim attempt (mirror of :meth:`_abandon_sim`
        minus the failure semantics)."""
        rt, prof = self._rt_for(v), self.prof
        v.meta["launch_epoch"] = None
        self._vacate(v)
        rt._release_slots(v)
        v.record_attempt("preempted", pod=rt._task_pod(v))
        prof.n_preempted += 1
        rt.journal.record(v, "preempted", pod=rt._task_pod(v))
        if self.tracer is not None:
            self.tracer.task_end(v, self.vnow, "preempted")
        v.meta.pop("slot_ids", None)
        v.meta.pop("slots_released", None)
        v.error = None
        v.state = TaskState.NEW        # always requeues: not a failure

    def _preempt_pass_sim(self):
        """Launch ready high-priority tasks, evicting for the ones that
        do not fit; runs before the normal scheduling pass so a latency
        task never waits behind a full pilot of throughput work."""
        graph = self.graph
        while True:
            t = graph.pop_ready()      # priority order: head is hottest
            if t is None:
                return
            if not self._preempt_enabled(t):
                graph.requeue(t)
                return
            if self._preempt_sim_for(t):
                self._launch_sim(t)
                continue
            graph.requeue(t)           # nothing evictable: wait in line
            return

    def _drain_sim(self):
        rt, graph, prof = self.rt, self.graph, self.prof
        # hoisted: one bound method, not two attribute hops per event
        _sample = (self.tracer.metrics.maybe_sample
                   if self.tracer is not None else None)
        _sampled_at = None
        while True:
            self._flush_callbacks()
            if _sample is not None and self.vnow != _sampled_at:
                _sampled_at = self.vnow
                _sample(_sampled_at)
            self._housekeeping_sim()
            self._overhead(self._schedule_sim)

            # fault events due before the next completion preempt it: a
            # pod death invalidates in-flight attempts, so their
            # completions must not be delivered first.  With an empty
            # heap, kills already due fire in place, and a pending
            # replacement pod advances the clock to its arrival (tasks
            # starved by the shrink wait for it instead of canceling).
            faults = self._fault_source()
            if faults is not None:
                nf = faults.next_time()
                if nf is not None and (
                        (self._heap and nf <= self._heap[0][0])
                        or (not self._heap
                            and (nf <= self.vnow
                                 or (faults.pending_revive()
                                     and not graph.done())))):
                    self.vnow = max(self.vnow, nf)
                    self._overhead(self._apply_faults_sim)
                    continue

            if not self._heap:
                if graph.done():
                    break
                # nothing runnable: cancel only truly unsatisfiable tasks
                # (failed/canceled upstream, or wider than the whole pilot)
                # so a narrow task queued behind a too-wide one still runs
                # on the next pass — same rule as real mode.  A pending
                # pod respawn defers the too-wide rule: capacity returns.
                reviving = faults is not None and faults.pending_revive()
                canceled = False
                for t in graph.tasks.values():
                    if t.state == TaskState.NEW and (
                            (self._too_wide_sim(t) and not reviving) or any(
                                graph.tasks[d].state.terminal
                                and graph.tasks[d].state != TaskState.DONE
                                for d in t.deps)):
                        tr = self._rt_for(t)
                        t.state = TaskState.CANCELED
                        tr.journal.record(t, "canceled")
                        tr._staging_finish(t)
                        self._queue_callback(t)
                        canceled = True
                if not canceled and not reviving:
                    # termination guard (unreachable by construction: a
                    # stuck NEW task always matches one rule above)
                    for t in graph.tasks.values():
                        if t.state == TaskState.NEW:
                            tr = self._rt_for(t)
                            t.state = TaskState.CANCELED
                            tr.journal.record(t, "canceled")
                            tr._staging_finish(t)
                            self._queue_callback(t)
                self._flush_callbacks()
                if graph.done():
                    break
                continue

            vfin, _, epoch, t = heapq.heappop(self._heap)
            if t.meta.get("launch_epoch") != epoch:
                # abandoned attempt (pod loss) or superseded original:
                # capacity and slots were settled at abandonment — the
                # entry is a zombie
                continue
            if t.state.terminal:
                # canceled twin: slot returns here; do NOT advance the
                # clock to its stale finish time
                if not t.meta.get("slot_freed"):
                    self._vacate(t)
                self._rt_for(t)._release_slots(t)
                continue
            self.vnow = max(self.vnow, vfin)
            self._vacate(t)
            self._rt_for(t)._release_slots(t)
            self._overhead(lambda: self._finish_sim(t))

            # straggler speculation: clone still-running outliers
            if rt.straggler_factor:
                self._overhead(self._speculate_sim)

    def _speculate_sim(self):
        rt, prof = self.rt, self.prof
        for vfin, sq, epoch, t in list(self._heap):
            if t.meta.get("launch_epoch") != epoch:
                continue
            rt = self._rt_for(t)
            hist = self._durations.get(t.stage, [])
            if (t.idempotent and not t.state.terminal
                    and t.speculative_of is None
                    and t.name not in self._spec_launched
                    and rt.slots - self._busy >= t.slots
                    and len(hist) >= rt.min_straggler_samples):
                med = statistics.median(hist)
                # the monitor fires when elapsed > factor * median; in DES
                # that trigger time is known, so schedule the duplicate to
                # start exactly then (if the original would still be running)
                trigger = t.v_started + rt.straggler_factor * med
                if trigger < vfin:
                    dup = Task(name=t.name + f".spec{t.attempts}",
                               duration=med, slots=t.slots, stage=t.stage,
                               instance=t.instance, iteration=t.iteration,
                               speculative_of=t.name)
                    dup.state = TaskState.RUNNING
                    dup.v_started = max(self.vnow, trigger)
                    dup.attempts = 1
                    dup.meta["launch_epoch"] = 1
                    if "pilot" in t.meta:      # clone runs on the same pilot
                        dup.meta["pilot"] = t.meta["pilot"]
                    prof.n_speculative += 1
                    self._occupy(dup)
                    # the clone reads the SAME staged inputs as the
                    # original: share the manifest (extra holds on the
                    # same blobs) so its transfers plan and charge t_data
                    # exactly like the original's
                    if rt.staging is not None:
                        rt.staging.clone_manifest(t, dup)
                    rt._acquire_slots(dup)
                    t_data = rt._stage_in_task(dup)
                    dup.meta["t_data_attempt"] = t_data
                    heapq.heappush(
                        self._heap,
                        (dup.v_started + med + t_data,
                         self._seq, dup.attempts, dup))
                    self._seq += 1
                    extra = self._sched_extra(dup)
                    if t_data:
                        extra["t_data"] = t_data
                    pod = rt._task_pod(dup)
                    rt.journal.record(dup, "scheduled", speculative=True,
                                      pod=pod, **extra)
                    if self.tracer is not None:
                        self.tracer.task_begin(dup, dup.v_started,
                                               pod=pod, t_data=t_data)
                    self._spec_launched[t.name] = dup

    # ------------------------------------------------------------ real mode
    def _check_faults_real(self):
        """Real-mode failure scan, run each pass of the drain loop: fire
        due injector events (elapsed wall clock), then detect dead worker
        threads — a thread that exited without running its completion
        bookkeeping (e.g. SystemExit through the isolation boundary) —
        and, with a detector configured, stale heartbeats."""
        now = time.perf_counter()
        elapsed = now - self._t0
        faults = self._fault_source()
        if faults is not None:
            for kind, pod in faults.pop_due(elapsed):
                if kind == REVIVE:
                    self._credit_free_n(self._rt_for_pod(pod),
                                        self._revive_pod(pod))
                else:
                    victim = pod if pod is not None else self._pick_victim()
                    if victim is not None and victim \
                            not in self._rt_for_pod(victim).dead_pods:
                        self._kill_pod_real(victim, elapsed)
        for (name, epoch), (th, t) in list(self._live_attempts.items()):
            if t.meta.get("launch_epoch") != epoch \
                    or t.state != TaskState.RUNNING:
                continue
            tr = self._rt_for(t)
            if not th.is_alive():
                self._abandon_real(t, tr._task_pod(t), "worker_died",
                                   credit_slots=True)
            elif tr.detector is not None and tr.detector.stale(t, now):
                self._abandon_real(t, tr._task_pod(t), "heartbeat_timeout",
                                   credit_slots=True)

    def _kill_pod_real(self, pod: str, elapsed: float):
        rt, prof = self._rt_for_pod(pod), self.prof
        ids = rt._pod_ids(pod)
        if not ids:
            return
        idset = set(ids)
        rt._retire_ids(ids, pod)
        abandoned_w = 0
        for (name, epoch), (th, t) in list(self._live_attempts.items()):
            if t.meta.get("launch_epoch") == epoch \
                    and self._rt_for(t) is rt \
                    and idset.intersection(t.meta.get("slot_ids", ())):
                abandoned_w += t.slots
                self._abandon_real(t, pod, "pod_lost", credit_slots=False)
        rt.slots = max(rt.slots - len(ids), 0)
        # the pod's free slots leave capacity; abandoned widths return
        # (their surviving ids re-entered the id pool at release)
        self._credit_free_n(rt, abandoned_w - len(ids))
        if rt.staging is not None:
            rt.staging.on_pod_lost(pod)
        rt.journal.record_event("pod_lost", pod=pod, n_slots=len(ids))
        if self.tracer is not None:
            self.tracer.instant("pod", f"pod_lost:{pod}", elapsed,
                                pod=pod, n_slots=len(ids))
        prof.events.append({"event": "pod_lost", "pod": pod,
                            "n_slots": len(ids), "elapsed": elapsed})
        if rt.faults is not None and rt.faults.respawn_after is not None:
            rt.faults.schedule_revive(pod, elapsed)
        elif rt.topology is not None:
            rt._drop_pending = True

    def _abandon_real(self, t: Task, pod: Optional[str], reason: str, *,
                      credit_slots: bool):
        """Fail one in-flight real attempt (pod kill, dead worker thread,
        stale heartbeat).  The worker thread cannot be stopped; popping
        the live-attempt entry turns its eventual completion into a
        zombie that skips all bookkeeping."""
        rt, prof = self._rt_for(t), self.prof
        entry = self._live_attempts.pop((t.name, t.meta.get("launch_epoch")),
                                        None)
        if entry is not None:
            self._zombie_threads.add(entry[0])
        t.meta["launch_epoch"] = None
        self._inflight -= 1
        if credit_slots:
            self._credit_free(t)
        rt._release_slots(t)
        err = f"{reason}" + (f": pod {pod}" if pod else "")
        t.record_attempt(reason, pod=pod, error=err)
        t.error = err
        prof.n_pod_lost += 1
        rt.journal.record(t, reason, pod=pod)
        if self.tracer is not None:
            self.tracer.task_end(t, self._now(), reason)
        t.meta.pop("slot_ids", None)
        t.meta.pop("slots_released", None)
        if t.attempts <= rt.max_retries:
            t.state = TaskState.NEW
            prof.n_retries += 1
        else:
            t.state = TaskState.FAILED
            rt.journal.record(t, "failed", pod=pod)
            prof.t_data += t.t_data
            rt._staging_finish(t)
            self._queue_callback(t)

    def _preempt_real_for(self, t: Task) -> bool:
        """Real-mode eviction on behalf of ready ``t`` (caller holds the
        session cv).  The victim's worker thread cannot be stopped:
        popping its live-attempt entry turns the eventual completion into
        a zombie, exactly as the failure paths do."""
        need = t.slots - self._free["n"]
        if need <= 0:
            return True
        running = [v for (_, epoch), (_th, v) in self._live_attempts.items()
                   if v.meta.get("launch_epoch") == epoch
                   and v.state == TaskState.RUNNING]
        victims = self._preempt_victims(t, need, running)
        if victims is None:
            return False
        for v in victims:
            self._preempt_real(v)
        return True

    def _preempt_real(self, v: Task):
        """Evict one running real attempt (mirror of :meth:`_abandon_real`
        minus the failure semantics)."""
        rt, prof = self._rt_for(v), self.prof
        entry = self._live_attempts.pop((v.name, v.meta.get("launch_epoch")),
                                        None)
        if entry is not None:
            self._zombie_threads.add(entry[0])
        v.meta["launch_epoch"] = None
        self._inflight -= 1
        self._credit_free(v)
        rt._release_slots(v)
        v.record_attempt("preempted", pod=rt._task_pod(v))
        prof.n_preempted += 1
        rt.journal.record(v, "preempted", pod=rt._task_pod(v))
        if self.tracer is not None:
            self.tracer.task_end(v, self._now(), "preempted")
        v.meta.pop("slot_ids", None)
        v.meta.pop("slots_released", None)
        v.error = None
        v.state = TaskState.NEW        # always requeues: not a failure

    def _execute_real(self, t: Task):
        rt, prof, cv = self._rt_for(t), self.prof, self._cv
        epoch = t.meta.get("launch_epoch")
        t.t_started = time.perf_counter()
        outcome = TaskState.DONE
        t.meta.pop("t_data_kernel", None)     # fresh window per attempt
        if rt.detector is not None:
            rt.detector.beat(t)
        res = None
        try:
            # staged-input transfers: between pop_ready and kernel launch,
            # on the worker (transfers overlap across tasks); the restamp
            # keeps t_exec and t_data disjoint in the TTC decomposition
            t.meta["t_data_attempt"] = rt._stage_in_task(t)
            t.t_started = time.perf_counter()
            if t.run is not None:
                # held locally until past the zombie check below: an
                # abandoned attempt's late return must not clobber the
                # retry's result
                res = t.run(t)
            elif t.duration:
                time.sleep(t.duration)
        except Exception as e:  # noqa: BLE001 - task isolation boundary
            t.error = f"{type(e).__name__}: {e}\n" \
                      + traceback.format_exc()[-1500:]
            outcome = (TaskState.NEW if t.attempts <= rt.max_retries
                       else TaskState.FAILED)
        t.t_finished = time.perf_counter()
        with cv:
            # the state transition happens INSIDE the lock: flipping a
            # retry to NEW any earlier lets the drain thread reschedule it
            # (and re-grant slot ids) before this attempt's bookkeeping
            # releases the old ones
            if self._live_attempts.pop((t.name, epoch), None) is None:
                # abandoned while running (pod kill / stale heartbeat):
                # the abandonment already settled slots, capacity and
                # history — this completion is a zombie
                cv.notify_all()
                return
            pod = rt._task_pod(t)
            if t.run is not None and outcome == TaskState.DONE:
                t.result = res
            self._credit_free(t)
            rt._release_slots(t)
            # in-kernel lazy derefs (ctx["staging"].get) charged to t_data
            # come OUT of the exec window — the decomposition terms must
            # not overlap
            span = max(t.t_finished - t.t_started
                       - t.meta.get("t_data_kernel", 0.0), 0.0)
            prof.t_exec += span
            prof.slot_busy += span * t.slots
            t.record_attempt("done" if outcome == TaskState.DONE
                             else "failed", pod=pod, error=t.error)
            t.state = outcome
            if outcome == TaskState.NEW:
                prof.n_retries += 1
                t.meta.pop("slot_ids", None)
                t.meta.pop("slots_released", None)
            # wall/t_exec/t_data_kernel feed the sanitizer's S306 check:
            # in-kernel deref seconds must come OUT of the exec window
            rt.journal.record(
                t, "finished" if t.state == TaskState.DONE else "failed",
                pod=pod, t_exec=span,
                t_data=t.meta.get("t_data_attempt", 0.0),
                t_data_kernel=t.meta.get("t_data_kernel", 0.0),
                wall=max(t.t_finished - t.t_started, 0.0))
            if self.tracer is not None:
                self.tracer.task_end(
                    t, self._now(),
                    "done" if t.state == TaskState.DONE else "failed")
            if t.state.terminal:
                # cumulative across attempts, charged once at the end
                prof.t_data += t.t_data
                rt._staging_finish(t)
                self._queue_callback(t)
            self._inflight -= 1
            cv.notify_all()

    def _drain_real(self):
        # thread-per-task: slot gating already bounds concurrency, and a
        # fixed pool would cap an elastic grow mid-run
        workers: List[threading.Thread] = []
        try:
            self._drain_real_loop(workers)
        finally:
            # join even when a user on_done callback raised, so no worker
            # is left mutating the profile/journal after drain() returns.
            # Abandoned (zombie) threads may be stuck in a hung kernel:
            # they get a bounded join — their completion path is inert
            # (the live-attempt pop already failed), so leaking the
            # daemon thread is safe
            for th in workers:
                if th in self._zombie_threads:
                    th.join(timeout=0.2)
                else:
                    th.join()

    def _launch_real(self, t: Task, workers: List[threading.Thread]):
        """Start one real-mode attempt (capacity already reserved via
        :meth:`_can_launch_real`)."""
        rt, graph = self._rt_for(t), self.graph
        self._debit_free(t)
        rt._acquire_slots(t)
        t.meta["dep_results"] = {
            d: graph.tasks[d].result for d in t.deps}
        t.attempts += 1
        t.error = None         # no stale error into a retry
        t.state = TaskState.RUNNING
        t.t_scheduled = time.perf_counter()
        t.meta["launch_epoch"] = t.attempts
        pod = rt._task_pod(t)
        rt.journal.record(t, "scheduled", pod=pod, **self._sched_extra(t))
        if self.tracer is not None:
            self.tracer.task_begin(t, self._now(), pod=pod)
        self._inflight += 1
        th = threading.Thread(target=self._execute_real,
                              args=(t,), daemon=True)
        self._live_attempts[(t.name, t.attempts)] = (th, t)
        workers.append(th)
        th.start()

    def _drain_real_loop(self, workers: List[threading.Thread]):
        rt, graph, prof = self.rt, self.graph, self.prof
        cv = self._cv
        _sample = (self.tracer.metrics.maybe_sample
                   if self.tracer is not None else None)
        with cv:
            while True:
                self._flush_callbacks()
                if _sample is not None:
                    _sample(self._now())
                self._housekeeping_real()
                self._check_faults_real()
                t0 = time.perf_counter()
                # pop from the incremental frontier, re-checking capacity
                # per task; too-wide tasks are skipped (narrower ones behind
                # them may fit) and requeued after the pass.  The min-width
                # check ends the pass as soon as NOTHING left can fit —
                # without it a nearly-full pilot would drain the whole
                # frontier into `skipped` on every wakeup (O(n) per event)
                scheduled, skipped = [], []
                cands = None
                if rt.staging is not None:
                    # locality-ordered pass: input-local tasks claim free
                    # pods before tasks that would have to copy (too-wide
                    # candidates are skipped, as in the default pass)
                    cands = self._locality_candidates(self._free["n"])
                    cands.reverse()        # consumed via pop() below
                while True:
                    if cands is not None:
                        t = cands.pop() if cands else None
                    else:
                        min_w = graph.frontier_min_width()
                        if min_w is None or min_w > self._free["n"]:
                            t = None
                        else:
                            t = graph.pop_ready()
                    if t is None and getattr(rt, "preempt", False):
                        # the width/locality early-exit must not hide a
                        # ready high-priority task wider than the free
                        # slots — that is exactly the case eviction
                        # (PilotRuntime(preempt=True)) exists for
                        t = graph.pop_ready()
                        if t is not None and not self._preempt_enabled(t):
                            graph.requeue(t)
                            t = None
                    if t is None:
                        break
                    if not self._can_launch_real(t):
                        if self._preempt_enabled(t) \
                                and self._preempt_real_for(t) \
                                and self._can_launch_real(t):
                            scheduled.append(t)
                            self._launch_real(t, workers)
                            continue
                        skipped.append(t)
                        continue
                    scheduled.append(t)
                    self._launch_real(t, workers)
                for t in skipped:
                    graph.requeue(t)
                prof.t_rts_overhead += time.perf_counter() - t0
                quiescent = not self._inflight and not self._cbq
                if graph.done() and quiescent:
                    break
                if not scheduled and quiescent:
                    # nothing runnable: cancel unsatisfiable tasks — failed
                    # upstream deps, or wider than the whole idle pilot
                    # (nothing in flight, so free == capacity: such a task
                    # can never start and would spin this loop forever).
                    # A pending pod respawn defers the too-wide rule:
                    # the capacity is coming back.
                    faults = self._fault_source()
                    reviving = (faults is not None
                                and faults.pending_revive())
                    for t in graph.tasks.values():
                        if t.state != TaskState.NEW:
                            continue
                        if (self._too_wide_real(t) and not reviving) \
                                or any(
                                graph.tasks[d].state.terminal
                                and graph.tasks[d].state != TaskState.DONE
                                for d in t.deps):
                            tr = self._rt_for(t)
                            t.state = TaskState.CANCELED
                            tr.journal.record(t, "canceled")
                            tr._staging_finish(t)
                            self._queue_callback(t)
                    if graph.done() and not self._cbq:
                        break
                    # retried tasks (back to NEW) reschedule next pass
                if not self._cbq:
                    cv.wait(timeout=0.05)
