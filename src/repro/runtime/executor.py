"""Pilot runtime executor: application-level scheduling of tasks onto the
pilot's slots (the RADICAL-Pilot analogue).

Two modes:
  real - tasks execute their callables on a slot thread pool (JAX work
         serializes on the device; orchestration concurrency is real).
  sim  - discrete-event simulation: task ``duration`` advances a virtual
         clock.  Scheduler/bookkeeping overheads are still measured on the
         real clock — this is how the Fig.7-10 scaling benches reproduce the
         paper's overhead measurements at 2560 tasks without hours of
         wall-clock sleep.

Fault tolerance: bounded retries with backoff; straggler mitigation via
speculative duplicates (sim+real); elastic pilot resize mid-run; journal for
restart.
"""
from __future__ import annotations

import heapq
import statistics
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState


@dataclass
class RuntimeProfile:
    """TTC decomposition (paper eq. 1-2)."""
    ttc: float = 0.0                   # makespan (virtual in sim mode)
    t_exec: float = 0.0                # sum of task execution times
    t_data: float = 0.0                # upload/download time
    t_rts_overhead: float = 0.0        # scheduling/dispatch (T_RP analogue)
    n_tasks: int = 0
    n_failed: int = 0
    n_retries: int = 0
    n_speculative: int = 0
    slot_busy: float = 0.0             # aggregate busy slot-seconds
    events: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.slot_busy / max(self.ttc, 1e-12)


class PilotRuntime:
    def __init__(self, slots: int, *, mode: str = "real",
                 journal: Optional[Journal] = None,
                 max_retries: int = 2,
                 straggler_factor: float = 0.0,
                 min_straggler_samples: int = 5):
        assert mode in ("real", "sim")
        self.slots = slots
        self.mode = mode
        self.journal = journal or Journal(None)
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.min_straggler_samples = min_straggler_samples
        self._resize_to: Optional[int] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------ elastic
    def resize(self, slots: int):
        """Elastic pilot resize; takes effect at the next scheduling step."""
        with self._lock:
            self._resize_to = slots

    def _apply_resize(self):
        with self._lock:
            if self._resize_to is not None:
                self.slots = self._resize_to
                self._resize_to = None

    # ------------------------------------------------------------ run
    def run(self, graph: TaskGraph) -> RuntimeProfile:
        graph.validate()
        skipped = self.journal.replay(graph)
        prof = RuntimeProfile()
        if skipped:
            prof.events.append({"event": "journal_skip", "n": skipped})
        if self.mode == "sim":
            self._run_sim(graph, prof)
        else:
            self._run_real(graph, prof)
        prof.n_tasks = len(graph)
        prof.n_failed = sum(1 for t in graph.tasks.values()
                            if t.state == TaskState.FAILED)
        return prof

    # ------------------------------------------------------------ sim mode
    def _run_sim(self, graph: TaskGraph, prof: RuntimeProfile):
        vnow = 0.0
        busy = 0
        running: List = []            # heap of (v_finish, seq, task)
        seq = 0
        durations: Dict[str, List[float]] = {}
        spec_launched: Dict[str, Task] = {}

        def overhead(fn):
            t0 = time.perf_counter()
            out = fn()
            prof.t_rts_overhead += time.perf_counter() - t0
            return out

        while not graph.done() or running:
            self._apply_resize()

            def schedule():
                nonlocal busy, seq
                ready = sorted(graph.ready(), key=lambda t: t.tid)
                for t in ready:
                    if self.slots - busy < t.slots:
                        break
                    busy += t.slots
                    t.attempts += 1
                    t.state = TaskState.RUNNING
                    t.t_scheduled = time.perf_counter()
                    t.v_started = vnow
                    self.journal.record(t, "scheduled")
                    heapq.heappush(running, (vnow + max(t.duration, 0.0),
                                             seq, t))
                    seq += 1
            overhead(schedule)

            if not running:
                if graph.done():
                    break
                # deadlock: unsatisfiable deps (failed upstream)
                for t in graph.tasks.values():
                    if t.state == TaskState.NEW:
                        t.state = TaskState.CANCELED
                        self.journal.record(t, "canceled")
                break

            vfin, _, t = heapq.heappop(running)
            if t.state.terminal:
                # canceled twin / original superseded by its speculative
                # duplicate: slot already freed at supersession; do NOT
                # advance the clock to its stale finish time
                if not t.meta.get("slot_freed"):
                    busy -= t.slots
                continue
            vnow = max(vnow, vfin)
            busy -= t.slots

            def finish():
                nonlocal busy
                t.state = TaskState.DONE
                t.v_finished = vnow
                t.t_finished = time.perf_counter()
                prof.t_exec += t.duration
                prof.slot_busy += t.duration * t.slots
                durations.setdefault(t.stage, []).append(t.duration)
                self.journal.record(t, "finished")
                if t.speculative_of:
                    # the duplicate won: complete the straggling original
                    # and kill it (freeing its slot now)
                    orig = graph.tasks.get(t.speculative_of)
                    if orig is not None and not orig.state.terminal:
                        orig.state = TaskState.DONE
                        orig.v_finished = vnow
                        orig.meta["slot_freed"] = True
                        busy -= orig.slots
                        self.journal.record(orig, "finished",
                                            by="speculative")
                    spec_launched.pop(t.speculative_of, None)
                else:
                    # original won: cancel its twin if any
                    twin = spec_launched.pop(t.name, None)
                    if twin is not None and not twin.state.terminal:
                        twin.state = TaskState.CANCELED
            overhead(finish)

            # straggler speculation: clone still-running outliers
            if self.straggler_factor:
                def spec():
                    nonlocal busy
                    busy = self._speculate_sim(
                        graph, running, durations, spec_launched, vnow,
                        prof, busy)
                overhead(spec)
        prof.ttc = vnow

    def _speculate_sim(self, graph, running, durations, spec_launched,
                       vnow, prof, busy):
        for vfin, sq, t in list(running):
            hist = durations.get(t.stage, [])
            if (t.idempotent and not t.state.terminal
                    and t.speculative_of is None
                    and t.name not in spec_launched
                    and self.slots - busy >= t.slots
                    and len(hist) >= self.min_straggler_samples):
                med = statistics.median(hist)
                # the monitor fires when elapsed > factor * median; in DES
                # that trigger time is known, so schedule the duplicate to
                # start exactly then (if the original would still be running)
                trigger = t.v_started + self.straggler_factor * med
                if trigger < vfin:
                    dup = Task(name=t.name + f".spec{t.attempts}",
                               duration=med, slots=t.slots, stage=t.stage,
                               instance=t.instance, iteration=t.iteration,
                               speculative_of=t.name)
                    dup.state = TaskState.RUNNING
                    dup.v_started = max(vnow, trigger)
                    prof.n_speculative += 1
                    busy += t.slots
                    heapq.heappush(
                        running, (max(vnow, trigger) + med, id(dup), dup))
                    spec_launched[t.name] = dup
        return busy

    # ------------------------------------------------------------ real mode
    def _run_real(self, graph: TaskGraph, prof: RuntimeProfile):
        t_start = time.perf_counter()
        lock = threading.Lock()
        cv = threading.Condition(lock)
        free = {"n": self.slots}
        pool = ThreadPoolExecutor(max_workers=max(self.slots, 1))

        def execute(t: Task):
            t.t_started = time.perf_counter()
            try:
                if t.run is not None:
                    t.result = t.run(t)
                elif t.duration:
                    time.sleep(t.duration)
                t.state = TaskState.DONE
            except Exception as e:  # noqa: BLE001 - task isolation boundary
                t.error = f"{type(e).__name__}: {e}\n" \
                          + traceback.format_exc()[-1500:]
                if t.attempts <= self.max_retries:
                    t.state = TaskState.NEW      # retry
                    with lock:
                        prof.n_retries += 1
                else:
                    t.state = TaskState.FAILED
            t.t_finished = time.perf_counter()
            with cv:
                free["n"] += t.slots
                prof.t_exec += t.t_finished - t.t_started
                prof.slot_busy += (t.t_finished - t.t_started) * t.slots
                self.journal.record(
                    t, "finished" if t.state == TaskState.DONE else "failed")
                cv.notify_all()

        with cv:
            while True:
                self._apply_resize()
                t0 = time.perf_counter()
                ready = [t for t in graph.ready() if t.slots <= free["n"]]
                for t in ready:
                    free["n"] -= t.slots
                    t.meta["dep_results"] = {
                        d: graph.tasks[d].result for d in t.deps}
                    t.attempts += 1
                    t.state = TaskState.RUNNING
                    t.t_scheduled = time.perf_counter()
                    self.journal.record(t, "scheduled")
                    pool.submit(execute, t)
                prof.t_rts_overhead += time.perf_counter() - t0
                if graph.done():
                    break
                in_flight = any(t.state == TaskState.RUNNING
                                for t in graph.tasks.values())
                if not ready and not in_flight:
                    # nothing runnable: cancel unsatisfiable tasks
                    for t in graph.tasks.values():
                        if t.state == TaskState.NEW and any(
                                graph.tasks[d].state.terminal
                                and graph.tasks[d].state != TaskState.DONE
                                for d in t.deps):
                            t.state = TaskState.CANCELED
                            self.journal.record(t, "canceled")
                    if graph.done():
                        break
                cv.wait(timeout=0.05)
        pool.shutdown(wait=True)
        prof.ttc = time.perf_counter() - t_start
