"""Pilot runtime executor: application-level scheduling of tasks onto the
pilot's slots (the RADICAL-Pilot analogue).

Two modes:
  real - tasks execute their callables on a slot thread pool (JAX work
         serializes on the device; orchestration concurrency is real).
  sim  - discrete-event simulation: task ``duration`` advances a virtual
         clock.  Scheduler/bookkeeping overheads are still measured on the
         real clock — this is how the Fig.7-10 scaling benches reproduce the
         paper's overhead measurements at 2560 tasks without hours of
         wall-clock sleep.

Incremental scheduling: a :class:`RuntimeSession` is a long-lived scheduling
context over one pilot.  ``submit()`` injects tasks at any time — including
from an ``on_task_done`` callback fired as each task completes — and
``drain()`` runs until everything submitted is terminal.  This is what lets
the PST ``AppManager`` (repro.core.pst) multiplex many pipelines over ONE
pilot session with no global barrier and no per-cycle graph teardown: a
completed exchange in ensemble A schedules A's next cycle immediately while
ensemble B is still simulating.  ``PilotRuntime.run(graph)`` is now a thin
wrapper: one session, one bulk submit, one drain.

Fault tolerance: bounded retries with backoff; straggler mitigation via
speculative duplicates (sim+real); elastic pilot resize mid-run; journal for
restart (dynamically injected tasks are journaled with a ``submitted``
record so a restarted session can tell replayed structure from new work).

Mesh-aware slots: with a ``topology`` (repro.dist.topology.SlotTopology) the
pilot's slots are *device submeshes* — a task occupying ``slots`` pilot slots
is granted that many slot ids (``task.meta["slot_ids"]``) and can build its
JAX mesh via ``runtime.submesh_for(task)``.  This ties the paper's pilot-slot
abstraction to device placement: e.g. one replica-exchange member per pod of
the 2x16x16 production mesh.

Data staging: with a ``staging`` layer (repro.staging.StagingLayer) tasks
carrying staged refs (``task.meta["staged_refs"]``) have their transfers
planned and executed between ``pop_ready`` and kernel launch, charged to
the task's ``t_data``; slot ids are granted locality-aware (free slots in
pods that already hold the task's input replicas first) and the scheduling
pass orders the frontier so input-local tasks run before tasks that would
have to copy.  Slot-id accounting turns on even without a device topology
(abstract ids) so locality works on plain pilots.
"""
from __future__ import annotations

import heapq
import statistics
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Union

from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState


@dataclass
class RuntimeProfile:
    """TTC decomposition (paper eq. 1-2)."""
    ttc: float = 0.0                   # makespan (virtual in sim mode)
    t_exec: float = 0.0                # sum of task execution times
    t_data: float = 0.0                # upload/download time
    t_rts_overhead: float = 0.0        # scheduling/dispatch (T_RP analogue)
    n_tasks: int = 0
    n_failed: int = 0
    n_canceled: int = 0
    n_retries: int = 0
    n_speculative: int = 0
    slot_busy: float = 0.0             # aggregate busy slot-seconds
    events: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.slot_busy / max(self.ttc, 1e-12)


class PilotRuntime:
    def __init__(self, slots: Optional[int] = None, *, mode: str = "real",
                 topology=None,
                 journal: Optional[Journal] = None,
                 staging=None,
                 max_retries: int = 2,
                 straggler_factor: float = 0.0,
                 min_straggler_samples: int = 5,
                 on_schedule: Optional[Callable] = None):
        assert mode in ("real", "sim")
        if slots is None:
            if topology is None:
                raise ValueError("need slots= or topology=")
            slots = topology.n_slots
        self.slots = slots
        self.mode = mode
        self.topology = topology
        if topology is not None and slots > topology.n_slots:
            raise ValueError(f"{slots} slots > {topology.n_slots} submeshes")
        # free slot ids: tracked when the slots are device submeshes, and
        # also (abstract ids) when a staging layer needs slot locality
        self._free_ids: Optional[List[int]] = (
            list(range(topology.n_slots))[::-1] if topology is not None
            else list(range(slots))[::-1] if staging is not None
            else None)
        # abstract ids ever minted and not retired (free + held): resize
        # must never re-mint an id a running task still holds
        self._minted: Optional[set] = \
            set(self._free_ids) if (topology is None
                                    and staging is not None) else None
        self.staging = staging
        if staging is not None:
            staging.bind_runtime(self)
        self.journal = journal or Journal(None)
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.min_straggler_samples = min_straggler_samples
        # called as on_schedule(runtime, graph, vnow) before every
        # scheduling step (vnow None in real mode) — the hook adaptive
        # strategies use to resize() the pilot MID-run
        self.on_schedule = on_schedule
        self._resize_to: Optional[int] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------ elastic
    def resize(self, slots: int):
        """Elastic pilot resize; takes effect at the next scheduling step.

        Growing past the carved submesh count re-carves the topology (e.g.
        2 pods -> 4 half-pods): validated here, applied at the first
        scheduling step where no task holds a slot id.
        """
        if self.topology is not None and slots > self.topology.n_slots:
            self.topology.recarve(slots)      # raises if not re-carvable
        with self._lock:
            self._resize_to = slots

    def _apply_resize(self) -> int:
        """Apply a pending resize; returns the capacity delta (real mode
        must credit/debit its free-slot counter by it)."""
        with self._lock:
            if self._resize_to is None:
                return 0
            if self.topology is not None \
                    and self._resize_to > self.topology.n_slots:
                # re-carve only when every slot id is free: ids change
                # meaning, so in-flight tasks must drain first (the resize
                # stays pending and re-tries each scheduling step)
                if len(self._free_ids) < self.topology.n_slots:
                    return 0
                self.topology = self.topology.recarve(self._resize_to)
                self._free_ids = list(range(self.topology.n_slots))[::-1]
            delta = self._resize_to - self.slots
            if self.topology is None and self._free_ids is not None:
                # abstract (staging-only) ids track capacity directly:
                # grow mints the lowest ids not currently outstanding
                # (NEVER an id a running task holds — that would alias two
                # tasks onto one locality domain), shrink retires free
                # ones (held ids return to a pool the capacity gate no
                # longer admits)
                if delta > 0:
                    new, i = [], 0
                    while len(new) < delta:
                        if i not in self._minted:
                            new.append(i)
                        i += 1
                    self._minted.update(new)
                    self._free_ids[:0] = new[::-1]
                elif delta < 0:
                    drop = set(sorted(self._free_ids,
                                      reverse=True)[:-delta])
                    self._free_ids = [i for i in self._free_ids
                                     if i not in drop]
                    self._minted -= drop
            delta_out = delta
            self.slots = self._resize_to
            self._resize_to = None
            return delta_out

    # ------------------------------------------------------------ submeshes
    def _acquire_slots(self, t: Task):
        """Grant ``t.slots`` slot ids (no-op without id tracking).

        Called wherever busy-count is incremented; capacity gating
        (busy <= self.slots <= topology.n_slots) guarantees availability.
        With a staging layer the grant is locality-aware: free ids in pods
        that already hold the task's staged input replicas come first, so
        the stage-in pass resolves to *link* instead of *copy*.
        """
        if self._free_ids is None:
            return
        if self.staging is not None and t.meta.get("staged_refs"):
            order = self.staging.preferred_ids(t, self._free_ids)
            ids = order[:t.slots]
            for i in ids:
                self._free_ids.remove(i)
            t.meta["slot_ids"] = ids
        else:
            t.meta["slot_ids"] = [self._free_ids.pop()
                                  for _ in range(t.slots)]
        t.meta.pop("slots_released", None)

    # ------------------------------------------------------------ staging
    def _stage_in_task(self, t: Task) -> float:
        """Execute the task's planned input transfers (repro.staging) —
        runs between ``pop_ready`` and kernel launch.  Returns the
        seconds charged to t_data (0.0 without a staging layer)."""
        if self.staging is None or not t.meta.get("staged_refs"):
            return 0.0
        return self.staging.stage_in(t, self.mode)

    def _staging_finish(self, t: Task):
        """Terminal-state hook: release the task's staged-blob holds."""
        if self.staging is not None:
            self.staging.finish(t)

    def _release_slots(self, t: Task):
        """Return t's slot ids exactly once (supersession may race a pop)."""
        if self._free_ids is None or "slot_ids" not in t.meta:
            return
        if t.meta.get("slots_released"):
            return
        t.meta["slots_released"] = True
        self._free_ids.extend(t.meta["slot_ids"])

    def submesh_for(self, t: Task):
        """jax Mesh over the devices of the slots granted to ``t``."""
        if self.topology is None:
            raise ValueError("runtime has no device topology")
        return self.topology.submesh(t.meta["slot_ids"])

    # ------------------------------------------------------------ sessions
    def session(self, *, on_task_done: Optional[Callable] = None
                ) -> "RuntimeSession":
        """Open a long-lived incremental scheduling session."""
        return RuntimeSession(self, on_task_done=on_task_done)

    # ------------------------------------------------------------ run
    def run(self, graph: TaskGraph) -> RuntimeProfile:
        """Closed-world execution of a prebuilt graph (one-shot session)."""
        graph.validate()
        sess = RuntimeSession(self, graph=graph)
        # journal replay from the session's (single) parse of the file
        skipped = sum(sess._replay_task(t) for t in graph.tasks.values())
        if skipped:
            sess.prof.events.append({"event": "journal_skip", "n": skipped})
        return sess.drain()


class RuntimeSession:
    """Incremental scheduling over one pilot: ``submit()`` then ``drain()``.

    The session owns the live TaskGraph, the virtual clock (sim mode), and
    the busy-slot accounting, all of which persist across submissions.  An
    ``on_task_done(task, session)`` callback fires from inside the drain
    loop as each non-speculative task reaches a terminal state and may call
    :meth:`submit` to inject downstream work — dynamic injection is what
    turns the per-cycle barrier of the legacy plugins into streaming,
    per-pipeline progress.  Callbacks run on the drain thread; ``submit``
    is not thread-safe against a concurrent ``drain``.
    """

    def __init__(self, runtime: PilotRuntime, *, graph: Optional[TaskGraph]
                 = None, on_task_done: Optional[Callable] = None):
        self.rt = runtime
        self.graph = graph if graph is not None else TaskGraph()
        self.prof = RuntimeProfile()
        self.on_task_done = on_task_done
        self.vnow = 0.0                      # virtual clock (sim mode)
        self._t0: Optional[float] = None     # real clock at first drain
        self._cbq: deque = deque()           # terminal tasks awaiting callback
        # sim-mode state (persists across drains: the clock never resets)
        self._busy = 0
        self._heap: List = []                # (v_finish, seq, task)
        self._seq = 0
        self._durations: Dict[str, List[float]] = {}
        self._spec_launched: Dict[str, Task] = {}
        # real-mode state
        self._cv = threading.Condition(threading.Lock())
        self._free = {"n": runtime.slots}
        # workers still inside _execute_real: a task flips to a terminal
        # state BEFORE its completion bookkeeping (callback enqueue, slot
        # release) runs under the lock, so graph.done() alone must never
        # end the drain loop
        self._inflight = 0
        # journal replay set, loaded once per session
        self._replayed_done, self._replayed_results = \
            runtime.journal.load_done()

    @property
    def busy_slots(self) -> int:
        """Slots currently occupied by running tasks (live signal for
        adaptive strategies; reads the drain thread's own accounting)."""
        if self.rt.mode == "sim":
            return self._busy
        return self.rt.slots - self._free["n"]

    # ------------------------------------------------------------ submit
    def submit(self, tasks: Union[Task, Iterable[Task]], *,
               dynamic: bool = False) -> List[Task]:
        """Add tasks to the live graph.  Deps must already be in the graph
        (earlier submission or same batch) — incremental submission is
        therefore acyclic by construction.  Tasks recorded DONE in the
        journal are replayed (skipped) and still fire their callback."""
        batch = [tasks] if isinstance(tasks, Task) else list(tasks)
        names = {t.name for t in batch}
        skipped = 0
        for t in batch:
            for d in t.deps:
                if d not in self.graph.tasks and d not in names:
                    raise ValueError(f"{t.name}: unknown dep {d}")
            self.graph.add(t)
            if dynamic:
                self.rt.journal.record(t, "submitted", dynamic=True)
            if self._replay_task(t):
                skipped += 1
                self._queue_callback(t)
        if skipped:
            self.prof.events.append({"event": "journal_skip", "n": skipped})
        return batch

    def _replay_task(self, t: Task) -> bool:
        """Mark ``t`` DONE (with its recorded result) if the journal says
        it already finished; the single shared replay rule."""
        if t.name not in self._replayed_done or t.state.terminal:
            return False
        t.state = TaskState.DONE
        t.result = self._replayed_results.get(t.name, t.result)
        return True

    # ------------------------------------------------------------ drain
    def drain(self) -> RuntimeProfile:
        """Run until every submitted task is terminal (callbacks included:
        work they inject is drained too).  Returns the session profile,
        cumulative across drains."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        if self.rt.mode == "sim":
            self._drain_sim()
            self.prof.ttc = self.vnow
        else:
            self._drain_real()
            self.prof.ttc = time.perf_counter() - self._t0
        self.prof.n_tasks = len(self.graph)
        self.prof.n_failed = sum(1 for t in self.graph.tasks.values()
                                 if t.state == TaskState.FAILED)
        self.prof.n_canceled = sum(1 for t in self.graph.tasks.values()
                                   if t.state == TaskState.CANCELED)
        return self.prof

    # ------------------------------------------------------------ staging
    def _locality_candidates(self, avail: int) -> List[Task]:
        """Bounded locality-ordered lookahead (staging pilots only): pop
        at most ``avail`` + headroom ready tasks — nothing at all when
        nothing can fit — and order input-local tasks first.  Shared by
        the sim and real drain loops; the caller launches what fits and
        hands the rest back."""
        graph, rt = self.graph, self.rt
        cands: List[Task] = []
        if avail <= 0:
            return cands
        min_w = graph.frontier_min_width()
        if min_w is None or min_w > avail:
            return cands
        while len(cands) < avail + 16:
            t = graph.pop_ready()
            if t is None:
                break
            cands.append(t)
        cands.sort(key=lambda c: (not rt.staging.prefers(
            c, rt._free_ids), c.tid))
        return cands

    # ------------------------------------------------------------ callbacks
    def _queue_callback(self, t: Task):
        if self.on_task_done is not None and t.speculative_of is None:
            self._cbq.append(t)

    def _flush_callbacks(self):
        while self._cbq:
            self.on_task_done(self._cbq.popleft(), self)

    # ------------------------------------------------------------ sim mode
    def _overhead(self, fn):
        t0 = time.perf_counter()
        out = fn()
        self.prof.t_rts_overhead += time.perf_counter() - t0
        return out

    def _launch_sim(self, t: Task):
        self._busy += t.slots
        rt = self.rt
        rt._acquire_slots(t)
        # staged-input transfers execute here — between pop_ready and
        # launch — and extend the task's occupancy on the virtual clock
        t_data = rt._stage_in_task(t)
        t.attempts += 1
        t.state = TaskState.RUNNING
        t.t_scheduled = time.perf_counter()
        t.v_started = self.vnow
        rt.journal.record(t, "scheduled")
        heapq.heappush(self._heap,
                       (self.vnow + max(t.duration, 0.0) + t_data,
                        self._seq, t))
        self._seq += 1

    def _schedule_sim(self):
        rt, graph = self.rt, self.graph
        if rt.staging is not None:
            # locality-ordered pass: tasks whose staged inputs already
            # have a replica in a free pod run first (they link instead
            # of copy); head-of-line holds within the locality order
            # (stop at the first candidate that does not fit, same as
            # the seed)
            cands = self._locality_candidates(rt.slots - self._busy)
            for i, t in enumerate(cands):
                if rt.slots - self._busy >= t.slots:
                    self._launch_sim(t)
                else:
                    for c in cands[i:]:
                        graph.requeue(c)
                    break
            return
        while True:
            t = graph.pop_ready()          # incremental frontier, tid order
            if t is None:
                break
            if rt.slots - self._busy < t.slots:
                graph.requeue(t)           # same head-of-line rule as seed
                break
            self._launch_sim(t)

    def _finish_sim(self, t: Task):
        rt, graph, prof = self.rt, self.graph, self.prof
        t.state = TaskState.DONE
        t.v_finished = self.vnow
        t.t_finished = time.perf_counter()
        prof.t_exec += t.duration
        prof.t_data += t.t_data
        prof.slot_busy += t.duration * t.slots
        self._durations.setdefault(t.stage, []).append(t.duration)
        rt.journal.record(t, "finished")
        rt._staging_finish(t)
        if t.speculative_of:
            # the duplicate won: complete the straggling original
            # and kill it (freeing its slot now)
            orig = graph.tasks.get(t.speculative_of)
            if orig is not None and not orig.state.terminal:
                orig.state = TaskState.DONE
                orig.v_finished = self.vnow
                orig.meta["slot_freed"] = True
                self._busy -= orig.slots
                rt._release_slots(orig)
                rt.journal.record(orig, "finished", by="speculative")
                rt._staging_finish(orig)
                self._queue_callback(orig)
            self._spec_launched.pop(t.speculative_of, None)
        else:
            # original won: cancel its twin if any
            twin = self._spec_launched.pop(t.name, None)
            if twin is not None and not twin.state.terminal:
                twin.state = TaskState.CANCELED
            self._queue_callback(t)

    def _drain_sim(self):
        rt, graph, prof = self.rt, self.graph, self.prof
        while True:
            self._flush_callbacks()
            if rt.on_schedule is not None:
                rt.on_schedule(rt, graph, self.vnow)
            rt._apply_resize()
            self._overhead(self._schedule_sim)

            if not self._heap:
                if graph.done():
                    break
                # nothing runnable: cancel only truly unsatisfiable tasks
                # (failed/canceled upstream, or wider than the whole pilot)
                # so a narrow task queued behind a too-wide one still runs
                # on the next pass — same rule as real mode
                canceled = False
                for t in graph.tasks.values():
                    if t.state == TaskState.NEW and (
                            t.slots > rt.slots or any(
                                graph.tasks[d].state.terminal
                                and graph.tasks[d].state != TaskState.DONE
                                for d in t.deps)):
                        t.state = TaskState.CANCELED
                        rt.journal.record(t, "canceled")
                        rt._staging_finish(t)
                        self._queue_callback(t)
                        canceled = True
                if not canceled:
                    # termination guard (unreachable by construction: a
                    # stuck NEW task always matches one rule above)
                    for t in graph.tasks.values():
                        if t.state == TaskState.NEW:
                            t.state = TaskState.CANCELED
                            rt.journal.record(t, "canceled")
                            rt._staging_finish(t)
                            self._queue_callback(t)
                self._flush_callbacks()
                if graph.done():
                    break
                continue

            vfin, _, t = heapq.heappop(self._heap)
            if t.state.terminal:
                # canceled twin / original superseded by its speculative
                # duplicate: slot already freed at supersession; do NOT
                # advance the clock to its stale finish time
                if not t.meta.get("slot_freed"):
                    self._busy -= t.slots
                rt._release_slots(t)
                continue
            self.vnow = max(self.vnow, vfin)
            self._busy -= t.slots
            rt._release_slots(t)
            self._overhead(lambda: self._finish_sim(t))

            # straggler speculation: clone still-running outliers
            if rt.straggler_factor:
                self._overhead(self._speculate_sim)

    def _speculate_sim(self):
        rt, prof = self.rt, self.prof
        for vfin, sq, t in list(self._heap):
            hist = self._durations.get(t.stage, [])
            if (t.idempotent and not t.state.terminal
                    and t.speculative_of is None
                    and t.name not in self._spec_launched
                    and rt.slots - self._busy >= t.slots
                    and len(hist) >= rt.min_straggler_samples):
                med = statistics.median(hist)
                # the monitor fires when elapsed > factor * median; in DES
                # that trigger time is known, so schedule the duplicate to
                # start exactly then (if the original would still be running)
                trigger = t.v_started + rt.straggler_factor * med
                if trigger < vfin:
                    dup = Task(name=t.name + f".spec{t.attempts}",
                               duration=med, slots=t.slots, stage=t.stage,
                               instance=t.instance, iteration=t.iteration,
                               speculative_of=t.name)
                    dup.state = TaskState.RUNNING
                    dup.v_started = max(self.vnow, trigger)
                    prof.n_speculative += 1
                    self._busy += t.slots
                    rt._acquire_slots(dup)
                    heapq.heappush(
                        self._heap,
                        (max(self.vnow, trigger) + med, id(dup), dup))
                    self._spec_launched[t.name] = dup

    # ------------------------------------------------------------ real mode
    def _execute_real(self, t: Task):
        rt, prof, cv = self.rt, self.prof, self._cv
        t.t_started = time.perf_counter()
        outcome = TaskState.DONE
        t.meta.pop("t_data_kernel", None)     # fresh window per attempt
        try:
            # staged-input transfers: between pop_ready and kernel launch,
            # on the worker (transfers overlap across tasks); the restamp
            # keeps t_exec and t_data disjoint in the TTC decomposition
            rt._stage_in_task(t)
            t.t_started = time.perf_counter()
            if t.run is not None:
                t.result = t.run(t)
            elif t.duration:
                time.sleep(t.duration)
        except Exception as e:  # noqa: BLE001 - task isolation boundary
            t.error = f"{type(e).__name__}: {e}\n" \
                      + traceback.format_exc()[-1500:]
            outcome = (TaskState.NEW if t.attempts <= rt.max_retries
                       else TaskState.FAILED)
        t.t_finished = time.perf_counter()
        with cv:
            # the state transition happens INSIDE the lock: flipping a
            # retry to NEW any earlier lets the drain thread reschedule it
            # (and re-grant slot ids) before this attempt's bookkeeping
            # releases the old ones
            self._free["n"] += t.slots
            rt._release_slots(t)
            # in-kernel lazy derefs (ctx["staging"].get) charged to t_data
            # come OUT of the exec window — the decomposition terms must
            # not overlap
            span = max(t.t_finished - t.t_started
                       - t.meta.get("t_data_kernel", 0.0), 0.0)
            prof.t_exec += span
            prof.slot_busy += span * t.slots
            t.state = outcome
            if outcome == TaskState.NEW:
                prof.n_retries += 1
            rt.journal.record(
                t, "finished" if t.state == TaskState.DONE else "failed")
            if t.state.terminal:
                # cumulative across attempts, charged once at the end
                prof.t_data += t.t_data
                rt._staging_finish(t)
                self._queue_callback(t)
            self._inflight -= 1
            cv.notify_all()

    def _drain_real(self):
        # thread-per-task: slot gating already bounds concurrency, and a
        # fixed pool would cap an elastic grow mid-run
        workers: List[threading.Thread] = []
        try:
            self._drain_real_loop(workers)
        finally:
            # join even when a user on_done callback raised, so no worker
            # is left mutating the profile/journal after drain() returns
            for th in workers:
                th.join()

    def _drain_real_loop(self, workers: List[threading.Thread]):
        rt, graph, prof = self.rt, self.graph, self.prof
        cv = self._cv
        with cv:
            while True:
                self._flush_callbacks()
                if rt.on_schedule is not None:
                    rt.on_schedule(rt, graph, None)
                self._free["n"] += rt._apply_resize()   # elastic grow/shrink
                t0 = time.perf_counter()
                # pop from the incremental frontier, re-checking capacity
                # per task; too-wide tasks are skipped (narrower ones behind
                # them may fit) and requeued after the pass.  The min-width
                # check ends the pass as soon as NOTHING left can fit —
                # without it a nearly-full pilot would drain the whole
                # frontier into `skipped` on every wakeup (O(n) per event)
                scheduled, skipped = [], []
                cands = None
                if rt.staging is not None:
                    # locality-ordered pass: input-local tasks claim free
                    # pods before tasks that would have to copy (too-wide
                    # candidates are skipped, as in the default pass)
                    cands = self._locality_candidates(self._free["n"])
                    cands.reverse()        # consumed via pop() below
                while True:
                    if cands is not None:
                        t = cands.pop() if cands else None
                    else:
                        min_w = graph.frontier_min_width()
                        if min_w is None or min_w > self._free["n"]:
                            break
                        t = graph.pop_ready()
                    if t is None:
                        break
                    if t.slots > self._free["n"]:
                        skipped.append(t)
                        continue
                    scheduled.append(t)
                    self._free["n"] -= t.slots
                    rt._acquire_slots(t)
                    t.meta["dep_results"] = {
                        d: graph.tasks[d].result for d in t.deps}
                    t.attempts += 1
                    t.state = TaskState.RUNNING
                    t.t_scheduled = time.perf_counter()
                    rt.journal.record(t, "scheduled")
                    self._inflight += 1
                    th = threading.Thread(target=self._execute_real,
                                          args=(t,), daemon=True)
                    workers.append(th)
                    th.start()
                for t in skipped:
                    graph.requeue(t)
                prof.t_rts_overhead += time.perf_counter() - t0
                quiescent = not self._inflight and not self._cbq
                if graph.done() and quiescent:
                    break
                if not scheduled and quiescent:
                    # nothing runnable: cancel unsatisfiable tasks — failed
                    # upstream deps, or wider than the whole idle pilot
                    # (nothing in flight, so free == capacity: such a task
                    # can never start and would spin this loop forever)
                    for t in graph.tasks.values():
                        if t.state != TaskState.NEW:
                            continue
                        if t.slots > self._free["n"] or any(
                                graph.tasks[d].state.terminal
                                and graph.tasks[d].state != TaskState.DONE
                                for d in t.deps):
                            t.state = TaskState.CANCELED
                            rt.journal.record(t, "canceled")
                            rt._staging_finish(t)
                            self._queue_callback(t)
                    if graph.done() and not self._cbq:
                        break
                    # retried tasks (back to NEW) reschedule next pass
                if not self._cbq:
                    cv.wait(timeout=0.05)
