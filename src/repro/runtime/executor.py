"""Pilot runtime executor: application-level scheduling of tasks onto the
pilot's slots (the RADICAL-Pilot analogue).

Two modes:
  real - tasks execute their callables on a slot thread pool (JAX work
         serializes on the device; orchestration concurrency is real).
  sim  - discrete-event simulation: task ``duration`` advances a virtual
         clock.  Scheduler/bookkeeping overheads are still measured on the
         real clock — this is how the Fig.7-10 scaling benches reproduce the
         paper's overhead measurements at 2560 tasks without hours of
         wall-clock sleep.

Fault tolerance: bounded retries with backoff; straggler mitigation via
speculative duplicates (sim+real); elastic pilot resize mid-run; journal for
restart.

Mesh-aware slots: with a ``topology`` (repro.dist.topology.SlotTopology) the
pilot's slots are *device submeshes* — a task occupying ``slots`` pilot slots
is granted that many slot ids (``task.meta["slot_ids"]``) and can build its
JAX mesh via ``runtime.submesh_for(task)``.  This ties the paper's pilot-slot
abstraction to device placement: e.g. one replica-exchange member per pod of
the 2x16x16 production mesh.
"""
from __future__ import annotations

import heapq
import statistics
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph, TaskState


@dataclass
class RuntimeProfile:
    """TTC decomposition (paper eq. 1-2)."""
    ttc: float = 0.0                   # makespan (virtual in sim mode)
    t_exec: float = 0.0                # sum of task execution times
    t_data: float = 0.0                # upload/download time
    t_rts_overhead: float = 0.0        # scheduling/dispatch (T_RP analogue)
    n_tasks: int = 0
    n_failed: int = 0
    n_retries: int = 0
    n_speculative: int = 0
    slot_busy: float = 0.0             # aggregate busy slot-seconds
    events: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def utilization(self) -> float:
        return self.slot_busy / max(self.ttc, 1e-12)


class PilotRuntime:
    def __init__(self, slots: Optional[int] = None, *, mode: str = "real",
                 topology=None,
                 journal: Optional[Journal] = None,
                 max_retries: int = 2,
                 straggler_factor: float = 0.0,
                 min_straggler_samples: int = 5,
                 on_schedule: Optional[Callable] = None):
        assert mode in ("real", "sim")
        if slots is None:
            if topology is None:
                raise ValueError("need slots= or topology=")
            slots = topology.n_slots
        self.slots = slots
        self.mode = mode
        self.topology = topology
        if topology is not None and slots > topology.n_slots:
            raise ValueError(f"{slots} slots > {topology.n_slots} submeshes")
        # free slot ids (only tracked when the slots are device submeshes)
        self._free_ids: Optional[List[int]] = \
            None if topology is None else list(range(topology.n_slots))[::-1]
        self.journal = journal or Journal(None)
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.min_straggler_samples = min_straggler_samples
        # called as on_schedule(runtime, graph, vnow) before every
        # scheduling step (vnow None in real mode) — the hook adaptive
        # strategies use to resize() the pilot MID-run
        self.on_schedule = on_schedule
        self._resize_to: Optional[int] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------ elastic
    def resize(self, slots: int):
        """Elastic pilot resize; takes effect at the next scheduling step."""
        if self.topology is not None and slots > self.topology.n_slots:
            raise ValueError(f"{slots} slots > {self.topology.n_slots} "
                             "submeshes in the pilot topology")
        with self._lock:
            self._resize_to = slots

    def _apply_resize(self) -> int:
        """Apply a pending resize; returns the capacity delta (real mode
        must credit/debit its free-slot counter by it)."""
        with self._lock:
            if self._resize_to is None:
                return 0
            delta = self._resize_to - self.slots
            self.slots = self._resize_to
            self._resize_to = None
            return delta

    # ------------------------------------------------------------ submeshes
    def _acquire_slots(self, t: Task):
        """Grant ``t.slots`` slot ids (no-op without a topology).

        Called wherever busy-count is incremented; capacity gating
        (busy <= self.slots <= topology.n_slots) guarantees availability.
        """
        if self._free_ids is None:
            return
        t.meta["slot_ids"] = [self._free_ids.pop() for _ in range(t.slots)]
        t.meta.pop("slots_released", None)

    def _release_slots(self, t: Task):
        """Return t's slot ids exactly once (supersession may race a pop)."""
        if self._free_ids is None or "slot_ids" not in t.meta:
            return
        if t.meta.get("slots_released"):
            return
        t.meta["slots_released"] = True
        self._free_ids.extend(t.meta["slot_ids"])

    def submesh_for(self, t: Task):
        """jax Mesh over the devices of the slots granted to ``t``."""
        if self.topology is None:
            raise ValueError("runtime has no device topology")
        return self.topology.submesh(t.meta["slot_ids"])

    # ------------------------------------------------------------ run
    def run(self, graph: TaskGraph) -> RuntimeProfile:
        graph.validate()
        skipped = self.journal.replay(graph)
        prof = RuntimeProfile()
        if skipped:
            prof.events.append({"event": "journal_skip", "n": skipped})
        if self.mode == "sim":
            self._run_sim(graph, prof)
        else:
            self._run_real(graph, prof)
        prof.n_tasks = len(graph)
        prof.n_failed = sum(1 for t in graph.tasks.values()
                            if t.state == TaskState.FAILED)
        return prof

    # ------------------------------------------------------------ sim mode
    def _run_sim(self, graph: TaskGraph, prof: RuntimeProfile):
        vnow = 0.0
        busy = 0
        running: List = []            # heap of (v_finish, seq, task)
        seq = 0
        durations: Dict[str, List[float]] = {}
        spec_launched: Dict[str, Task] = {}

        def overhead(fn):
            t0 = time.perf_counter()
            out = fn()
            prof.t_rts_overhead += time.perf_counter() - t0
            return out

        while not graph.done() or running:
            if self.on_schedule is not None:
                self.on_schedule(self, graph, vnow)
            self._apply_resize()

            def schedule():
                nonlocal busy, seq
                ready = sorted(graph.ready(), key=lambda t: t.tid)
                for t in ready:
                    if self.slots - busy < t.slots:
                        break
                    busy += t.slots
                    self._acquire_slots(t)
                    t.attempts += 1
                    t.state = TaskState.RUNNING
                    t.t_scheduled = time.perf_counter()
                    t.v_started = vnow
                    self.journal.record(t, "scheduled")
                    heapq.heappush(running, (vnow + max(t.duration, 0.0),
                                             seq, t))
                    seq += 1
            overhead(schedule)

            if not running:
                if graph.done():
                    break
                # deadlock: unsatisfiable deps (failed upstream)
                for t in graph.tasks.values():
                    if t.state == TaskState.NEW:
                        t.state = TaskState.CANCELED
                        self.journal.record(t, "canceled")
                break

            vfin, _, t = heapq.heappop(running)
            if t.state.terminal:
                # canceled twin / original superseded by its speculative
                # duplicate: slot already freed at supersession; do NOT
                # advance the clock to its stale finish time
                if not t.meta.get("slot_freed"):
                    busy -= t.slots
                self._release_slots(t)
                continue
            vnow = max(vnow, vfin)
            busy -= t.slots
            self._release_slots(t)

            def finish():
                nonlocal busy
                t.state = TaskState.DONE
                t.v_finished = vnow
                t.t_finished = time.perf_counter()
                prof.t_exec += t.duration
                prof.slot_busy += t.duration * t.slots
                durations.setdefault(t.stage, []).append(t.duration)
                self.journal.record(t, "finished")
                if t.speculative_of:
                    # the duplicate won: complete the straggling original
                    # and kill it (freeing its slot now)
                    orig = graph.tasks.get(t.speculative_of)
                    if orig is not None and not orig.state.terminal:
                        orig.state = TaskState.DONE
                        orig.v_finished = vnow
                        orig.meta["slot_freed"] = True
                        busy -= orig.slots
                        self._release_slots(orig)
                        self.journal.record(orig, "finished",
                                            by="speculative")
                    spec_launched.pop(t.speculative_of, None)
                else:
                    # original won: cancel its twin if any
                    twin = spec_launched.pop(t.name, None)
                    if twin is not None and not twin.state.terminal:
                        twin.state = TaskState.CANCELED
            overhead(finish)

            # straggler speculation: clone still-running outliers
            if self.straggler_factor:
                def spec():
                    nonlocal busy
                    busy = self._speculate_sim(
                        graph, running, durations, spec_launched, vnow,
                        prof, busy)
                overhead(spec)
        prof.ttc = vnow

    def _speculate_sim(self, graph, running, durations, spec_launched,
                       vnow, prof, busy):
        for vfin, sq, t in list(running):
            hist = durations.get(t.stage, [])
            if (t.idempotent and not t.state.terminal
                    and t.speculative_of is None
                    and t.name not in spec_launched
                    and self.slots - busy >= t.slots
                    and len(hist) >= self.min_straggler_samples):
                med = statistics.median(hist)
                # the monitor fires when elapsed > factor * median; in DES
                # that trigger time is known, so schedule the duplicate to
                # start exactly then (if the original would still be running)
                trigger = t.v_started + self.straggler_factor * med
                if trigger < vfin:
                    dup = Task(name=t.name + f".spec{t.attempts}",
                               duration=med, slots=t.slots, stage=t.stage,
                               instance=t.instance, iteration=t.iteration,
                               speculative_of=t.name)
                    dup.state = TaskState.RUNNING
                    dup.v_started = max(vnow, trigger)
                    prof.n_speculative += 1
                    busy += t.slots
                    self._acquire_slots(dup)
                    heapq.heappush(
                        running, (max(vnow, trigger) + med, id(dup), dup))
                    spec_launched[t.name] = dup
        return busy

    # ------------------------------------------------------------ real mode
    def _run_real(self, graph: TaskGraph, prof: RuntimeProfile):
        t_start = time.perf_counter()
        lock = threading.Lock()
        cv = threading.Condition(lock)
        free = {"n": self.slots}
        # thread-per-task: slot gating already bounds concurrency, and a
        # fixed pool would cap an elastic grow mid-run
        workers: List[threading.Thread] = []

        def execute(t: Task):
            t.t_started = time.perf_counter()
            try:
                if t.run is not None:
                    t.result = t.run(t)
                elif t.duration:
                    time.sleep(t.duration)
                t.state = TaskState.DONE
            except Exception as e:  # noqa: BLE001 - task isolation boundary
                t.error = f"{type(e).__name__}: {e}\n" \
                          + traceback.format_exc()[-1500:]
                if t.attempts <= self.max_retries:
                    t.state = TaskState.NEW      # retry
                    with lock:
                        prof.n_retries += 1
                else:
                    t.state = TaskState.FAILED
            t.t_finished = time.perf_counter()
            with cv:
                free["n"] += t.slots
                self._release_slots(t)
                prof.t_exec += t.t_finished - t.t_started
                prof.slot_busy += (t.t_finished - t.t_started) * t.slots
                self.journal.record(
                    t, "finished" if t.state == TaskState.DONE else "failed")
                cv.notify_all()

        with cv:
            while True:
                if self.on_schedule is not None:
                    self.on_schedule(self, graph, None)
                free["n"] += self._apply_resize()   # elastic grow/shrink
                t0 = time.perf_counter()
                # re-check capacity per task: a single pass may admit
                # several tasks, each draining free["n"]
                scheduled = []
                for t in graph.ready():
                    if t.slots > free["n"]:
                        continue
                    scheduled.append(t)
                    free["n"] -= t.slots
                    self._acquire_slots(t)
                    t.meta["dep_results"] = {
                        d: graph.tasks[d].result for d in t.deps}
                    t.attempts += 1
                    t.state = TaskState.RUNNING
                    t.t_scheduled = time.perf_counter()
                    self.journal.record(t, "scheduled")
                    th = threading.Thread(target=execute, args=(t,),
                                          daemon=True)
                    workers.append(th)
                    th.start()
                prof.t_rts_overhead += time.perf_counter() - t0
                if graph.done():
                    break
                in_flight = any(t.state == TaskState.RUNNING
                                for t in graph.tasks.values())
                if not scheduled and not in_flight:
                    # nothing runnable: cancel unsatisfiable tasks
                    for t in graph.tasks.values():
                        if t.state == TaskState.NEW and any(
                                graph.tasks[d].state.terminal
                                and graph.tasks[d].state != TaskState.DONE
                                for d in t.deps):
                            t.state = TaskState.CANCELED
                            self.journal.record(t, "canceled")
                    if graph.done():
                        break
                cv.wait(timeout=0.05)
        for th in workers:
            th.join()
        prof.ttc = time.perf_counter() - t_start
