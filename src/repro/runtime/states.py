"""Task model: states, tasks, task graphs (the IR all patterns compile to)."""
from __future__ import annotations

import enum
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


class TaskState(str, enum.Enum):
    NEW = "NEW"
    SCHEDULED = "SCHEDULED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELED = "CANCELED"

    @property
    def terminal(self) -> bool:
        return self in (TaskState.DONE, TaskState.FAILED, TaskState.CANCELED)


_tid_counter = itertools.count()


@dataclass
class Task:
    """One executable unit (the paper's task, produced from a kernel plugin).

    ``duration``: simulated execution seconds (DES mode); ``run``: callable
    executed in real mode.  ``slots``: resource width (paper's "cores").
    """
    name: str
    run: Optional[Callable[["Task"], Any]] = None
    duration: float = 0.0
    slots: int = 1
    deps: List[str] = field(default_factory=list)
    stage: str = ""
    instance: int = 0
    iteration: int = 0
    idempotent: bool = True       # eligible for speculative re-execution
    meta: Dict[str, Any] = field(default_factory=dict)

    tid: str = field(default_factory=lambda: f"t{next(_tid_counter):06d}")
    state: TaskState = TaskState.NEW
    attempts: int = 0
    result: Any = None
    error: Optional[str] = None
    # timestamps (real clock for overheads; virtual clock for sim TTC)
    t_created: float = field(default_factory=time.perf_counter)
    t_scheduled: float = 0.0
    t_started: float = 0.0
    t_finished: float = 0.0
    v_started: float = 0.0
    v_finished: float = 0.0
    speculative_of: Optional[str] = None


@dataclass
class TaskGraph:
    tasks: Dict[str, Task] = field(default_factory=dict)

    def add(self, task: Task) -> Task:
        if task.name in self.tasks:
            raise ValueError(f"duplicate task {task.name}")
        self.tasks[task.name] = task
        return task

    def __len__(self):
        return len(self.tasks)

    def validate(self):
        for t in self.tasks.values():
            for d in t.deps:
                if d not in self.tasks:
                    raise ValueError(f"{t.name}: unknown dep {d}")
        # cycle check (Kahn)
        indeg = {n: len(t.deps) for n, t in self.tasks.items()}
        out: Dict[str, List[str]] = {n: [] for n in self.tasks}
        for n, t in self.tasks.items():
            for d in t.deps:
                out[d].append(n)
        q = [n for n, k in indeg.items() if k == 0]
        seen = 0
        while q:
            n = q.pop()
            seen += 1
            for m in out[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    q.append(m)
        if seen != len(self.tasks):
            raise ValueError("task graph has a cycle")

    def ready(self) -> List[Task]:
        return [t for t in self.tasks.values()
                if t.state == TaskState.NEW
                and all(self.tasks[d].state == TaskState.DONE
                        for d in t.deps)]

    def done(self) -> bool:
        return all(t.state.terminal for t in self.tasks.values())
