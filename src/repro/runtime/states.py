"""Task model: states, tasks, task graphs (the IR all patterns compile to).

The TaskGraph maintains its ready frontier *incrementally*: every task keeps
a count of unmet (not-DONE) dependencies and the graph keeps a min-heap of
ready task names keyed by (-priority, tid): higher-priority tasks (e.g. the
serving ``latency`` SLA class) pop before lower ones, FIFO within a
priority level.  State transitions are observed through the
``Task.state`` descriptor, so any ``t.state = ...`` write — scheduler,
journal replay, speculative supersession — updates the frontier in O(log f)
(f = frontier size) instead of the per-event full scan the seed used, which
made a long session O(n²) in completion events.  ``ready()`` survives as a
snapshot API; schedulers should use ``pop_ready()``/``requeue()``.
"""
from __future__ import annotations

import enum
import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


class TaskState(str, enum.Enum):
    NEW = "NEW"
    SCHEDULED = "SCHEDULED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELED = "CANCELED"

    @property
    def terminal(self) -> bool:
        return self in (TaskState.DONE, TaskState.FAILED, TaskState.CANCELED)


_tid_counter = itertools.count()


@dataclass
class Task:
    """One executable unit (the paper's task, produced from a kernel plugin).

    ``duration``: simulated execution seconds (DES mode); ``run``: callable
    executed in real mode.  ``slots``: resource width (paper's "cores").
    """
    name: str
    run: Optional[Callable[["Task"], Any]] = None
    duration: float = 0.0
    slots: int = 1
    deps: List[str] = field(default_factory=list)
    stage: str = ""
    instance: int = 0
    iteration: int = 0
    idempotent: bool = True       # eligible for speculative re-execution
    # frontier ordering: higher pops first; ties break on tid (FIFO).
    # Serving SLA classes map onto this (latency > throughput), and the
    # executor may preempt a running lower-priority task for a ready
    # higher-priority one (see PilotRuntime(preempt=True)).
    priority: int = 0
    meta: Dict[str, Any] = field(default_factory=dict)

    tid: str = field(default_factory=lambda: f"t{next(_tid_counter):06d}")
    state: TaskState = TaskState.NEW
    attempts: int = 0
    # execution history: one JSON-able record per finished attempt
    # ({"attempt", "pod", "slot_ids", "outcome", "error"}) — the scitq
    # Execution-table analogue.  Drives bounded retries that EXCLUDE the
    # failing pod from the re-grant, and survives restarts via the journal.
    history: List[Dict[str, Any]] = field(default_factory=list)
    # seconds spent moving this task's data (staged-ref transfers executed
    # between pop_ready and launch, plus in-kernel lazy derefs) — the
    # per-task slice of the paper's t_data term
    t_data: float = 0.0
    result: Any = None
    error: Optional[str] = None
    # timestamps (real clock for overheads; virtual clock for sim TTC)
    t_created: float = field(default_factory=time.perf_counter)
    t_scheduled: float = 0.0
    t_started: float = 0.0
    t_finished: float = 0.0
    v_started: float = 0.0
    v_finished: float = 0.0
    speculative_of: Optional[str] = None

    # ------------------------------------------------------------ attempts
    def record_attempt(self, outcome: str, *, pod: Optional[str] = None,
                       error: Optional[str] = None) -> Dict[str, Any]:
        """Append one attempt record to :attr:`history` (outcome in
        done|failed|pod_lost|worker_died|heartbeat_timeout|superseded|
        canceled|preempted)."""
        rec = {"attempt": self.attempts, "pod": pod,
               "slot_ids": list(self.meta.get("slot_ids", ())),
               "outcome": outcome}
        if error:
            rec["error"] = error
        self.history.append(rec)
        return rec

    def excluded_pods(self) -> set:
        """Pods a retry must avoid: every pod a FAILED attempt ran on
        (the retry-remembering model — availability still wins: the
        scheduler falls back to an excluded pod when nothing else is
        free)."""
        from repro.runtime.faults import FAILED_OUTCOMES
        return {h["pod"] for h in self.history
                if h.get("pod") and h["outcome"] in FAILED_OUTCOMES}

    def beat(self):
        """Heartbeat hook for long-running kernels (real mode): refreshes
        the liveness timestamp the failure detector checks."""
        self.meta["heartbeat"] = time.perf_counter()


def _task_state_get(self: Task) -> TaskState:
    return self.__dict__["_state"]


def _task_state_set(self: Task, new: TaskState):
    old = self.__dict__.get("_state")
    self.__dict__["_state"] = new
    graph = self.__dict__.get("_graph")
    if graph is not None and old is not new:
        graph._on_state(self, old, new)


# ``state`` stays a dataclass field (default/repr/eq all intact) but reads
# and writes go through a property attached after class creation: once a
# task is add()ed to a TaskGraph, EVERY state write notifies the graph so
# the frontier and terminal count stay incremental — no call-site refactor,
# no way to bypass the bookkeeping.
Task.state = property(_task_state_get, _task_state_set)


@dataclass
class TaskGraph:
    tasks: Dict[str, Task] = field(default_factory=dict)

    def __post_init__(self):
        self._unmet: Dict[str, int] = {}       # name -> deps not yet DONE
        self._waiters: Dict[str, List[str]] = {}   # dep name -> dependents
        self._in_frontier: set = set()
        self._heap: List = []    # (-priority, tid, name), lazily pruned
        self._width_counts: Dict[int, int] = {}    # slots -> frontier count
        self._n_terminal = 0
        # optional zero-arg run clock (a sim RuntimeSession sets it to its
        # virtual now): frontier entry stamps task.meta["v_ready"], the
        # ready-timestamp the t_sched term of the TTC decomposition needs
        self.clock: Optional[Callable[[], float]] = None
        for t in list(self.tasks.values()):    # pre-populated dict support
            self._index(t)

    def add(self, task: Task) -> Task:
        if task.name in self.tasks:
            raise ValueError(f"duplicate task {task.name}")
        self.tasks[task.name] = task
        self._index(task)
        return task

    def _index(self, task: Task):
        task.__dict__["_graph"] = self
        unmet = 0
        for d in task.deps:
            dep = self.tasks.get(d)
            if dep is None or dep.state != TaskState.DONE:
                unmet += 1
                self._waiters.setdefault(d, []).append(task.name)
        self._unmet[task.name] = unmet
        if task.state == TaskState.NEW:
            if unmet == 0:
                self._frontier_add(task)
        elif task.state.terminal:
            self._n_terminal += 1
            if task.state == TaskState.DONE:
                self._satisfy_waiters(task)

    def __len__(self):
        return len(self.tasks)

    # ------------------------------------------------------------ frontier
    def _frontier_add(self, task: Task):
        if task.name not in self._in_frontier:
            self._in_frontier.add(task.name)
            heapq.heappush(self._heap,
                           (-task.priority, task.tid, task.name))
            w = task.slots
            self._width_counts[w] = self._width_counts.get(w, 0) + 1
            if self.clock is not None:
                # setdefault: a pop_ready/requeue round-trip keeps the
                # ORIGINAL ready time; a retry (launch popped the key)
                # stamps afresh
                task.meta.setdefault("v_ready", self.clock())

    def _frontier_discard(self, task: Task):
        if task.name in self._in_frontier:
            self._in_frontier.discard(task.name)
            w = task.slots
            left = self._width_counts.get(w, 0) - 1
            if left:
                self._width_counts[w] = left
            else:
                self._width_counts.pop(w, None)

    def frontier_min_width(self) -> Optional[int]:
        """Narrowest slot width in the frontier (None when empty).  Lets a
        scheduler skip a pass outright when nothing can fit the free
        capacity, instead of scanning wide tasks (#widths is tiny)."""
        return min(self._width_counts) if self._width_counts else None

    def frontier_slots(self) -> int:
        """Total slot width queued in the frontier (O(#distinct widths)) —
        the backlog signal backlog-driven recruiting keys on."""
        return sum(w * c for w, c in self._width_counts.items())

    def _satisfy_waiters(self, task: Task):
        for wname in self._waiters.pop(task.name, ()):
            left = self._unmet.get(wname)
            if left is None:
                continue
            self._unmet[wname] = left - 1
            w = self.tasks.get(wname)
            if left == 1 and w is not None and w.state == TaskState.NEW:
                self._frontier_add(w)

    def _on_state(self, task: Task, old: Optional[TaskState],
                  new: TaskState):
        """Observer for every in-graph ``task.state`` write."""
        was_terminal = old is not None and old.terminal
        if new.terminal and not was_terminal:
            self._n_terminal += 1
        elif was_terminal and not new.terminal:
            self._n_terminal -= 1
        if new == TaskState.NEW:               # retry re-enters the frontier
            if self._unmet.get(task.name, 0) == 0:
                self._frontier_add(task)
        else:
            self._frontier_discard(task)
        if new == TaskState.DONE and old != TaskState.DONE:
            self._satisfy_waiters(task)

    def pop_ready(self) -> Optional[Task]:
        """Highest-priority ready task (ties: lowest tid), removed from the
        frontier (the caller either schedules it or gives it back via
        :meth:`requeue`)."""
        while self._heap:
            name = self._heap[0][2]
            if name not in self._in_frontier:   # stale entry: lazily prune
                heapq.heappop(self._heap)
                continue
            heapq.heappop(self._heap)
            t = self.tasks[name]
            self._frontier_discard(t)
            return t
        return None

    def requeue(self, task: Task):
        """Return a popped-but-unscheduled task to the frontier."""
        if task.state == TaskState.NEW and \
                self._unmet.get(task.name, 0) == 0:
            self._frontier_add(task)

    def validate(self):
        for t in self.tasks.values():
            for d in t.deps:
                if d not in self.tasks:
                    raise ValueError(f"{t.name}: unknown dep {d}")
        # cycle check (Kahn)
        indeg = {n: len(t.deps) for n, t in self.tasks.items()}
        out: Dict[str, List[str]] = {n: [] for n in self.tasks}
        for n, t in self.tasks.items():
            for d in t.deps:
                out[d].append(n)
        q = [n for n, k in indeg.items() if k == 0]
        seen = 0
        while q:
            n = q.pop()
            seen += 1
            for m in out[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    q.append(m)
        if seen != len(self.tasks):
            raise ValueError("task graph has a cycle")

    def ready(self) -> List[Task]:
        """Snapshot of the frontier in pop order — priority desc, then tid
        (O(f log f), f = frontier size — NOT O(n); kept for
        inspection/back-compat)."""
        return sorted((self.tasks[n] for n in self._in_frontier),
                      key=lambda t: (-t.priority, t.tid))

    def done(self) -> bool:
        return self._n_terminal == len(self.tasks)
