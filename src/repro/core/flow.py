"""Typed data-flow ports: cross-pipeline coupling for PST workflows.

The PST API (core/pst.py) runs many pipelines over one pilot session, but
until this module a stage could only consume results from *its own*
pipeline's previous stage.  Ports turn that shared-session concurrency into
a true DAG-of-ensembles: a ``Stage`` (or ``TaskSpec``) declares ``inputs``
and ``outputs``, and the ``AppManager`` resolves every cross-pipeline edge
into task dependencies on the shared ``RuntimeSession`` — a consumer stage
in pipeline B starts the moment the producing stage in pipeline A is done,
while A's later stages are still running.

Two edge primitives:

  StageFuture   a handle to ONE specific stage's eventual results
                (``stage.future()``).  The consumer's tasks gain direct
                dependencies on the producer's tasks, so the consumer is
                submitted as soon as the producer stage is, and starts the
                instant the producer's last task finishes.
  Channel       a named, ordered stream decoupling producers from
                consumers.  Every completion of a producing stage ``put``s
                its results; each consumer binding ``take``s the oldest
                untaken put (FIFO work-queue).  Repeating producers (one
                put per cycle) feed repeating consumers without either side
                naming the other's stages.

Producer ensemble -> shared analysis ensemble -> feedback stage::

    from repro.core import AppManager, PipelineSpec, Stage, TaskSpec
    from repro.core.flow import Channel

    traj = Channel("trajectories", dtype=dict)   # typed: puts are checked
    weights = Channel("weights")

    # ensemble of simulators: each cycle's stage streams into `traj`
    prod = PipelineSpec(
        [Stage([TaskSpec(md_kernel(m)) for m in range(members)],
               name=f"cycle{c}", outputs=[traj])
         for c in range(cycles)], name="producer")

    # shared analysis ensemble: each round consumes ONE trajectory put —
    # round 0 starts while the producer is still on cycle 1
    ana = PipelineSpec(
        [Stage([TaskSpec(ana_kernel())], name=f"round{c}",
               inputs={"traj": traj}, outputs=[weights])
         for c in range(cycles)], name="analysis")

    # feedback: re-weights sampling from the analysis stream
    fb = PipelineSpec(
        [Stage([TaskSpec(fb_kernel())], name=f"fb{c}",
               inputs={"weights": weights}) for c in range(cycles)],
        name="feedback")

    AppManager(pilot).run([prod, ana, fb])

A consumer kernel receives its bound ports as ``ctx["inputs"]`` — for the
analysis kernel above, ``ctx["inputs"]["traj"]`` is the producing stage's
``{task_name: result}`` dict.  A pipeline whose next stage's inputs are not
yet satisfiable parks ("waiting") and is woken when the producer stage is
submitted (futures) or a put arrives (channels); pipelines still parked
when the session drains are reported ``blocked``.

Restart determinism: the journal records every ``channel_put`` (value) and
``channel_take`` (consumer -> producer binding).  On replay, puts reuse the
journaled value and takes re-bind to the journaled producer — consumer
stages see byte-identical inputs and no completed task re-executes (see
runtime/journal.py ``load_flow``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Port:
    """A named, optionally typed attachment point for data flow."""
    name: str
    dtype: Optional[type] = None


class TypedPortError(TypeError):
    """A put violated the channel's declared payload type."""


class Channel:
    """Named, ordered stream of stage/task outputs shared across pipelines.

    Producers are stages (put value = the stage's ``{task: result}`` dict)
    or single tasks (put value = the task's result).  ``dtype``, when set,
    is enforced per task result at put time.  A Channel belongs to one
    AppManager run topology; names must be unique within it.

    Consumption modes:

      fifo (default)  work-queue: each consumer binding takes the oldest
                      untaken put exactly once — N consumers SPLIT the
                      stream.
      broadcast       each consumer *stream* (one pipeline's successive
                      bindings of the port) keeps its own cursor over
                      EVERY put — N analysis ensembles each see every
                      trajectory.  Staged refs (repro.staging) make the
                      fan-out cheap: one blob, N takes.

    ``capacity`` declares back-pressure: the AppManager parks a producer
    pipeline whose next stage would put onto a channel already holding
    ``capacity`` unconsumed puts, and wakes it on the next take (default
    None: unbounded, the historical behavior).

    ``capacity_bytes`` is the byte-denominated variant: each put carries a
    payload size (the AppManager passes the staged-ref ``nbytes``, or the
    producing kernels' declared ``output_nbytes`` in DES mode) and a
    producer parks while the channel's *unconsumed* bytes plus its next
    emission would exceed the budget.  This is what bounds staged-blob
    memory for streaming workloads (serving traffic windows) where put
    COUNT says nothing about footprint.  Both limits may be set; either
    parks the producer.
    """

    def __init__(self, name: str, dtype: Optional[type] = None, *,
                 capacity: Optional[int] = None,
                 capacity_bytes: Optional[int] = None, mode: str = "fifo"):
        if not name:
            raise ValueError("channel needs a non-empty name")
        if mode not in ("fifo", "broadcast"):
            raise ValueError(f"channel mode must be fifo|broadcast, "
                             f"got {mode!r}")
        if capacity is not None and capacity < 1:
            raise ValueError("channel capacity must be >= 1")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError("channel capacity_bytes must be >= 1")
        self.name = name
        self.dtype = dtype
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self.mode = mode
        self.puts: List[Tuple[str, Any]] = []   # (producer_key, value)
        self._index: Dict[str, int] = {}        # producer_key -> put index
        self._taken: set = set()                # consumed put indices (fifo)
        self._scan_from = 0                     # first possibly-untaken idx
        # puts pre-bound to a consumer by journal replay (producer_key ->
        # consumer_key): invisible to fresh FIFO takes
        self._reserved: Dict[str, str] = {}
        # broadcast: consumer stream -> index of its next unread put
        self._cursors: Dict[str, int] = {}
        # byte accounting: per-put payload sizes as a prefix-sum (O(1)
        # unconsumed-bytes queries), bytes retired by fifo takes, and the
        # high-water mark the serving bench asserts against the budget
        self._byte_prefix: List[int] = [0]      # prefix[i] = bytes of puts[:i]
        self._bytes_taken = 0
        self.peak_unconsumed_bytes = 0

    @property
    def port(self) -> Port:
        return Port(self.name, self.dtype)

    def check(self, value: Any, *, task_level: bool = False):
        """Type-check a put payload.  Stage-level puts are ``{task:
        result}`` dicts (each result checked); task-level puts are one bare
        result (checked as-is — it may itself be a dict)."""
        if self.dtype is None:
            return
        if not task_level and not isinstance(value, dict):
            raise TypedPortError(
                f"channel {self.name!r}: stage-level puts must be "
                f"{{task: result}} dicts, got {type(value).__name__}")
        results = [value] if task_level else value.values()
        for r in results:
            if not isinstance(r, self.dtype):
                raise TypedPortError(
                    f"channel {self.name!r} expects {self.dtype.__name__} "
                    f"results, got {type(r).__name__}")

    def put(self, producer_key: str, value: Any, *,
            task_level: bool = False, check: bool = True,
            nbytes: int = 0) -> int:
        """``check=False`` skips the dtype check — the AppManager passes it
        in DES (sim) mode, where tasks run nothing and every result is
        None, so a typed channel would reject the placeholder payloads.
        ``nbytes`` is the payload size charged against ``capacity_bytes``
        (0 = untracked put)."""
        if producer_key in self._index:
            raise ValueError(f"channel {self.name!r}: duplicate put from "
                             f"{producer_key!r}")
        if check:
            self.check(value, task_level=task_level)
        self._index[producer_key] = len(self.puts)
        self.puts.append((producer_key, value))
        self._byte_prefix.append(self._byte_prefix[-1] + max(int(nbytes), 0))
        self.peak_unconsumed_bytes = max(self.peak_unconsumed_bytes,
                                         self.n_unconsumed_bytes())
        return self._index[producer_key]

    def has_put(self, producer_key: str) -> bool:
        return producer_key in self._index

    def _fifo_candidates(self, consumer_key: str):
        # amortized O(new puts): the cursor skips the fully-consumed prefix
        # (reserved-but-untaken replay puts can pin it, bounded by replay)
        while self._scan_from < len(self.puts) \
                and self._scan_from in self._taken:
            self._scan_from += 1
        for i in range(self._scan_from, len(self.puts)):
            if i in self._taken:
                continue
            if self._reserved.get(self.puts[i][0],
                                  consumer_key) != consumer_key:
                continue                        # held for a replayed taker
            yield i

    def n_available(self, consumer_key: str,
                    stream: Optional[str] = None) -> int:
        """Puts a fresh (non-replayed) take by ``consumer_key`` could bind.
        Broadcast channels count from the consumer stream's own cursor."""
        if self.mode == "broadcast":
            return len(self.puts) - self._cursors.get(
                stream or consumer_key, 0)
        return sum(1 for _ in self._fifo_candidates(consumer_key))

    def touch(self, stream: str):
        """Register a broadcast consumer stream (cursor at 0) so
        back-pressure counts it before its first take."""
        if self.mode == "broadcast":
            self._cursors.setdefault(stream, 0)

    def n_unconsumed(self) -> int:
        """Puts nobody has consumed yet — the back-pressure signal.
        Broadcast counts from the SLOWEST registered stream's cursor."""
        if self.mode == "broadcast":
            return len(self.puts) - (min(self._cursors.values())
                                     if self._cursors else 0)
        return len(self.puts) - len(self._taken)

    def n_unconsumed_bytes(self) -> int:
        """Payload bytes nobody has consumed yet — the byte-denominated
        back-pressure signal ``capacity_bytes`` parks producers on.
        Broadcast counts from the SLOWEST registered stream's cursor (a
        put's bytes are retained until every stream is past it)."""
        if self.mode == "broadcast":
            lo = min(self._cursors.values()) if self._cursors else 0
            return self._byte_prefix[-1] - self._byte_prefix[lo]
        return self._byte_prefix[-1] - self._bytes_taken

    def take(self, consumer_key: str, producer_key: Optional[str] = None,
             stream: Optional[str] = None) -> Tuple[str, Any]:
        """Consume one put: the journaled producer when replaying, else the
        oldest untaken put (fifo) / the stream's cursor (broadcast).
        Returns ``(producer_key, value)``."""
        if self.mode == "broadcast":
            s = stream or consumer_key
            if producer_key is not None:
                idx = self._index.get(producer_key)
                if idx is None:
                    raise LookupError(
                        f"channel {self.name!r}: put from {producer_key!r} "
                        "not available for replayed take")
            else:
                idx = self._cursors.get(s, 0)
                if idx >= len(self.puts):
                    raise LookupError(
                        f"channel {self.name!r}: no put available")
            self._cursors[s] = max(self._cursors.get(s, 0), idx + 1)
            return self.puts[idx]
        if producer_key is not None:
            idx = self._index.get(producer_key)
            if idx is None or idx in self._taken:
                raise LookupError(
                    f"channel {self.name!r}: put from {producer_key!r} "
                    "not available for replayed take")
        else:
            idx = next(self._fifo_candidates(consumer_key), None)
            if idx is None:
                raise LookupError(f"channel {self.name!r}: no put available")
        self._taken.add(idx)
        self._bytes_taken += \
            self._byte_prefix[idx + 1] - self._byte_prefix[idx]
        return self.puts[idx]

    def __repr__(self):
        consumed = (f"{len(self._cursors)} streams"
                    if self.mode == "broadcast"
                    else f"{len(self._taken)} taken")
        return f"Channel({self.name!r}, {len(self.puts)} puts, {consumed})"


class StageFuture:
    """Handle to one Stage's eventual results — a cross-pipeline edge.

    Created via ``Stage.future()``.  The consuming stage's tasks depend
    directly on the producer stage's tasks; at execution time the bound
    port resolves to the producer's ``{task: result}`` dict.
    """

    def __init__(self, stage, port: str = ""):
        self.stage = stage
        self.port = port or (getattr(stage, "name", "") or "stage")

    @property
    def submitted(self) -> bool:
        return getattr(self.stage, "task_names", None) is not None

    def __repr__(self):
        return f"StageFuture({self.stage!r})"


def normalize_sources(sources) -> Dict[str, Any]:
    """Normalize an ``inputs`` declaration to ``{port_name: source}``.

    Accepts None, a single Channel/StageFuture, an iterable of them (port
    name defaults to the channel name / producer stage name), or a dict.
    """
    if sources is None:
        return {}
    if isinstance(sources, dict):
        return dict(sources)
    if isinstance(sources, (Channel, StageFuture)):
        sources = [sources]
    out: Dict[str, Any] = {}
    for src in sources:
        port = src.name if isinstance(src, Channel) else src.port
        if port in out:
            raise ValueError(f"duplicate input port {port!r}")
        out[port] = src
    return out


def normalize_outputs(outputs) -> List[Channel]:
    """Normalize an ``outputs`` declaration to a list of Channels."""
    if outputs is None:
        return []
    if isinstance(outputs, Channel):
        return [outputs]
    chans = list(outputs)
    for ch in chans:
        if not isinstance(ch, Channel):
            raise TypeError(f"outputs must be Channels, got {type(ch)}")
    return chans
