"""Fused SPMD ensemble execution — the beyond-paper, TPU-native mode.

The paper schedules each replica as an independent task (O(N) dispatch, host
round-trip at every exchange).  A homogeneous ensemble phase on TPU can
instead be ONE SPMD program: member states stacked on a leading axis, vmapped
member steps sharded over the mesh, and the exchange phase computed on-device
(all-gather of scalar losses + Metropolis swap of the temperature vector).
Dispatch cost becomes O(1) per *cycle* and the exchange needs no host
round-trip.  benchmarks/fused_dispatch.py quantifies both against task mode.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import forward, init_params
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.train.losses import chunked_softmax_xent


def _member_train_step(cfg: ModelConfig, state, batch, lr):
    """One member's train step with a *traced* learning rate (the RE/PBT
    temperature dimension)."""
    def loss_fn(params):
        out = forward(cfg, params, batch["tokens"], mesh=None,
                      remat=cfg.remat != "none")
        loss, _ = chunked_softmax_xent(cfg, params, out["h"],
                                       batch["labels"])
        return loss + 0.01 * out["aux"]

    loss, grads = jax.value_and_grad(loss_fn)(state["params"])
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    new_params, new_opt = adamw_update(grads, state["opt"],
                                       state["params"], lr=lr)
    return ({"params": new_params, "opt": new_opt,
             "step": state["step"] + 1}, loss)


def metropolis_swap_device(losses, temps, cycle, key):
    """On-device even/odd Metropolis swap of the temperature vector.
    losses, temps: (N,).  Returns (new_temps, n_accepted)."""
    n = losses.shape[0]
    idx = jnp.arange(n)
    start = cycle % 2
    is_left = (idx % 2) == (start % 2)
    partner = jnp.where(is_left, idx + 1, idx - 1)
    valid = (partner >= 0) & (partner < n)
    partner = jnp.clip(partner, 0, n - 1)
    e_i, e_j = losses, losses[partner]
    t_i, t_j = temps, temps[partner]
    # d is symmetric in the pair: swapping (i, j) negates both factors, so
    # each member computes the same acceptance exponent as its partner
    d = (e_i - e_j) * (1.0 / t_i - 1.0 / t_j)
    u = jax.random.uniform(key, (n,), minval=1e-12)
    # both members read the pair leader's (left member's) uniform draw, so
    # the accept decision is mirrored exactly across the pair
    leader = jnp.where(is_left, idx, partner)
    accept = valid & (jnp.log(u)[leader] < d)
    new_temps = jnp.where(accept, temps[partner], temps)
    return new_temps, jnp.sum(accept) // 2


class FusedEnsemble:
    """Homogeneous replica-exchange ensemble as one SPMD program.

    Member axis sharded over the pilot mesh's "data" axis (one slot = one
    member shard).  ``mesh=None`` runs single-device (CPU tests).
    """

    def __init__(self, cfg: ModelConfig, n_members: int, *,
                 mesh=None, base_temp: float = 3e-4, temp_ratio: float = 1.3):
        self.cfg = cfg
        self.n = n_members
        self.mesh = mesh
        self.temps0 = jnp.array(
            [base_temp * temp_ratio ** i for i in range(n_members)],
            jnp.float32)
        self._cycle_fn = None

    # ------------------------------------------------------------ state
    def init(self, key) -> Dict[str, Any]:
        keys = jax.random.split(key, self.n)

        def one(k):
            params = init_params(self.cfg, k)
            return {"params": params,
                    "opt": adamw_init(params, self.cfg.optstate_dtype),
                    "step": jnp.zeros((), jnp.int32)}

        states = jax.vmap(one)(keys)
        if self.mesh is not None:
            spec = jax.tree.map(
                lambda x: NamedSharding(
                    self.mesh, P("data", *([None] * (x.ndim - 1)))), states)
            states = jax.device_put(states, spec)
        # fresh copy: the ensemble state is donated per cycle and must not
        # alias self.temps0
        return {"members": states, "temps": self.temps0 + 0.0,
                "cycle": jnp.zeros((), jnp.int32)}

    # ------------------------------------------------------------ cycle
    def _build_cycle(self, steps_per_cycle: int, shape: ShapeSpec):
        cfg = self.cfg

        def member_steps(state, batches, lr):
            def body(st, b):
                st, loss = _member_train_step(cfg, st, b, lr)
                return st, loss
            state, losses = jax.lax.scan(body, state, batches)
            return state, losses[-1]

        vmapped = jax.vmap(member_steps, in_axes=(0, 0, 0))

        def cycle(ens_state, batches, key):
            members, temps = ens_state["members"], ens_state["temps"]
            members, losses = vmapped(members, batches, temps)
            new_temps, n_acc = metropolis_swap_device(
                losses, temps, ens_state["cycle"], key)
            return ({"members": members, "temps": new_temps,
                     "cycle": ens_state["cycle"] + 1},
                    {"losses": losses, "accepted": n_acc,
                     "temps": new_temps})

        return jax.jit(cycle, donate_argnums=(0,))

    def run(self, key, *, cycles: int, steps_per_cycle: int,
            shape: ShapeSpec, data_seed: int = 0) -> Tuple[Any, list]:
        """Returns (final ensemble state, per-cycle metrics)."""
        from repro.data import SyntheticLM
        ens = self.init(key)
        cyc = self._build_cycle(steps_per_cycle, shape)
        history = []
        data = [SyntheticLM(self.cfg, shape, seed=data_seed + i)
                for i in range(self.n)]
        step0 = 0
        for c in range(cycles):
            batches = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[jax.tree.map(
                    jnp.asarray,
                    _stack_steps(data[i], step0, steps_per_cycle))
                  for i in range(self.n)])
            key, sub = jax.random.split(key)
            ens, m = cyc(ens, batches, sub)
            history.append(jax.device_get(m))
            step0 += steps_per_cycle
        return ens, history


def _stack_steps(ds, start: int, n: int):
    batches = [ds.batch_at(start + i) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
