"""Composable Pipeline-Stage-Task (PST) workflow API with data-flow ports.

The seed mirrored the 2016 toolkit's subclass-hook pattern API
(``stage_1..stage_M`` via getattr, ``prepare_*`` overrides).  The second
generation toolkit ("Harnessing the Power of Many", arXiv:1710.08491)
replaced those hardcoded patterns with composable *data objects* because the
hook API structurally cannot express adaptive or coupled ensembles.  This
module is that redesign:

  TaskSpec      one executable unit: a bound Kernel + placement metadata
                (+ optional per-task data-flow ports).
  Stage         a set of concurrent TaskSpecs + an ``on_done`` adaptivity
                callback that may append stages or mutate the downstream
                pipeline when the stage completes, + declared ``inputs`` /
                ``outputs`` ports (core/flow.py) for cross-pipeline edges.
  PipelineSpec  an ordered list of Stages; stage k+1 starts when stage k
                finishes (a per-pipeline barrier — never a global one).
  AppManager    executes many pipelines concurrently over ONE long-lived
                PilotRuntime session (runtime/executor.RuntimeSession) with
                dynamic task injection, resolving every cross-pipeline port
                edge into task dependencies on the shared session — a true
                DAG-of-ensembles, not just shared-session concurrency.

Quickstart::

    sim = Stage([TaskSpec(k) for k in member_kernels], name="sim")
    def adapt(stage, pipe):
        if needs_more_sampling(stage.results):
            pipe.add_stage(make_refinement_stage(stage.results))
    ana = Stage([TaskSpec(ana_kernel)], name="analysis", on_done=adapt)
    profile = AppManager(pilot).run([PipelineSpec([sim, ana], name="e0"),
                                     PipelineSpec([...], name="e1")])

Coupling (see core/flow.py for the full producer -> analysis -> feedback
example): a Stage in pipeline B consumes a Stage in pipeline A either via a
``Channel`` (``outputs=[ch]`` / ``inputs={"traj": ch}``: FIFO stream, one
put per producing stage completion) or a ``StageFuture``
(``inputs={"traj": stage_a.future()}``: direct task dependencies).  The
consumer starts the moment its producer stage is done — while pipeline A's
later stages are still running.  A pipeline whose next stage's inputs are
not yet satisfiable parks and is woken by the producing event; pipelines
still parked when the session drains are reported ``blocked``.

The legacy patterns (Pipeline, BagOfTasks, ReplicaExchange,
SimulationAnalysisLoop) still work: their execution plugins are now thin
compilers from the hook API to port-annotated PST (core/execution_plugin.py).

Placement: tasks land on mesh slots via ``PilotRuntime.submesh_for`` — in
real mode a kernel's ``ctx["submesh"]`` is the jax Mesh over the devices of
the slots the scheduler granted to its task (requires the runtime to be
built with a ``SlotTopology``).

Federation: ``AppManager`` also accepts a :class:`repro.federation.Fleet`
as its runtime — the same application then late-binds every task across N
pilots (different slot counts/meshes, per-pilot journals, optional
backlog-driven recruiting) with no declaration change; the per-pilot
dispatch counts land in ``profile.results["federation"]``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Union

from repro.core import flow
from repro.core.flow import Channel, StageFuture
from repro.core.kernel_plugin import Kernel
from repro.runtime.states import Task, TaskState
from repro.staging.ports import TaskStagingView, decode_refs, encode_refs
from repro.staging.store import StagedRef

_MISSING = object()


@dataclass
class ExecutionProfile:
    """Paper eq. (1)-(2): TTC = T_exec + T_data + T_EnMD(core+pattern+rts)."""
    ttc: float = 0.0
    t_exec: float = 0.0
    t_data: float = 0.0
    t_core_overhead: float = 0.0
    t_pattern_overhead: float = 0.0
    t_rts_overhead: float = 0.0
    n_tasks: int = 0
    n_failed: int = 0
    n_canceled: int = 0
    n_retries: int = 0
    n_speculative: int = 0
    n_pod_lost: int = 0     # attempts lost to pod/worker failure
    n_preempted: int = 0    # attempts evicted for higher-priority work
    # busy slot-seconds accumulate here so utilization can be computed over
    # the WHOLE run at the end (not overwritten per cycle — that bug made
    # RE/SAL report only the last cycle's utilization)
    slot_busy: float = 0.0
    utilization: float = 0.0
    per_stage: Dict[str, Dict[str, float]] = field(default_factory=dict)
    results: Dict[str, Any] = field(default_factory=dict)

    @property
    def t_enmd_overhead(self) -> float:
        return (self.t_core_overhead + self.t_pattern_overhead
                + self.t_rts_overhead)

    def summary(self) -> Dict[str, float]:
        return {"ttc": self.ttc, "t_exec": self.t_exec,
                "t_data": self.t_data,
                "t_core_overhead": self.t_core_overhead,
                "t_pattern_overhead": self.t_pattern_overhead,
                "t_rts_overhead": self.t_rts_overhead,
                "n_tasks": self.n_tasks, "n_failed": self.n_failed,
                "utilization": self.utilization}


# ------------------------------------------------------------------ objects

@dataclass
class TaskSpec:
    """Kernel + slots + metadata (+ ports): what to run, how wide, labels.

    ``kernel`` is a :class:`Kernel` or a plugin name string; a string is
    resolved at submit time and an unknown name is rejected with
    diagnostic E107 (carrying the pipeline/stage/task location) before
    any task of the stage launches.

    ``name`` (optional) becomes the runtime task name verbatim — callers
    providing names are responsible for global uniqueness; unnamed specs get
    ``<pipeline>.<stage_idx>.<stage>.<index>`` (unique even when adaptive
    extension reuses a stage name).  Slot width comes from ``kernel.cores``.
    ``metadata`` keys ``instance`` and ``iteration`` land on the Task record
    (profiling labels); everything else rides along in ``task.meta``.

    ``inputs``/``outputs`` are per-TASK ports: an input Channel takes one
    put for this task alone; an output Channel receives this task's bare
    result the moment the task finishes (finer-grained streaming than the
    stage-level ports, which move ``{task: result}`` dicts per stage).

    ``stage_in``/``stage_out`` are data-staging declarations (values or
    callables / result-consuming callables).  They default to the kernel's
    legacy ``upload_input_data``/``download_output_data`` fields — the
    compile path from the 2016 staging directives — and are acted on only
    when the pilot runs with a ``repro.staging.StagingLayer``: inputs are
    content-address-staged ONCE (N members sharing a blob link it), moved
    to each task's pod between ``pop_ready`` and launch, and delivered as
    ``ctx["staged_inputs"]``; every move is charged to ``t_data``.
    Without staging the kernel handles its own lists, exactly as before.

    ``sla`` names a serving SLA class (``latency`` | ``throughput``, see
    repro/serving/sla.py); an unknown name is rejected with diagnostic
    E115.  The class supplies the frontier ``priority`` (overridable
    explicitly) and a default ``deadline`` budget in seconds; both land on
    the Task (``task.priority`` / ``task.meta["deadline"]``) so the
    scheduler orders — and, with ``PilotRuntime(preempt=True)``, preempts —
    by them.
    """
    kernel: Union[Kernel, str]
    name: str = ""
    metadata: Dict[str, Any] = field(default_factory=dict)
    inputs: Any = None
    outputs: Any = None
    stage_in: Any = None
    stage_out: Any = None
    sla: Optional[str] = None
    priority: Optional[int] = None
    deadline: Optional[float] = None

    def __post_init__(self):
        if isinstance(self.kernel, str):
            # named-kernel spec: resolved to a Kernel (and the staging
            # defaults below applied) at submit time, where an unknown
            # name is rejected with diagnostic E107
            return
        if self.stage_in is None:
            self.stage_in = self.kernel.upload_input_data
        if self.stage_out is None:
            self.stage_out = self.kernel.download_output_data


class Stage:
    """A set of concurrent tasks; completes when all of them are terminal.

    ``on_done(stage, pipeline)`` fires once at completion (only if no task
    failed) and may mutate the downstream graph: append stages via
    ``pipeline.add_stage`` / ``pipeline.extend`` or return an iterable of
    new stages.  ``stage.results`` maps task name -> result.

    ``inputs`` declares data-flow sources (``{port: Channel|StageFuture}``,
    or a list — see core/flow.py); kernels receive the bound values as
    ``ctx["inputs"][port]``.  ``outputs`` lists Channels that receive this
    stage's ``{task: result}`` dict when the stage completes.  A Stage is
    executed at most once by one AppManager (adaptive loops build a fresh
    Stage per cycle).
    """

    def __init__(self, tasks: Iterable[Union[TaskSpec, Kernel]] = (), *,
                 name: str = "",
                 inputs: Any = None, outputs: Any = None,
                 stage_in: Any = None, stage_out: Any = None,
                 on_done: Optional[Callable[["Stage", "PipelineSpec"],
                                            Any]] = None):
        self.name = name
        self.tasks: List[TaskSpec] = [
            t if isinstance(t, TaskSpec) else TaskSpec(t) for t in tasks]
        self.inputs = inputs
        self.outputs = outputs
        # stage-level staging declarations: shared by EVERY task of the
        # stage (one content-addressed blob, N links); out-callables run
        # once with the stage's {task: result} dict
        self.stage_in = list(stage_in) if stage_in else []
        self.stage_out = list(stage_out) if stage_out else []
        self.on_done = on_done
        self.results: Dict[str, Any] = {}
        self.n_failed = 0
        # set by the AppManager when the stage is submitted
        self.task_names: Optional[List[str]] = None
        self.bound_inputs: Dict[str, Any] = {}   # channel ports, concrete
        self._future_ports: List = []            # (port, StageFuture), lazy
        self._port_deps: List[str] = []          # producer task names

    def add(self, task: Union[TaskSpec, Kernel]) -> TaskSpec:
        spec = task if isinstance(task, TaskSpec) else TaskSpec(task)
        self.tasks.append(spec)
        return spec

    def future(self, port: str = "") -> StageFuture:
        """Cross-pipeline handle to this stage's eventual results."""
        return StageFuture(self, port)

    def __repr__(self):
        return f"Stage({self.name!r}, {len(self.tasks)} tasks)"


class PipelineSpec:
    """Ordered stages executed with a per-pipeline barrier between them.

    The stage list may grow while the pipeline runs (adaptivity): appending
    from an ``on_done`` callback extends this pipeline without touching any
    other pipeline running on the same AppManager.
    """

    def __init__(self, stages: Iterable[Stage] = (), *, name: str = ""):
        self.name = name
        self.stages: List[Stage] = list(stages)

    def add_stage(self, stage: Stage) -> Stage:
        self.stages.append(stage)
        return stage

    def extend(self, stages: Iterable[Stage]):
        self.stages.extend(stages)

    def __repr__(self):
        return f"PipelineSpec({self.name!r}, {len(self.stages)} stages)"


# ------------------------------------------------------------------ manager

class _PipelineRun:
    """Execution-time state of one pipeline on an AppManager."""

    def __init__(self, spec: PipelineSpec, name: str):
        self.spec = spec
        self.name = name
        self.idx = -1                 # index of the currently running stage
        # pending | running | waiting | done | failed | blocked
        self.state = "pending"
        self.waiting_on: Optional[str] = None
        self.pending: set = set()     # outstanding task names, current stage
        self.stage_task_names: List[List[str]] = []


class AppManager:
    """Run many PST pipelines concurrently over one pilot session.

    Accepts a ``Pilot`` (core.resource_handler) or a bare ``PilotRuntime``.
    All pipelines share the runtime's slots; each advances independently —
    stage k+1 of pipeline A is injected into the live session the moment
    stage k completes, regardless of what B is doing (no global barrier, no
    per-cycle graph teardown).  Port declarations (core/flow.py) couple
    pipelines into a DAG-of-ensembles resolved on the same session.

    ``strategy`` (runtime/strategy.AdaptiveSlotStrategy) is applied at every
    stage completion with the LIVE per-pipeline queue depths, so the pilot
    elastically grows into a backlog and shrinks when pipelines idle —
    within one session, not just between runs.
    """

    def __init__(self, pilot, *, profile: Optional[ExecutionProfile] = None,
                 strategy=None):
        if hasattr(pilot, "runtime"):
            self.pilot = pilot
            self.runtime = pilot.runtime
        else:
            self.pilot = None
            self.runtime = pilot
        self.profile = profile if profile is not None else ExecutionProfile()
        self.strategy = strategy
        # the pilot's staging layer (repro.staging), when configured:
        # large channel puts become StagedRefs, dereferenced back into
        # ctx["inputs"] between pop_ready and kernel launch
        self.staging = getattr(self.runtime, "staging", None)
        self._kernels: Dict[str, Kernel] = {}
        self._task_index: Dict[str, _PipelineRun] = {}
        self._stage_of: Dict[str, Stage] = {}
        self._spec_of: Dict[str, TaskSpec] = {}
        self._task_bound: Dict[str, Dict[str, Any]] = {}
        self._task_futures: Dict[str, List] = {}
        self.session = None            # live RuntimeSession while running
        self.pipeline_runs: Dict[str, _PipelineRun] = {}
        # data-flow state: registered channels, parked pipelines, and the
        # journal's replayed puts/takes (restart determinism; loaded
        # lazily on first port use so port-free workloads never pay a
        # second journal parse on top of the session's load_done)
        self.channels: Dict[str, Channel] = {}
        self._parked: Dict[Any, List[_PipelineRun]] = {}
        self._replayed_puts: Optional[Dict] = None
        self._replayed_takes: Optional[Dict] = None
        # wakes raised while a stage is mid-submission are DEFERRED until
        # the outermost submission completes: a wake delivered between two
        # of a stage's counted takes could reentrantly submit another
        # consumer that steals the puts this stage's blocker check already
        # counted (-> LookupError mid-bind)
        self._advance_depth = 0
        self._pending_wakes: List[Any] = []

    # ------------------------------------------------------------ build
    def _make_run(self, kernel: Kernel, stage: Stage):
        if self.runtime.mode != "real":
            return None

        def run(task: Task, _k=kernel, _stage=stage):
            ctx = {"pilot": self.pilot, "runtime": self.runtime,
                   "task": task,
                   "dep_results": task.meta.get("dep_results", {}),
                   "inputs": self._bound_inputs_for(task, _stage)}
            if self.runtime.topology is not None \
                    and task.meta.get("slot_ids"):
                ctx["submesh"] = self.runtime.submesh_for(task)
            if self.staging is not None:
                ctx["staging_managed"] = True
                ctx["staging"] = TaskStagingView(self.staging, task)
                # always present under management, as the unmanaged
                # kernel path guarantees (kernels index it unconditionally)
                ctx["staged_inputs"] = task.meta.get("staged_in_values",
                                                     [])
            return _k.execute(ctx)

        return run

    def _resolve_ref(self, task: Task, value: Any) -> Any:
        """Top-level staged refs bound to a port dereference to the value
        the stage-in pass landed at this task's pod; refs NESTED inside a
        payload stay lazy (a consumer reading only scalar fields never
        pays for the bulk ones — it derefs via ``ctx["staging"]``)."""
        if self.staging is not None and isinstance(value, StagedRef):
            return self.staging.resolve(task, value)
        return value

    def _bound_inputs_for(self, task: Task, stage: Stage) -> Dict[str, Any]:
        """Concrete port values for one task: channel takes were bound at
        submission (staged refs dereference here, after the executor's
        stage-in pass moved them pod-local); StageFuture ports resolve now
        (their producer tasks are dependencies, so the results are
        complete by execution time)."""
        inputs = {p: self._resolve_ref(task, v)
                  for p, v in stage.bound_inputs.items()}
        for port, fut in stage._future_ports:
            inputs[port] = dict(fut.stage.results)
        for p, v in self._task_bound.get(task.name, {}).items():
            inputs[p] = self._resolve_ref(task, v)
        for port, fut in self._task_futures.get(task.name, ()):
            inputs[port] = dict(fut.stage.results)
        return inputs

    def _build_task(self, spec: TaskSpec, pr: _PipelineRun, stage: Stage,
                    stage_idx: int, j: int, deps: List[str]) -> Task:
        k = spec.kernel
        stage_label = stage.name or f"stage{stage_idx}"
        # stage_idx keeps auto-names unique when a stage NAME repeats
        # across appended cycles (the adaptive extension pattern)
        name = spec.name or f"{pr.name}.{stage_idx:04d}.{stage_label}.{j:05d}"
        port_deps = self._bind_task_ports(spec, pr, name, stage_idx, j)
        all_deps = list(dict.fromkeys(
            [*deps, *stage._port_deps, *port_deps]))
        # deferred import: repro.serving sits above core in the layering
        from repro.serving.sla import resolve_sla
        priority, deadline = resolve_sla(spec)
        t = Task(name=name, run=self._make_run(k, stage),
                 duration=(k.sim_duration or 0.0), slots=k.cores,
                 deps=all_deps, stage=stage_label,
                 instance=int(spec.metadata.get("instance", j)),
                 iteration=int(spec.metadata.get("iteration", 0)),
                 idempotent=k.idempotent, priority=priority)
        t.meta["pipeline"] = pr.name
        if spec.sla is not None:
            t.meta["sla"] = spec.sla
        if deadline is not None:
            t.meta["deadline"] = deadline
        extra = {kk: v for kk, v in spec.metadata.items()
                 if kk not in ("instance", "iteration")}
        if extra:
            t.meta["spec"] = extra
        if self.staging is not None:
            self._build_staging_manifest(t, spec, stage)
        self._kernels[name] = k
        self._task_index[name] = pr
        self._stage_of[name] = stage
        self._spec_of[name] = spec
        return t

    # ------------------------------------------------------------ ports
    def _ensure_flow_loaded(self):
        if self._replayed_puts is None:
            self._replayed_puts, self._replayed_takes = \
                self.runtime.journal.load_flow()

    def _register_channel(self, ch: Channel):
        self._ensure_flow_loaded()
        cur = self.channels.get(ch.name)
        if cur is None:
            if ch.capacity_bytes is not None and self.staging is None:
                # byte budgets meter *staged* payload bytes; without a
                # staging layer no put carries a size and the budget would
                # silently never park anyone
                from repro.analysis.diagnostics import (Diagnostic,
                                                        DiagnosticError)
                raise DiagnosticError([Diagnostic(
                    "E115",
                    f"channel {ch.name!r} declares capacity_bytes="
                    f"{ch.capacity_bytes} but the pilot has no staging "
                    "layer (PilotRuntime(staging=StagingLayer(...))) — "
                    "puts carry no byte sizes to meter")])
            self.channels[ch.name] = ch
            # reserve journaled put->consumer bindings so a replayed take
            # always re-binds to ITS producer, never a FIFO steal
            for (cname, ck), pk in self._replayed_takes.items():
                if cname == ch.name:
                    ch._reserved[pk] = ck
            tr = getattr(self.runtime, "tracer", None)
            if tr is not None:
                tr.metrics.gauge(f"channel_backlog:{ch.name}",
                                 ch.n_unconsumed)
                tr.metrics.gauge(f"channel_backlog_bytes:{ch.name}",
                                 ch.n_unconsumed_bytes)
        elif cur is not ch:
            raise ValueError(
                f"two different Channel objects named {ch.name!r} on one "
                "AppManager")

    def _iter_bindings(self, stage: Stage, pr: _PipelineRun, idx: int):
        """Yield (consumer_key, stream, port, source, task_j) for every
        declared input of the stage and its task specs.  The *stream* id
        omits the stage index: a pipeline's successive bindings of one
        port form one broadcast cursor."""
        for port, src in flow.normalize_sources(stage.inputs).items():
            yield (f"{pr.name}:{idx:04d}:{port}",
                   f"{pr.name}:{port}", port, src, None)
        for j, spec in enumerate(stage.tasks):
            for port, src in flow.normalize_sources(spec.inputs).items():
                yield (f"{pr.name}:{idx:04d}:{j:05d}:{port}",
                       f"{pr.name}:{j:05d}:{port}", port, src, j)

    def _input_blocker(self, stage: Stage, pr: _PipelineRun, idx: int):
        """First unsatisfiable input — or full output channel
        (back-pressure) — as ``(parking_key, description)``; None when the
        stage can submit right now."""
        fresh: Dict[str, int] = {}
        own_takes: Dict[str, int] = {}    # this stage's own consumption
        for ck, stream, port, src, _j in self._iter_bindings(stage, pr,
                                                             idx):
            if isinstance(src, Channel):
                self._register_channel(src)
                src.touch(stream)
                own_takes[src.name] = own_takes.get(src.name, 0) + 1
                pk = self._replayed_takes.get((src.name, ck))
                if pk is not None:
                    i = src._index.get(pk)
                    if i is None or (src.mode != "broadcast"
                                     and i in src._taken):
                        return (("channel", src.name),
                                f"channel:{src.name}")
                elif src.mode == "broadcast":
                    if src.n_available(ck, stream) < 1:
                        return (("channel", src.name),
                                f"channel:{src.name}")
                else:
                    fresh[src.name] = fresh.get(src.name, 0) + 1
            elif isinstance(src, StageFuture):
                if not src.submitted:
                    return (("future", id(src.stage)),
                            f"stage:{getattr(src.stage, 'name', '?')}")
            else:
                raise TypeError(f"input port {port!r}: expected Channel or "
                                f"StageFuture, got {type(src).__name__}")
        for cname, n in fresh.items():
            if self.channels[cname].n_available("") < n:
                return (("channel", cname), f"channel:{cname}")
        # back-pressure: park the producer when admitting this stage would
        # leave the channel above `capacity` unconsumed puts — or above
        # `capacity_bytes` unconsumed payload bytes — counting what the
        # stage itself will emit (a stage of N task-level outputs bursts
        # N puts between blocker checks; emitted bytes come from the
        # kernels' declared output_nbytes, resolved before this runs).
        # Two carve-outs keep progress: the stage's OWN takes from that
        # channel are credited (a feedback stage consuming and producing
        # one bounded channel must not deadlock on itself), and a fully
        # drained channel always admits one stage even when its burst
        # alone exceeds the limit.
        emits: Dict[str, int] = {}
        emit_bytes: Dict[str, int] = {}
        stage_nbytes = sum(int(getattr(s.kernel, "output_nbytes", 0) or 0)
                           for s in stage.tasks)
        for ch in flow.normalize_outputs(stage.outputs):
            self._register_channel(ch)
            emits[ch.name] = emits.get(ch.name, 0) + 1
            emit_bytes[ch.name] = emit_bytes.get(ch.name, 0) + stage_nbytes
        for s in stage.tasks:
            for ch in flow.normalize_outputs(s.outputs):
                self._register_channel(ch)
                emits[ch.name] = emits.get(ch.name, 0) + 1
                emit_bytes[ch.name] = emit_bytes.get(ch.name, 0) + \
                    int(getattr(s.kernel, "output_nbytes", 0) or 0)
        for name, n_emit in emits.items():
            ch = self.channels[name]
            if ch.capacity is not None:
                backlog = ch.n_unconsumed() - own_takes.get(name, 0)
                if backlog > 0 and backlog + n_emit > ch.capacity:
                    return (("channel_space", name),
                            f"channel_space:{name}")
            if ch.capacity_bytes is not None:
                credit = self._own_take_byte_credit(
                    ch, own_takes.get(name, 0))
                backlog_b = ch.n_unconsumed_bytes() - credit
                if backlog_b > 0 and \
                        backlog_b + emit_bytes[name] > ch.capacity_bytes:
                    return (("channel_space", name),
                            f"channel_space:{name}")
        return None

    @staticmethod
    def _own_take_byte_credit(ch: Channel, n_takes: int) -> int:
        """Bytes of the puts this stage's own takes are about to retire
        (fifo binds the oldest candidates) — credited against the byte
        backlog so a self-feeding stage cannot park on its own input."""
        if n_takes <= 0 or ch.mode == "broadcast":
            return 0
        credit = 0
        for idx in ch._fifo_candidates(""):
            credit += ch._byte_prefix[idx + 1] - ch._byte_prefix[idx]
            n_takes -= 1
            if n_takes == 0:
                break
        return credit

    def _take(self, ch: Channel, ck: str, stream: Optional[str] = None,
              n_consumers: int = 1) -> Any:
        pk = self._replayed_takes.get((ch.name, ck))
        producer, value = ch.take(ck, pk, stream)
        is_ref = isinstance(value, StagedRef)
        self.runtime.journal.record_flow(
            "channel_take", ch.name, producer, consumer=ck,
            digest=value.digest if is_ref else None)
        if self.staging is not None and is_ref:
            self.staging.on_take(value, n_consumers=n_consumers,
                                 broadcast=ch.mode == "broadcast")
        # a take frees channel space: wake producers parked on capacity
        self._wake(("channel_space", ch.name))
        return value

    def _bind_stage_inputs(self, stage: Stage, pr: _PipelineRun, idx: int):
        stage.bound_inputs = {}
        stage._future_ports = []
        stage._port_deps = []
        for port, src in flow.normalize_sources(stage.inputs).items():
            if isinstance(src, Channel):
                ck = f"{pr.name}:{idx:04d}:{port}"
                stage.bound_inputs[port] = self._take(
                    src, ck, f"{pr.name}:{port}",
                    n_consumers=len(stage.tasks))
            else:
                stage._future_ports.append((port, src))
                stage._port_deps.extend(src.stage.task_names)

    def _bind_task_ports(self, spec: TaskSpec, pr: _PipelineRun, name: str,
                         idx: int, j: int) -> List[str]:
        port_deps: List[str] = []
        for port, src in flow.normalize_sources(spec.inputs).items():
            if isinstance(src, Channel):
                ck = f"{pr.name}:{idx:04d}:{j:05d}:{port}"
                self._task_bound.setdefault(name, {})[port] = \
                    self._take(src, ck, f"{pr.name}:{j:05d}:{port}")
            else:
                self._task_futures.setdefault(name, []).append((port, src))
                port_deps.extend(src.stage.task_names)
        return port_deps

    # ------------------------------------------------------------ staging
    def _build_staging_manifest(self, t: Task, spec: TaskSpec,
                                stage: Stage):
        """Collect the task's staged refs (bound channel payloads +
        stage_in declarations) into ``task.meta["staged_refs"]`` — the
        executor's stage-in pass transfers them to the task's granted pod
        between ``pop_ready`` and kernel launch."""
        for port, v in stage.bound_inputs.items():
            if isinstance(v, StagedRef):
                self.staging.manifest_input(t, port, v)
        for port, v in self._task_bound.get(t.name, {}).items():
            if isinstance(v, StagedRef):
                self.staging.manifest_input(t, port, v)
        for item in [*stage.stage_in, *(spec.stage_in or ())]:
            self.staging.acquire_stage_in(t, item)

    def _producer_hints(self, task_names):
        """(locations, declared nbytes) of a completed producer stage —
        where its members ran (each member's piece is replicated there)
        and, for DES mode, how big the combined payload is declared."""
        if self.staging is None:
            return [], 0
        locs: List[str] = []
        nbytes = 0
        for nm in task_names or ():
            task = self.session.graph.tasks.get(nm) if self.session else \
                None
            if task is not None:
                loc = self.staging.location_for(task)
                if loc not in locs:
                    locs.append(loc)
            k = self._kernels.get(nm)
            if k is not None and k.output_nbytes:
                nbytes += int(k.output_nbytes)
        return locs, nbytes

    def _run_stage_out(self, outs, payload):
        """Invoke stage_out callables (the legacy download_output_data
        path under staging management), charged to t_data.  Real mode
        only — DES tasks execute nothing, so there is no result to stage
        out (and a callable would crash on the None placeholder)."""
        if self.runtime.mode != "real":
            return
        callables = [d for d in (outs or ()) if callable(d)]
        if not callables:
            return
        t0 = time.perf_counter()
        for d in callables:
            d(payload)
        self.profile.t_data += time.perf_counter() - t0

    def _put(self, ch: Channel, pk: str, fresh_value, *,
             task_level: bool = False, nbytes_hint: int = 0,
             locations=()):
        """The one put-with-replay protocol: journaled values override the
        freshly computed one, the put is recorded, waiters wake.  With a
        staging layer, large fresh payloads are staged and the REF is what
        travels (journaled with its digest, so restarts replay refs
        without re-staging); in DES mode a declared ``nbytes_hint`` stages
        a virtual ref so t_data is modeled without payloads."""
        self._register_channel(ch)
        if ch.has_put(pk):
            return
        value = self._replayed_puts.get((ch.name, pk), _MISSING)
        replayed = value is not _MISSING
        if not replayed:
            value = fresh_value
        elif self.staging is not None:
            value = decode_refs(value)
        check = self.runtime.mode == "real"
        if self.staging is not None and not replayed:
            if check and not isinstance(value, StagedRef):
                ch.check(value, task_level=task_level)   # pre-staging
                check = False
                value = self.staging.stage_payload(value, list(locations))
            elif self.runtime.mode == "sim" and nbytes_hint:
                ref = self.staging.stage_virtual(
                    f"{ch.name}:{pk}", nbytes_hint, list(locations))
                if ref is not None:
                    value = ref
        is_ref = isinstance(value, StagedRef)
        ch.put(pk, value, task_level=task_level,
               check=check and not is_ref,
               nbytes=value.nbytes if is_ref else int(nbytes_hint or 0))
        # a journaled ref is only replayable when its payload outlives the
        # process: a write-through spill file (real mode) or virtual-ref
        # metadata (sim).  Otherwise journal the payload itself, so a
        # restart replays by value (and re-stages fresh)
        ref_durable = is_ref and (
            self.runtime.mode == "sim"
            or self.staging.store.spill_dir is not None)
        if is_ref and not ref_durable:
            journal_value = fresh_value
        elif self.staging is not None:
            journal_value = encode_refs(value)
        else:
            journal_value = value
        self.runtime.journal.record_flow(
            "channel_put", ch.name, pk, value=journal_value,
            digest=value.digest if is_ref else None,
            nbytes=value.nbytes if is_ref else None,
            mode=ch.mode)
        self._wake(("channel", ch.name))

    def _emit_outputs(self, stage: Stage, pr: _PipelineRun, idx: int):
        """Stage completed: put its {task: result} dict on every declared
        output channel."""
        outs = flow.normalize_outputs(stage.outputs)
        if self.staging is not None and stage.stage_out and any(
                self.session.graph.tasks[nm].attempts
                for nm in stage.task_names or ()):
            # skipped when the whole stage replayed from the journal:
            # its downloads ran before the restart
            self._run_stage_out(stage.stage_out, dict(stage.results))
        if not outs:
            return
        locations, nbytes = self._producer_hints(stage.task_names)
        for ch in outs:
            self._put(ch, f"{pr.name}:{idx:04d}", dict(stage.results),
                      nbytes_hint=nbytes, locations=locations)

    def _emit_task_outputs(self, task: Task, spec: TaskSpec):
        outs = flow.normalize_outputs(spec.outputs)
        if not outs:
            return
        locations, nbytes = self._producer_hints([task.name])
        for ch in outs:
            self._put(ch, task.name, task.result, task_level=True,
                      nbytes_hint=nbytes, locations=locations)

    def _wake(self, key):
        """Re-attempt submission of pipelines parked on ``key`` (they
        re-park on their next unsatisfied input, if any).  Only "waiting"
        pipelines wake: a pipeline marked "blocked" belongs to a drained
        session whose task graph is gone — resubmitting its stages into a
        later run's fresh session would reference dead dependency names.

        Wakes raised while another pipeline is mid-submission queue up and
        drain when the outermost submission returns (see ``_advance_depth``
        above)."""
        self._pending_wakes.append(key)
        if self._advance_depth == 0:
            self._drain_wakes()

    def _drain_wakes(self):
        while self._pending_wakes:
            key = self._pending_wakes.pop(0)
            for pr in self._parked.pop(key, []):
                if pr.state == "waiting":
                    self._submit_next_stage(pr, dynamic=True)

    # ------------------------------------------------------------ advance
    def _resolve_kernels(self, stage: Stage, pr: _PipelineRun, idx: int):
        """Resolve named-kernel specs (``TaskSpec(kernel="...")``) to
        Kernel instances, applying the staging defaults the dataclass
        deferred; an unknown name raises E107 with its full pipeline/
        stage/task location — at submit time, before any task of the
        stage (or of a stage parked behind it) launches."""
        from repro.core.kernel_plugin import kernel_registered
        from repro.serving.sla import CLASSES
        for j, spec in enumerate(stage.tasks):
            if spec.sla is not None and spec.sla not in CLASSES:
                from repro.analysis.diagnostics import (Diagnostic,
                                                        DiagnosticError)
                raise DiagnosticError([Diagnostic(
                    "E115",
                    f"unknown SLA class {spec.sla!r} (known: "
                    f"{', '.join(sorted(CLASSES))})",
                    pipeline=pr.name, stage=idx,
                    task=spec.name or f"{stage.name or idx}[{j}]")])
            if not isinstance(spec.kernel, str):
                continue
            kname = spec.kernel
            if not kernel_registered(kname):
                from repro.analysis.diagnostics import (Diagnostic,
                                                        DiagnosticError)
                raise DiagnosticError([Diagnostic(
                    "E107",
                    f"kernel {kname!r} matches no registered plugin "
                    "(kernel_names() lists the registry)",
                    pipeline=pr.name, stage=idx,
                    task=spec.name or f"{stage.name or idx}[{j}]")])
            spec.kernel = Kernel(kname)
            if spec.stage_in is None:
                spec.stage_in = spec.kernel.upload_input_data
            if spec.stage_out is None:
                spec.stage_out = spec.kernel.download_output_data

    def _submit_next_stage(self, pr: _PipelineRun, *, dynamic: bool):
        self._advance_depth += 1
        try:
            self._submit_next_stage_inner(pr, dynamic=dynamic)
        finally:
            self._advance_depth -= 1
        if self._advance_depth == 0:
            self._drain_wakes()

    def _submit_next_stage_inner(self, pr: _PipelineRun, *, dynamic: bool):
        """Submit pr's next stage; parks the pipeline when its inputs are
        not yet satisfiable; skips through empty (control-only) stages,
        firing their on_done inline."""
        while True:
            nxt = pr.idx + 1
            if nxt >= len(pr.spec.stages):
                pr.state = "done"
                return
            stage = pr.spec.stages[nxt]
            self._resolve_kernels(stage, pr, nxt)
            if self.staging is None and (stage.stage_in or stage.stage_out):
                # stage-level declarations have no kernel-side fallback
                # (unlike TaskSpec's, which default FROM the kernel's own
                # upload/download lists) — ignoring them silently would
                # drop declared inputs
                raise ValueError(
                    f"stage {stage.name!r} declares stage_in/stage_out "
                    "but the pilot has no staging layer "
                    "(PilotRuntime(staging=StagingLayer(...)))")
            blocker = self._input_blocker(stage, pr, nxt)
            if blocker is not None:
                key, desc = blocker
                pr.state = "waiting"
                pr.waiting_on = desc
                self._parked.setdefault(key, []).append(pr)
                self._note_park(pr, desc)
                return
            pr.idx = nxt
            pr.state = "running"
            pr.waiting_on = None
            self._note_unpark(pr)
            self._bind_stage_inputs(stage, pr, nxt)
            deps = pr.stage_task_names[-1] if pr.stage_task_names else []
            tasks = [self._build_task(spec, pr, stage, nxt, j, deps)
                     for j, spec in enumerate(stage.tasks)]
            stage.task_names = [t.name for t in tasks]
            if tasks:
                pr.pending = set(stage.task_names)
                pr.stage_task_names.append(list(stage.task_names))
                self.session.submit(tasks, dynamic=dynamic)
                # consumers waiting on this stage's submission (futures)
                self._wake(("future", id(stage)))
                return
            # empty stage: pure control point — emit, fire on_done, continue
            self._wake(("future", id(stage)))
            self._emit_outputs(stage, pr, nxt)
            self._fire_on_done(stage, pr)

    def _note_park(self, pr: _PipelineRun, desc: str):
        """Journal + trace a pipeline parking on an unsatisfiable input
        (span opens; :meth:`_note_unpark` closes it at the advance).  A
        pipeline still parked at drain end keeps an open span — the
        truncated-span convention, same as a preempted attempt."""
        pr._was_parked = True
        now = self.session._now() if self.session is not None else 0.0
        self.runtime.journal.record_event(
            "pipeline_parked", pipeline=pr.name, on=desc)
        tr = getattr(self.runtime, "tracer", None)
        if tr is not None:
            tr.begin(("park", pr.name), "park", pr.name, now,
                     pipeline=pr.name, on=desc)
            tr.metrics.inc("pipeline_parks")

    def _note_unpark(self, pr: _PipelineRun):
        if not getattr(pr, "_was_parked", False):
            return
        pr._was_parked = False
        now = self.session._now() if self.session is not None else 0.0
        self.runtime.journal.record_event("pipeline_woken",
                                          pipeline=pr.name)
        tr = getattr(self.runtime, "tracer", None)
        if tr is not None:
            tr.end(("park", pr.name), now, "woken")

    def _fire_on_done(self, stage: Stage, pr: _PipelineRun):
        if stage.on_done is None:
            return
        t0 = time.perf_counter()
        appended = stage.on_done(stage, pr.spec)
        if appended:
            pr.spec.extend(appended)
        self.profile.t_pattern_overhead += time.perf_counter() - t0

    def _on_task(self, task: Task, session):
        pr = self._task_index.get(task.name)
        if pr is None:
            return
        stage = self._stage_of[task.name]
        prof = self.profile
        if task.attempts:                 # executed (possibly failed): its
            k = self._kernels[task.name]  # staging/exec time is real cost
            prof.t_data += k.timings["data_in"] + k.timings["data_out"]
        st = prof.per_stage.setdefault(task.stage, {"n": 0, "t_exec": 0.0})
        st["n"] += 1
        st["t_exec"] += (task.duration if self.runtime.mode == "sim"
                         else max(task.t_finished - task.t_started
                                  - task.meta.get("t_data_kernel", 0.0),
                                  0.0))
        if task.t_data:
            st["t_data"] = st.get("t_data", 0.0) + task.t_data
        if task.state == TaskState.DONE:
            stage.results[task.name] = task.result
            prof.results.setdefault("tasks", {})[task.name] = task.result
            spec = self._spec_of[task.name]
            if self.staging is not None and task.attempts:
                # the kernel skipped its own download phase (staging
                # manages data movement): run the declarations here —
                # but NOT for journal-replayed tasks (attempts == 0),
                # whose downloads ran before the restart
                self._run_stage_out(spec.stage_out, task.result)
            self._emit_task_outputs(task, spec)
        else:
            stage.n_failed += 1
        pr.pending.discard(task.name)
        if pr.pending:
            return
        # stage complete
        if stage.n_failed:
            pr.state = "failed"
            return
        self._emit_outputs(stage, pr, pr.idx)    # puts before adaptivity
        self._fire_on_done(stage, pr)
        self._submit_next_stage(pr, dynamic=True)
        if self.strategy is not None:
            self._apply_strategy()

    # ------------------------------------------------------------ adaptive
    def _apply_strategy(self):
        """Feed the adaptive strategy from LIVE per-pipeline queue depth
        (submitted-but-not-started tasks), within the running session."""
        graph = self.session.graph
        backlogs = {
            p.name: sum(1 for nm in p.pending
                        if graph.tasks[nm].state == TaskState.NEW)
            for p in self.pipeline_runs.values()
            if p.state in ("running", "waiting")}
        backlog = sum(backlogs.values())
        slots = max(self.runtime.slots, 1)
        # demand-aware utilization: busy slots plus the queued work that
        # could fill them now (instantaneous busy alone reads 0 at a stage
        # boundary and would always vote shrink)
        utilization = min(1.0, (self.session.busy_slots + backlog) / slots)
        self.strategy.apply(self.pilot or self.runtime,
                            utilization=utilization, backlog=backlog,
                            per_pipeline=backlogs)

    # ------------------------------------------------------------ faults
    def _failure_counts(self, pr) -> Dict[str, int]:
        """Per-pipeline fault accounting read back from ``Task.history``:
        which ensemble members failed, how often they retried, and how
        many attempts a pod/worker death cost them."""
        tasks = self.session.graph.tasks
        n_failed = n_retries = n_pod_lost = 0
        for names in pr.stage_task_names:
            for nm in names:
                t = tasks.get(nm)
                if t is None:
                    continue
                if t.state == TaskState.FAILED:
                    n_failed += 1
                n_retries += max(t.attempts - 1, 0)
                n_pod_lost += sum(
                    1 for h in t.history
                    if h["outcome"] in ("pod_lost", "worker_died",
                                        "heartbeat_timeout"))
        return {"n_failed": n_failed, "n_retries": n_retries,
                "n_pod_lost": n_pod_lost}

    # ------------------------------------------------------------ run
    def run(self, pipelines: Union[PipelineSpec, Iterable[PipelineSpec]],
            *, validate: str = "warn") -> ExecutionProfile:
        """Execute the pipelines to completion; returns the aggregate
        profile (cumulative if a profile was passed in).

        ``validate`` gates the pre-flight linter (repro.analysis) run over
        the declared specs BEFORE any task launches: ``"error"`` raises
        :class:`~repro.analysis.diagnostics.DiagnosticError` on any E-code
        finding (nothing is submitted), ``"warn"`` (default) prints a
        one-line summary to stderr and proceeds, ``"off"`` skips the pass.
        The full report lands in ``profile.results["diagnostics"]``."""
        if validate not in ("error", "warn", "off"):
            raise ValueError(f"validate={validate!r}: "
                             "expected 'error', 'warn' or 'off'")
        pipes = ([pipelines] if isinstance(pipelines, PipelineSpec)
                 else list(pipelines))
        prof = self.profile
        if validate != "off":
            from repro.analysis.validate import validate_app
            report = validate_app(
                pipes, runtime=self.runtime, channels=dict(self.channels),
                existing_pipelines=list(self.pipeline_runs))
            prof.results["diagnostics"] = [str(d) for d in
                                           report.diagnostics]
            if validate == "error":
                report.raise_if_errors()
            elif not report.ok:
                import sys
                print(f"repro.analysis: {len(report.errors)} error(s), "
                      f"{len(report.warnings)} warning(s) in submitted "
                      "pipelines (validate='warn'; see "
                      "profile.results['diagnostics'])", file=sys.stderr)
        t0 = time.perf_counter()
        runs = []
        for p in pipes:
            name = p.name or f"p{len(self.pipeline_runs):04d}"
            if name in self.pipeline_runs:
                raise ValueError(f"duplicate pipeline name {name!r}")
            pr = _PipelineRun(p, name)
            self.pipeline_runs[name] = pr
            runs.append(pr)
        prof.t_pattern_overhead += time.perf_counter() - t0

        self.session = self.runtime.session(on_task_done=self._on_task)
        for pr in runs:
            self._submit_next_stage(pr, dynamic=False)
        rp = self.session.drain()

        # pipelines still parked when the session drained can never wake
        for pr in self.pipeline_runs.values():
            if pr.state == "waiting":
                pr.state = "blocked"

        prof.ttc += rp.ttc
        prof.t_exec += rp.t_exec
        prof.t_data += rp.t_data          # staged-ref transfer seconds
        prof.t_rts_overhead += rp.t_rts_overhead
        prof.n_tasks += rp.n_tasks
        prof.n_failed += rp.n_failed
        prof.n_canceled += rp.n_canceled
        prof.n_retries += rp.n_retries
        prof.n_speculative += rp.n_speculative
        prof.n_pod_lost += rp.n_pod_lost
        prof.n_preempted += rp.n_preempted
        prof.slot_busy += rp.slot_busy
        # utilization over the WHOLE session: busy slot-seconds / available
        # slot-seconds (accumulated, then computed once — not per cycle)
        prof.utilization = prof.slot_busy / (
            max(prof.ttc, 1e-12) * max(self.runtime.slots, 1))
        prof.results["pipelines"] = {
            pr.name: {"state": pr.state,
                      "n_stages": len(pr.spec.stages),
                      "n_tasks": sum(len(ns) for ns in pr.stage_task_names),
                      **self._failure_counts(pr),
                      **({"waiting_on": pr.waiting_on}
                         if pr.state == "blocked" else {})}
            for pr in self.pipeline_runs.values()}
        if self.staging is not None:
            prof.results["staging"] = self.staging.summary()
        if getattr(self.runtime, "pilots", None) is not None:
            # federated runtime (repro.federation.Fleet): fleet shape,
            # recruiter activity, and where the dispatcher sent the work
            dispatch: Dict[str, int] = {}
            for t in self.session.graph.tasks.values():
                p = t.meta.get("pilot")
                if p is not None:
                    dispatch[p] = dispatch.get(p, 0) + 1
            prof.results["federation"] = {**self.runtime.summary(),
                                          "dispatch": dispatch}
        tr = getattr(self.runtime, "tracer", None)
        if tr is not None:
            prof.results["timeseries"] = tr.timeseries()
            prof.results["trace"] = tr.summary()
        return prof
