"""Composable Pipeline-Stage-Task (PST) workflow API.

The seed mirrored the 2016 toolkit's subclass-hook pattern API
(``stage_1..stage_M`` via getattr, ``prepare_*`` overrides).  The second
generation toolkit ("Harnessing the Power of Many", arXiv:1710.08491)
replaced those hardcoded patterns with composable *data objects* because the
hook API structurally cannot express adaptive or coupled ensembles.  This
module is that redesign:

  TaskSpec      one executable unit: a bound Kernel + placement metadata.
  Stage         a set of concurrent TaskSpecs + an ``on_done`` adaptivity
                callback that may append stages or mutate the downstream
                pipeline when the stage completes.
  PipelineSpec  an ordered list of Stages; stage k+1 starts when stage k
                finishes (a per-pipeline barrier — never a global one).
  AppManager    executes many pipelines concurrently over ONE long-lived
                PilotRuntime session (runtime/executor.RuntimeSession) with
                dynamic task injection: when a stage of pipeline A
                completes, A's next stage is submitted immediately, while
                pipeline B's tasks are still running.

Quickstart::

    sim = Stage([TaskSpec(k) for k in member_kernels], name="sim")
    def adapt(stage, pipe):
        if needs_more_sampling(stage.results):
            pipe.add_stage(make_refinement_stage(stage.results))
    ana = Stage([TaskSpec(ana_kernel)], name="analysis", on_done=adapt)
    profile = AppManager(pilot).run([PipelineSpec([sim, ana], name="e0"),
                                     PipelineSpec([...], name="e1")])

The legacy patterns (Pipeline, BagOfTasks, ReplicaExchange,
SimulationAnalysisLoop) still work: their execution plugins are now thin
compilers from the hook API to PST (see core/execution_plugin.py).

Placement: tasks land on mesh slots via ``PilotRuntime.submesh_for`` — in
real mode a kernel's ``ctx["submesh"]`` is the jax Mesh over the devices of
the slots the scheduler granted to its task (requires the runtime to be
built with a ``SlotTopology``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Union

from repro.core.kernel_plugin import Kernel
from repro.runtime.states import Task, TaskState


@dataclass
class ExecutionProfile:
    """Paper eq. (1)-(2): TTC = T_exec + T_data + T_EnMD(core+pattern+rts)."""
    ttc: float = 0.0
    t_exec: float = 0.0
    t_data: float = 0.0
    t_core_overhead: float = 0.0
    t_pattern_overhead: float = 0.0
    t_rts_overhead: float = 0.0
    n_tasks: int = 0
    n_failed: int = 0
    n_canceled: int = 0
    n_retries: int = 0
    n_speculative: int = 0
    # busy slot-seconds accumulate here so utilization can be computed over
    # the WHOLE run at the end (not overwritten per cycle — that bug made
    # RE/SAL report only the last cycle's utilization)
    slot_busy: float = 0.0
    utilization: float = 0.0
    per_stage: Dict[str, Dict[str, float]] = field(default_factory=dict)
    results: Dict[str, Any] = field(default_factory=dict)

    @property
    def t_enmd_overhead(self) -> float:
        return (self.t_core_overhead + self.t_pattern_overhead
                + self.t_rts_overhead)

    def summary(self) -> Dict[str, float]:
        return {"ttc": self.ttc, "t_exec": self.t_exec,
                "t_data": self.t_data,
                "t_core_overhead": self.t_core_overhead,
                "t_pattern_overhead": self.t_pattern_overhead,
                "t_rts_overhead": self.t_rts_overhead,
                "n_tasks": self.n_tasks, "n_failed": self.n_failed,
                "utilization": self.utilization}


# ------------------------------------------------------------------ objects

@dataclass
class TaskSpec:
    """Kernel + slots + metadata: what to run, how wide, and labels.

    ``name`` (optional) becomes the runtime task name verbatim — callers
    providing names are responsible for global uniqueness; unnamed specs get
    ``<pipeline>.<stage_idx>.<stage>.<index>`` (unique even when adaptive
    extension reuses a stage name).  Slot width comes from ``kernel.cores``.
    ``metadata`` keys ``instance`` and ``iteration`` land on the Task record
    (profiling labels); everything else rides along in ``task.meta``.
    """
    kernel: Kernel
    name: str = ""
    metadata: Dict[str, Any] = field(default_factory=dict)


class Stage:
    """A set of concurrent tasks; completes when all of them are terminal.

    ``on_done(stage, pipeline)`` fires once at completion (only if no task
    failed) and may mutate the downstream graph: append stages via
    ``pipeline.add_stage`` / ``pipeline.extend`` or return an iterable of
    new stages.  ``stage.results`` maps task name -> result.
    """

    def __init__(self, tasks: Iterable[Union[TaskSpec, Kernel]] = (), *,
                 name: str = "",
                 on_done: Optional[Callable[["Stage", "PipelineSpec"],
                                            Any]] = None):
        self.name = name
        self.tasks: List[TaskSpec] = [
            t if isinstance(t, TaskSpec) else TaskSpec(t) for t in tasks]
        self.on_done = on_done
        self.results: Dict[str, Any] = {}
        self.n_failed = 0

    def add(self, task: Union[TaskSpec, Kernel]) -> TaskSpec:
        spec = task if isinstance(task, TaskSpec) else TaskSpec(task)
        self.tasks.append(spec)
        return spec

    def __repr__(self):
        return f"Stage({self.name!r}, {len(self.tasks)} tasks)"


class PipelineSpec:
    """Ordered stages executed with a per-pipeline barrier between them.

    The stage list may grow while the pipeline runs (adaptivity): appending
    from an ``on_done`` callback extends this pipeline without touching any
    other pipeline running on the same AppManager.
    """

    def __init__(self, stages: Iterable[Stage] = (), *, name: str = ""):
        self.name = name
        self.stages: List[Stage] = list(stages)

    def add_stage(self, stage: Stage) -> Stage:
        self.stages.append(stage)
        return stage

    def extend(self, stages: Iterable[Stage]):
        self.stages.extend(stages)

    def __repr__(self):
        return f"PipelineSpec({self.name!r}, {len(self.stages)} stages)"


# ------------------------------------------------------------------ manager

class _PipelineRun:
    """Execution-time state of one pipeline on an AppManager."""

    def __init__(self, spec: PipelineSpec, name: str):
        self.spec = spec
        self.name = name
        self.idx = -1                 # index of the currently running stage
        self.state = "pending"        # pending | running | done | failed
        self.pending: set = set()     # outstanding task names, current stage
        self.stage_task_names: List[List[str]] = []


class AppManager:
    """Run many PST pipelines concurrently over one pilot session.

    Accepts a ``Pilot`` (core.resource_handler) or a bare ``PilotRuntime``.
    All pipelines share the runtime's slots; each advances independently —
    stage k+1 of pipeline A is injected into the live session the moment
    stage k completes, regardless of what B is doing (no global barrier, no
    per-cycle graph teardown).
    """

    def __init__(self, pilot, *, profile: Optional[ExecutionProfile] = None):
        if hasattr(pilot, "runtime"):
            self.pilot = pilot
            self.runtime = pilot.runtime
        else:
            self.pilot = None
            self.runtime = pilot
        self.profile = profile if profile is not None else ExecutionProfile()
        self._kernels: Dict[str, Kernel] = {}
        self._task_index: Dict[str, _PipelineRun] = {}
        self._stage_of: Dict[str, Stage] = {}
        self.session = None            # live RuntimeSession while running
        self.pipeline_runs: Dict[str, _PipelineRun] = {}

    # ------------------------------------------------------------ build
    def _make_run(self, kernel: Kernel):
        if self.runtime.mode != "real":
            return None

        def run(task: Task, _k=kernel):
            ctx = {"pilot": self.pilot, "runtime": self.runtime,
                   "task": task,
                   "dep_results": task.meta.get("dep_results", {})}
            if self.runtime.topology is not None \
                    and task.meta.get("slot_ids"):
                ctx["submesh"] = self.runtime.submesh_for(task)
            return _k.execute(ctx)

        return run

    def _build_task(self, spec: TaskSpec, pr: _PipelineRun, stage: Stage,
                    stage_idx: int, j: int, deps: List[str]) -> Task:
        k = spec.kernel
        stage_label = stage.name or f"stage{stage_idx}"
        # stage_idx keeps auto-names unique when a stage NAME repeats
        # across appended cycles (the adaptive extension pattern)
        name = spec.name or f"{pr.name}.{stage_idx:04d}.{stage_label}.{j:05d}"
        t = Task(name=name, run=self._make_run(k),
                 duration=(k.sim_duration or 0.0), slots=k.cores,
                 deps=list(deps), stage=stage_label,
                 instance=int(spec.metadata.get("instance", j)),
                 iteration=int(spec.metadata.get("iteration", 0)),
                 idempotent=k.idempotent)
        t.meta["pipeline"] = pr.name
        extra = {kk: v for kk, v in spec.metadata.items()
                 if kk not in ("instance", "iteration")}
        if extra:
            t.meta["spec"] = extra
        self._kernels[name] = k
        self._task_index[name] = pr
        self._stage_of[name] = stage
        return t

    # ------------------------------------------------------------ advance
    def _submit_next_stage(self, pr: _PipelineRun, *, dynamic: bool):
        """Submit pr's next stage; skips through empty (control-only)
        stages, firing their on_done inline."""
        while True:
            pr.idx += 1
            if pr.idx >= len(pr.spec.stages):
                pr.state = "done"
                return
            pr.state = "running"
            stage = pr.spec.stages[pr.idx]
            deps = pr.stage_task_names[-1] if pr.stage_task_names else []
            tasks = [self._build_task(spec, pr, stage, pr.idx, j, deps)
                     for j, spec in enumerate(stage.tasks)]
            if tasks:
                pr.pending = {t.name for t in tasks}
                pr.stage_task_names.append([t.name for t in tasks])
                self.session.submit(tasks, dynamic=dynamic)
                return
            # empty stage: pure control point — fire on_done and continue
            self._fire_on_done(stage, pr)

    def _fire_on_done(self, stage: Stage, pr: _PipelineRun):
        if stage.on_done is None:
            return
        t0 = time.perf_counter()
        appended = stage.on_done(stage, pr.spec)
        if appended:
            pr.spec.extend(appended)
        self.profile.t_pattern_overhead += time.perf_counter() - t0

    def _on_task(self, task: Task, session):
        pr = self._task_index.get(task.name)
        if pr is None:
            return
        stage = self._stage_of[task.name]
        prof = self.profile
        if task.attempts:                 # executed (possibly failed): its
            k = self._kernels[task.name]  # staging/exec time is real cost
            prof.t_data += k.timings["data_in"] + k.timings["data_out"]
        st = prof.per_stage.setdefault(task.stage, {"n": 0, "t_exec": 0.0})
        st["n"] += 1
        st["t_exec"] += (task.duration if self.runtime.mode == "sim"
                         else max(task.t_finished - task.t_started, 0.0))
        if task.state == TaskState.DONE:
            stage.results[task.name] = task.result
            prof.results.setdefault("tasks", {})[task.name] = task.result
        else:
            stage.n_failed += 1
        pr.pending.discard(task.name)
        if pr.pending:
            return
        # stage complete
        if stage.n_failed:
            pr.state = "failed"
            return
        self._fire_on_done(stage, pr)
        self._submit_next_stage(pr, dynamic=True)

    # ------------------------------------------------------------ run
    def run(self, pipelines: Union[PipelineSpec, Iterable[PipelineSpec]]
            ) -> ExecutionProfile:
        """Execute the pipelines to completion; returns the aggregate
        profile (cumulative if a profile was passed in)."""
        pipes = ([pipelines] if isinstance(pipelines, PipelineSpec)
                 else list(pipelines))
        t0 = time.perf_counter()
        prof = self.profile
        runs = []
        for p in pipes:
            name = p.name or f"p{len(self.pipeline_runs):04d}"
            if name in self.pipeline_runs:
                raise ValueError(f"duplicate pipeline name {name!r}")
            pr = _PipelineRun(p, name)
            self.pipeline_runs[name] = pr
            runs.append(pr)
        prof.t_pattern_overhead += time.perf_counter() - t0

        self.session = self.runtime.session(on_task_done=self._on_task)
        for pr in runs:
            self._submit_next_stage(pr, dynamic=False)
        rp = self.session.drain()

        prof.ttc += rp.ttc
        prof.t_exec += rp.t_exec
        prof.t_rts_overhead += rp.t_rts_overhead
        prof.n_tasks += rp.n_tasks
        prof.n_failed += rp.n_failed
        prof.n_canceled += rp.n_canceled
        prof.n_retries += rp.n_retries
        prof.n_speculative += rp.n_speculative
        prof.slot_busy += rp.slot_busy
        # utilization over the WHOLE session: busy slot-seconds / available
        # slot-seconds (accumulated, then computed once — not per cycle)
        prof.utilization = prof.slot_busy / (
            max(prof.ttc, 1e-12) * max(self.runtime.slots, 1))
        prof.results["pipelines"] = {
            pr.name: {"state": pr.state,
                      "n_stages": len(pr.spec.stages),
                      "n_tasks": sum(len(ns) for ns in pr.stage_task_names)}
            for pr in self.pipeline_runs.values()}
        return prof
