"""Kernel plugins: the paper's task abstraction.

A kernel plugin names a computational tool + its environment and data
movement, independent of the pattern it runs in.  Plugins register under
dotted names (the paper's "md.namd", "md.re_exchange" become e.g.
"lm.train", "re.exchange", "misc.mkfile", "misc.ccount").

Interface (paper listing 2):
    k = Kernel(name="misc.ccount")
    k.arguments = {"bytes": 1 << 20}
    k.upload_input_data = [...]
    k.download_output_data = [...]
    k.cores = 1
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

_KERNEL_REGISTRY: Dict[str, "KernelDef"] = {}


class KernelDef:
    def __init__(self, name: str, fn: Callable[..., Any], *,
                 idempotent: bool = True, description: str = ""):
        self.name = name
        self.fn = fn
        self.idempotent = idempotent
        self.description = description


def register_kernel(name: str, *, idempotent: bool = True,
                    description: str = ""):
    def deco(fn):
        if name in _KERNEL_REGISTRY:
            raise ValueError(f"kernel {name} already registered")
        _KERNEL_REGISTRY[name] = KernelDef(name, fn, idempotent=idempotent,
                                           description=description)
        return fn
    return deco


def kernel_names() -> List[str]:
    _ensure_plugins()
    return sorted(_KERNEL_REGISTRY)


def kernel_registered(name: str) -> bool:
    """True when a plugin registered under ``name`` — the static check
    behind diagnostic E107 (repro.analysis), usable without constructing
    a Kernel (which raises KeyError on miss)."""
    _ensure_plugins()
    return name in _KERNEL_REGISTRY


def _ensure_plugins():
    import repro.plugins  # noqa: F401  (registers the standard plugins)


class Kernel:
    """A bound instance of a kernel plugin (one per task)."""

    def __init__(self, name: str):
        _ensure_plugins()
        if name not in _KERNEL_REGISTRY:
            raise KeyError(f"unknown kernel plugin {name!r}; "
                           f"available: {kernel_names()}")
        self._def = _KERNEL_REGISTRY[name]
        self.name = name
        self.arguments: Dict[str, Any] = {}
        self.upload_input_data: List[Any] = []
        self.download_output_data: List[Any] = []
        self.cores: int = 1
        self.uses_mpi: bool = False      # multi-chip (submesh-wide) task
        self.sim_duration: Optional[float] = None   # DES-mode duration
        # declared result size: lets the staging layer (repro.staging)
        # model this kernel's output traffic in DES mode, where no real
        # payload exists to measure
        self.output_nbytes: Optional[int] = None
        # declared result type: lets the static validator (repro.analysis)
        # check this kernel's puts against a typed Channel's dtype BEFORE
        # the run (diagnostic E101); runtime puts are still checked live
        self.output_dtype: Optional[type] = None
        self.timings = {"data_in": 0.0, "data_out": 0.0, "exec": 0.0}

    # ------------------------------------------------------------ execute
    def execute(self, ctx: Optional[Dict[str, Any]] = None) -> Any:
        """Run the kernel: stage data in, execute, stage data out.

        When a staging layer manages the run (``ctx["staging_managed"]``,
        set by the PST AppManager on a pilot built with
        ``staging=StagingLayer(...)``), the upload/download phases are
        skipped here: inputs were content-address-staged and dereferenced
        to the task's pod between ``pop_ready`` and launch (arriving as
        ``ctx["staged_inputs"]``), and ``stage_out`` callables run —
        charged to ``t_data`` — after completion."""
        ctx = dict(ctx or {})
        managed = bool(ctx.get("staging_managed"))
        t0 = time.perf_counter()
        if not managed:
            staged = [u() if callable(u) else u
                      for u in self.upload_input_data]
            ctx.setdefault("staged_inputs", staged)
        self.timings["data_in"] = time.perf_counter() - t0

        t1 = time.perf_counter()
        result = self._def.fn(self.arguments, ctx)
        self.timings["exec"] = time.perf_counter() - t1

        t2 = time.perf_counter()
        if not managed:
            for d in self.download_output_data:
                if callable(d):
                    d(result)
        self.timings["data_out"] = time.perf_counter() - t2
        return result

    @property
    def idempotent(self) -> bool:
        return self._def.idempotent
