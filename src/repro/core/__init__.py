"""Ensemble toolkit public API.

Two API generations live here:

**PST (current)** — composable Pipeline-Stage-Task data objects (the
second-generation EnTK model, arXiv:1710.08491), executed by an AppManager
over one long-lived pilot session with dynamic task injection::

    from repro.core import AppManager, PipelineSpec, Stage, TaskSpec

    sim = Stage([TaskSpec(k) for k in kernels], name="sim")
    ana = Stage([TaskSpec(ak)], name="analysis", on_done=adapt)  # may append
    AppManager(pilot).run([PipelineSpec([sim, ana], name="e0"), ...])

Many pipelines run concurrently with NO global barrier: ensemble A's next
cycle is injected the moment A's exchange completes, while B still
simulates.  ``on_done`` callbacks make workloads adaptive (append stages,
extend loops, branch on results) — shapes the 2016 hook API could not
express.

Typed data-flow ports (``repro.core.flow``) couple pipelines into a
DAG-of-ensembles: a stage declares ``outputs=[Channel("traj")]`` and a
stage in ANOTHER pipeline consumes it via ``inputs={"traj": ch}`` (or
pins one producer with ``inputs={"x": stage.future()}``); the consumer
starts the moment its producer stage completes, while the producer
pipeline keeps running.  Kernels see bound ports as ``ctx["inputs"]``.

**Legacy hooks (still supported)** — the 2016 paper's subclass API
(paper listings 1/4/5).  The patterns now *compile to PST* (see
core/execution_plugin.py); behavior and profiles are unchanged.

Migration table (old hook -> PST equivalent):

====================================  =====================================
legacy hook API                       PST equivalent
====================================  =====================================
``Pipeline.stage_k(self, i)``         one ``PipelineSpec`` per instance i,
                                      one single-task ``Stage`` per k
``BagOfTasks.task(self, i)``          single ``Stage`` of N ``TaskSpec``s
``RE.prepare_replica_for_md(r)``      "simulation" ``Stage`` (task per
                                      replica) of cycle c
``RE.prepare_exchange(replicas)``     "exchange" ``Stage``; its ``on_done``
``RE.apply_exchange(result, rs)``     applies the swap and *appends* cycle
                                      c+1's stages (adaptive extension)
``SAL.simulation_stage(it, i)``       "simulation" ``Stage`` of iteration it
``SAL.analysis_stage(it, j)``         "analysis" ``Stage``; ``on_done``
``SAL.should_continue(it, res)``      decides whether to append iteration
                                      it+1 or the ``post_loop`` stage
``SingleClusterEnvironment.run(p)``   ``AppManager(pilot).run(pipelines)``
====================================  =====================================
"""
from repro.core.ensemble import FusedEnsemble  # noqa: F401
from repro.core.execution_plugin import (  # noqa: F401
    BaseExecutionPlugin,
    get_plugin,
)
from repro.core.flow import (  # noqa: F401
    Channel,
    Port,
    StageFuture,
    TypedPortError,
)
from repro.core.kernel_plugin import Kernel, kernel_names, register_kernel  # noqa: F401
from repro.core.patterns import (  # noqa: F401
    BagOfTasks,
    ExecutionPattern,
    Pipeline,
    Replica,
    ReplicaExchange,
    SimulationAnalysisLoop,
)
from repro.core.pst import (  # noqa: F401
    AppManager,
    ExecutionProfile,
    PipelineSpec,
    Stage,
    TaskSpec,
)
from repro.core.resource_handler import (  # noqa: F401
    Pilot,
    ResourceSpec,
    SingleClusterEnvironment,
)
