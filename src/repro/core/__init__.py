"""Ensemble toolkit public API (mirrors the paper's import surface):

    from repro.core import Pipeline, ReplicaExchange, SimulationAnalysisLoop
    from repro.core import Kernel, SingleClusterEnvironment
"""
from repro.core.ensemble import FusedEnsemble  # noqa: F401
from repro.core.execution_plugin import (  # noqa: F401
    BaseExecutionPlugin,
    ExecutionProfile,
    get_plugin,
)
from repro.core.kernel_plugin import Kernel, kernel_names, register_kernel  # noqa: F401
from repro.core.patterns import (  # noqa: F401
    BagOfTasks,
    ExecutionPattern,
    Pipeline,
    Replica,
    ReplicaExchange,
    SimulationAnalysisLoop,
)
from repro.core.resource_handler import (  # noqa: F401
    Pilot,
    ResourceSpec,
    SingleClusterEnvironment,
)
