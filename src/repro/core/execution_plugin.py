"""Execution plugins (paper §3.2 component 4): compile a legacy hook-API
pattern into PST pipelines (core/pst.py) and run them on an AppManager.

One plugin per pattern.  The plugin is the ONLY component that sees both the
pattern structure and the runtime — patterns stay execution-agnostic, the
runtime stays pattern-agnostic.  Since the PST redesign the plugin no longer
drives per-cycle TaskGraphs itself: it emits *port-annotated* ``PipelineSpec``
objects — consumer stages declare their producers as StageFuture inputs
(core/flow.py), so the exchange/analysis kernels receive the member results
as ``ctx["inputs"]`` and the dependency structure is explicit in the PST
objects rather than implied by the per-pipeline barrier alone — whose
``on_done`` callbacks reproduce the pattern's control flow (apply_exchange,
should_continue, ...) adaptively, and one long-lived runtime session
executes everything.  Profiles are pinned by tests: the port edges dedupe
against the barrier deps, so task sets, dependencies and timings are
unchanged.  The paper's TTC decomposition
(TTC = T_EnMD(core+pattern+rts) + T_exec + T_data) is assembled by the
AppManager; utilization is computed once over the whole run from
accumulated busy slot-seconds (it used to be overwritten per cycle, so
RE/SAL reported only the last cycle's utilization).
"""
from __future__ import annotations

import time
from typing import List

from repro.core.patterns import (ExecutionPattern, Pipeline,
                                 ReplicaExchange, SimulationAnalysisLoop)
from repro.core.pst import (AppManager, ExecutionProfile, PipelineSpec,
                            Stage, TaskSpec)
from repro.core.resource_handler import Pilot

__all__ = ["ExecutionProfile", "BaseExecutionPlugin",
           "PipelineExecutionPlugin", "REExecutionPlugin",
           "SALExecutionPlugin", "get_plugin"]


class BaseExecutionPlugin:
    """Compile ``self.pattern`` to PST pipelines, then run them."""

    def __init__(self, pattern: ExecutionPattern, pilot: Pilot):
        self.pattern = pattern
        self.pilot = pilot
        self.profile = ExecutionProfile()

    def compile(self) -> List[PipelineSpec]:
        raise NotImplementedError

    def execute(self) -> ExecutionProfile:
        t0 = time.perf_counter()
        pipelines = self.compile()
        self.profile.t_pattern_overhead += time.perf_counter() - t0
        AppManager(self.pilot, profile=self.profile).run(pipelines)
        return self.profile


# ---------------------------------------------------------------- pipeline

class PipelineExecutionPlugin(BaseExecutionPlugin):
    pattern_cls = Pipeline

    def compile(self) -> List[PipelineSpec]:
        pat: Pipeline = self.pattern
        pipes = []
        # one PST pipeline per pipe instance: pipes advance independently
        # (a slow pipe never blocks another pipe's later stages)
        for p in range(pat.instances):
            stages: List[Stage] = []
            for s in range(1, pat.stages + 1):
                stages.append(Stage(
                    [TaskSpec(pat.stage_kernel(s, p),
                              name=f"pipe{p:05d}.stage{s}",
                              metadata={"instance": p})],
                    name=f"stage{s}",
                    inputs=({"prev": stages[-1].future()} if stages
                            else None)))
            pipes.append(PipelineSpec(stages, name=f"pipe{p:05d}"))
        return pipes


# ---------------------------------------------------------------- replica

class REExecutionPlugin(BaseExecutionPlugin):
    pattern_cls = ReplicaExchange

    def compile(self) -> List[PipelineSpec]:
        pat: ReplicaExchange = self.pattern
        prof = self.profile

        def cycle_stages(c: int) -> List[Stage]:
            sims = Stage(
                [TaskSpec(pat.prepare_replica_for_md(r),
                          name=f"cycle{c:04d}.md{r.id:05d}",
                          metadata={"instance": r.id, "iteration": c})
                 for r in pat.replicas],
                name="simulation")
            xname = f"cycle{c:04d}.exchange"

            def on_exchange(stage: Stage, pipe: PipelineSpec):
                xres = stage.results[xname]
                pat.apply_exchange(xres, pat.replicas)
                for r in pat.replicas:
                    r.cycle += 1
                prof.results[f"exchange_{c}"] = xres
                if c + 1 < pat.cycles:
                    # next cycle's kernels are prepared only now, AFTER the
                    # exchange was applied — the PST adaptivity hook
                    pipe.extend(cycle_stages(c + 1))

            # the exchange consumes the simulation stage through a typed
            # port: the kernel sees member results as ctx["inputs"]["members"]
            exchange = Stage(
                [TaskSpec(pat.prepare_exchange(pat.replicas), name=xname,
                          metadata={"iteration": c})],
                name="exchange", inputs={"members": sims.future()},
                on_done=on_exchange)
            return [sims, exchange]

        if pat.cycles <= 0:
            return [PipelineSpec([], name="re")]
        return [PipelineSpec(cycle_stages(0), name="re")]


# ---------------------------------------------------------------- SAL

class SALExecutionPlugin(BaseExecutionPlugin):
    pattern_cls = SimulationAnalysisLoop

    def compile(self) -> List[PipelineSpec]:
        pat: SimulationAnalysisLoop = self.pattern
        prof = self.profile

        def finale() -> List[Stage]:
            post = pat.post_loop()
            if post is None:
                return []
            return [Stage([TaskSpec(post, name="post_loop")],
                          name="post_loop")]

        def iter_stages(it: int) -> List[Stage]:
            sims = Stage(
                [TaskSpec(pat.simulation_stage(it, i),
                          name=f"iter{it:04d}.sim{i:05d}",
                          metadata={"instance": i, "iteration": it})
                 for i in range(pat.simulation_instances)],
                name="simulation")
            ana_names = [f"iter{it:04d}.ana{j:05d}"
                         for j in range(pat.analysis_instances)]

            def on_analysis(stage: Stage, pipe: PipelineSpec):
                results = [stage.results[n] for n in ana_names]
                prof.results[f"analysis_{it}"] = results
                # legacy called should_continue on EVERY iteration, the
                # last included — keep that call parity (subclasses may
                # track convergence state in it)
                cont = pat.should_continue(it, results)
                if cont and it + 1 < pat.maxiterations:
                    pipe.extend(iter_stages(it + 1))
                else:
                    pipe.extend(finale())

            analysis = Stage(
                [TaskSpec(pat.analysis_stage(it, j), name=n,
                          metadata={"instance": j, "iteration": it})
                 for j, n in enumerate(ana_names)],
                name="analysis", inputs={"sims": sims.future()},
                on_done=on_analysis)
            return [sims, analysis]

        stages: List[Stage] = []
        pre = pat.pre_loop()
        if pre is not None:
            stages.append(Stage([TaskSpec(pre, name="pre_loop")],
                                name="pre_loop"))
        if pat.maxiterations > 0:
            stages += iter_stages(0)
        else:
            stages += finale()
        return [PipelineSpec(stages, name="sal")]


_PLUGINS = [PipelineExecutionPlugin, REExecutionPlugin, SALExecutionPlugin]


def get_plugin(pattern: ExecutionPattern, pilot: Pilot,
               **kw) -> BaseExecutionPlugin:
    for cls in _PLUGINS:
        if isinstance(pattern, cls.pattern_cls):
            return cls(pattern, pilot, **kw)
    raise TypeError(f"no execution plugin for {type(pattern).__name__}; "
                    "register one by appending to _PLUGINS")
