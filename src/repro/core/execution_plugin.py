"""Execution plugins (paper §3.2 component 4): bind a pattern's kernels into
executable units (Tasks) and submit them to the pilot runtime.

One plugin per pattern.  The plugin is the ONLY component that sees both the
pattern structure and the runtime — patterns stay execution-agnostic, the
runtime stays pattern-agnostic.  The plugin also assembles the paper's TTC
decomposition:  TTC = T_EnMD(core+pattern+rts) + T_exec + T_data.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.kernel_plugin import Kernel
from repro.core.patterns import (BagOfTasks, ExecutionPattern, Pipeline,
                                 ReplicaExchange, SimulationAnalysisLoop)
from repro.core.resource_handler import Pilot
from repro.runtime.states import Task, TaskGraph, TaskState


@dataclass
class ExecutionProfile:
    """Paper eq. (1)-(2)."""
    ttc: float = 0.0
    t_exec: float = 0.0
    t_data: float = 0.0
    t_core_overhead: float = 0.0
    t_pattern_overhead: float = 0.0
    t_rts_overhead: float = 0.0
    n_tasks: int = 0
    n_failed: int = 0
    n_retries: int = 0
    n_speculative: int = 0
    utilization: float = 0.0
    per_stage: Dict[str, Dict[str, float]] = field(default_factory=dict)
    results: Dict[str, Any] = field(default_factory=dict)

    @property
    def t_enmd_overhead(self) -> float:
        return (self.t_core_overhead + self.t_pattern_overhead
                + self.t_rts_overhead)

    def summary(self) -> Dict[str, float]:
        return {"ttc": self.ttc, "t_exec": self.t_exec,
                "t_data": self.t_data,
                "t_core_overhead": self.t_core_overhead,
                "t_pattern_overhead": self.t_pattern_overhead,
                "t_rts_overhead": self.t_rts_overhead,
                "n_tasks": self.n_tasks, "n_failed": self.n_failed,
                "utilization": self.utilization}


class BaseExecutionPlugin:
    def __init__(self, pattern: ExecutionPattern, pilot: Pilot):
        self.pattern = pattern
        self.pilot = pilot
        self.profile = ExecutionProfile()
        self._kernels: Dict[str, Kernel] = {}

    # ------------------------------------------------------------ helpers
    def _make_task(self, kernel: Kernel, name: str, *, deps=(), stage="",
                   instance: int = 0, iteration: int = 0) -> Task:
        self._kernels[name] = kernel

        def run(task: Task, _k=kernel):
            ctx = {"pilot": self.pilot, "task": task,
                   "dep_results": task.meta.get("dep_results", {})}
            return _k.execute(ctx)

        return Task(
            name=name,
            run=run if self.pilot.runtime.mode == "real" else None,
            duration=(kernel.sim_duration or 0.0),
            slots=kernel.cores,
            deps=list(deps),
            stage=stage, instance=instance, iteration=iteration,
            idempotent=kernel.idempotent)

    def _run_graph(self, graph: TaskGraph):
        rp = self.pilot.runtime.run(graph)
        self.profile.ttc += rp.ttc
        self.profile.t_exec += rp.t_exec
        self.profile.t_rts_overhead += rp.t_rts_overhead
        self.profile.n_tasks += rp.n_tasks
        self.profile.n_failed += rp.n_failed
        self.profile.n_retries += rp.n_retries
        self.profile.n_speculative += rp.n_speculative
        # data staging time comes from the kernels themselves
        for name, k in list(self._kernels.items()):
            if name in graph.tasks:
                self.profile.t_data += (k.timings["data_in"]
                                        + k.timings["data_out"])
        busy = rp.slot_busy
        denom = max(rp.ttc, 1e-12) * max(self.pilot.slots, 1)
        self.profile.utilization = busy / denom
        return rp

    def _stage_stats(self, graph: TaskGraph):
        for t in graph.tasks.values():
            st = self.profile.per_stage.setdefault(
                t.stage, {"n": 0, "t_exec": 0.0})
            st["n"] += 1
            if self.pilot.runtime.mode == "sim":
                st["t_exec"] += t.duration
            else:
                st["t_exec"] += max(t.t_finished - t.t_started, 0.0)

    def execute(self) -> ExecutionProfile:
        raise NotImplementedError


# ---------------------------------------------------------------- pipeline

class PipelineExecutionPlugin(BaseExecutionPlugin):
    pattern_cls = Pipeline

    def execute(self) -> ExecutionProfile:
        t0 = time.perf_counter()
        pat: Pipeline = self.pattern
        graph = TaskGraph()
        for p in range(pat.instances):
            prev = None
            for s in range(1, pat.stages + 1):
                k = pat.stage_kernel(s, p)
                name = f"pipe{p:05d}.stage{s}"
                graph.add(self._make_task(
                    k, name, deps=[prev] if prev else [],
                    stage=f"stage{s}", instance=p))
                prev = name
        self.profile.t_pattern_overhead += time.perf_counter() - t0
        self._run_graph(graph)
        self._stage_stats(graph)
        self.profile.results["tasks"] = {
            n: t.result for n, t in graph.tasks.items()}
        return self.profile


# ---------------------------------------------------------------- replica

class REExecutionPlugin(BaseExecutionPlugin):
    pattern_cls = ReplicaExchange

    def execute(self) -> ExecutionProfile:
        pat: ReplicaExchange = self.pattern
        for c in range(pat.cycles):
            t0 = time.perf_counter()
            graph = TaskGraph()
            sim_names = []
            for r in pat.replicas:
                k = pat.prepare_replica_for_md(r)
                name = f"cycle{c:04d}.md{r.id:05d}"
                graph.add(self._make_task(k, name, stage="simulation",
                                          instance=r.id, iteration=c))
                sim_names.append(name)
            xk = pat.prepare_exchange(pat.replicas)
            xname = f"cycle{c:04d}.exchange"
            graph.add(self._make_task(xk, xname, deps=sim_names,
                                      stage="exchange", iteration=c))
            self.profile.t_pattern_overhead += time.perf_counter() - t0

            self._run_graph(graph)
            self._stage_stats(graph)

            t1 = time.perf_counter()
            xres = graph.tasks[xname].result
            pat.apply_exchange(xres, pat.replicas)
            for r in pat.replicas:
                r.cycle += 1
            self.profile.t_pattern_overhead += time.perf_counter() - t1
            self.profile.results[f"exchange_{c}"] = xres
        return self.profile


# ---------------------------------------------------------------- SAL

class SALExecutionPlugin(BaseExecutionPlugin):
    pattern_cls = SimulationAnalysisLoop

    def execute(self) -> ExecutionProfile:
        pat: SimulationAnalysisLoop = self.pattern

        t0 = time.perf_counter()
        pre = pat.pre_loop()
        self.profile.t_pattern_overhead += time.perf_counter() - t0
        if pre is not None:
            g = TaskGraph()
            g.add(self._make_task(pre, "pre_loop", stage="pre_loop"))
            self._run_graph(g)
            self._stage_stats(g)

        for it in range(pat.maxiterations):
            t0 = time.perf_counter()
            graph = TaskGraph()
            sims = []
            for i in range(pat.simulation_instances):
                k = pat.simulation_stage(it, i)
                name = f"iter{it:04d}.sim{i:05d}"
                graph.add(self._make_task(k, name, stage="simulation",
                                          instance=i, iteration=it))
                sims.append(name)
            ana = []
            for j in range(pat.analysis_instances):
                k = pat.analysis_stage(it, j)
                name = f"iter{it:04d}.ana{j:05d}"
                graph.add(self._make_task(k, name, deps=sims,
                                          stage="analysis", instance=j,
                                          iteration=it))
                ana.append(name)
            self.profile.t_pattern_overhead += time.perf_counter() - t0

            self._run_graph(graph)
            self._stage_stats(graph)

            results = [graph.tasks[n].result for n in ana]
            self.profile.results[f"analysis_{it}"] = results
            if not pat.should_continue(it, results):
                break

        t0 = time.perf_counter()
        post = pat.post_loop()
        self.profile.t_pattern_overhead += time.perf_counter() - t0
        if post is not None:
            g = TaskGraph()
            g.add(self._make_task(post, "post_loop", stage="post_loop"))
            self._run_graph(g)
            self._stage_stats(g)
        return self.profile


_PLUGINS = [PipelineExecutionPlugin, REExecutionPlugin, SALExecutionPlugin]


def get_plugin(pattern: ExecutionPattern, pilot: Pilot,
               **kw) -> BaseExecutionPlugin:
    for cls in _PLUGINS:
        if isinstance(pattern, cls.pattern_cls):
            return cls(pattern, pilot, **kw)
    raise TypeError(f"no execution plugin for {type(pattern).__name__}; "
                    "register one by appending to _PLUGINS")
