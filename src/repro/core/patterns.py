"""Legacy execution patterns (the paper's §3.4): Pipeline, Replica Exchange,
Simulation-Analysis Loop, plus BagOfTasks.

A pattern is a parameterized control-flow template; users subclass and fill
stage methods with Kernel plugins (paper listings 1/4/5).  Patterns compile
to PST pipelines (core/pst.py) via their execution plugin — the pattern
itself never touches execution details (paper design decision: "decouple
what to execute from how to execute").

New code should use the PST API directly (``AppManager``, ``PipelineSpec``,
``Stage``, ``TaskSpec``): it expresses everything these templates do plus
adaptive and coupled workloads they cannot (see the migration table in
repro/core/__init__.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core.kernel_plugin import Kernel


class ExecutionPattern:
    name = "abstract"

    def describe(self) -> Dict[str, Any]:
        return {"pattern": self.name}


# ---------------------------------------------------------------- pipeline

class Pipeline(ExecutionPattern):
    """N independent pipes x M sequential stages (paper listing 1).

    Subclasses define ``stage_1(self, instance) -> Kernel`` ... ``stage_M``.
    """
    name = "pipeline"

    def __init__(self, stages: int, instances: int):
        self.stages = stages
        self.instances = instances

    def stage_kernel(self, stage: int, instance: int) -> Kernel:
        fn = getattr(self, f"stage_{stage}", None)
        if fn is None:
            raise NotImplementedError(f"stage_{stage} not defined")
        return fn(instance)

    def describe(self):
        return {"pattern": self.name, "stages": self.stages,
                "instances": self.instances}


class BagOfTasks(Pipeline):
    """Degenerate single-stage pipeline (paper's BoT scenario)."""
    name = "bag_of_tasks"

    def __init__(self, instances: int):
        super().__init__(stages=1, instances=instances)

    def task(self, instance: int) -> Kernel:
        raise NotImplementedError

    def stage_1(self, instance: int) -> Kernel:
        return self.task(instance)


# ---------------------------------------------------------------- replica

class Replica:
    """Mutable replica context threaded through RE cycles (paper's
    ``replica.id`` / ``replica.cycle``)."""

    def __init__(self, rid: int):
        self.id = rid
        self.cycle = 0
        self.state: Dict[str, Any] = {}   # e.g. temperature, params handle


class ReplicaExchange(ExecutionPattern):
    """Cycles of (concurrent simulation phase -> exchange phase).

    Subclasses define:
      prepare_replica_for_md(self, replica) -> Kernel
      prepare_exchange(self, replicas) -> Kernel       (barrier task)
      apply_exchange(self, result, replicas) -> None   (host-side swap)
    """
    name = "replica_exchange"

    def __init__(self, cycles: int, replicas: int):
        self.cycles = cycles
        self.replicas = [Replica(i) for i in range(replicas)]

    def prepare_replica_for_md(self, replica: Replica) -> Kernel:
        raise NotImplementedError

    def prepare_exchange(self, replicas: List[Replica]) -> Kernel:
        raise NotImplementedError

    def apply_exchange(self, result: Any, replicas: List[Replica]) -> None:
        pass

    def describe(self):
        return {"pattern": self.name, "cycles": self.cycles,
                "replicas": len(self.replicas)}


# ---------------------------------------------------------------- SAL

class SimulationAnalysisLoop(ExecutionPattern):
    """pre_loop -> [N x simulation -> M x analysis] * k -> post_loop
    (paper listing 4)."""
    name = "simulation_analysis_loop"

    def __init__(self, maxiterations: int, simulation_instances: int = 1,
                 analysis_instances: int = 1):
        self.maxiterations = maxiterations
        self.simulation_instances = simulation_instances
        self.analysis_instances = analysis_instances

    def pre_loop(self) -> Optional[Kernel]:
        return None

    def simulation_stage(self, iteration: int, instance: int) -> Kernel:
        raise NotImplementedError

    def analysis_stage(self, iteration: int, instance: int) -> Kernel:
        raise NotImplementedError

    def post_loop(self) -> Optional[Kernel]:
        return None

    def should_continue(self, iteration: int, analysis_results) -> bool:
        """Convergence hook: return False to stop before maxiterations."""
        return True

    def describe(self):
        return {"pattern": self.name, "iterations": self.maxiterations,
                "simulations": self.simulation_instances,
                "analyses": self.analysis_instances}
