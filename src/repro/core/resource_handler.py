"""Resource handler (paper §3.5.3): pilot-based resource acquisition.

``SingleClusterEnvironment`` keeps the paper's interface (listing 3) —
resource name, cores, walltime, credentials, database — mapped to the TPU
fleet: cores -> slots (submeshes of the pilot mesh), database -> journal
path.  ``allocate`` acquires the pilot once; patterns then run on it with
application-level scheduling (the whole point of the pilot abstraction).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax

from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal


@dataclass
class ResourceSpec:
    name: str = "local.cpu"
    cores: int = 4
    walltime: int = 15                 # minutes
    username: Optional[str] = None
    project: Optional[str] = None
    queue: Optional[str] = None
    # hardware model used for napkin math / sim calibration
    peak_flops_per_core: float = 197e12
    hbm_per_core: float = 16e9


class Pilot:
    """The resource placeholder: holds slots (and the device mesh when the
    resource is a TPU pod) for application-level task scheduling."""

    def __init__(self, spec: ResourceSpec, runtime: PilotRuntime,
                 mesh=None):
        self.spec = spec
        self.runtime = runtime
        self.mesh = mesh
        self.t_allocated = time.perf_counter()
        self.active = True

    @property
    def slots(self) -> int:
        return self.runtime.slots

    def resize(self, slots: int):
        """Elastic scaling: grow/shrink the slot pool mid-run."""
        self.runtime.resize(slots)

    def walltime_remaining(self) -> float:
        return self.spec.walltime * 60 - (time.perf_counter()
                                          - self.t_allocated)


class SingleClusterEnvironment:
    """Paper listing 3 interface."""

    def __init__(self, resource: str = "local.cpu", cores: int = 4,
                 walltime: int = 15, username: Optional[str] = None,
                 project: Optional[str] = None, queue: Optional[str] = None,
                 database_url: Optional[str] = None,
                 database_name: str = "enmd",
                 mode: str = "real",
                 straggler_factor: float = 0.0,
                 max_retries: int = 2):
        self.spec = ResourceSpec(resource, cores, walltime, username,
                                 project, queue)
        self.mode = mode
        self.straggler_factor = straggler_factor
        self.max_retries = max_retries
        self.journal_path = (f"{database_url}/{database_name}.jsonl"
                             if database_url else None)
        self.pilot: Optional[Pilot] = None
        self.overheads: Dict[str, float] = {"t_core": 0.0}

    # ------------------------------------------------------------ allocate
    def allocate(self) -> Pilot:
        t0 = time.perf_counter()
        mesh = None
        if self.spec.name.startswith("tpu.") and len(jax.devices()) > 1:
            n = min(self.spec.cores, len(jax.devices()))
            mesh = jax.make_mesh((n,), ("data",),
                                 devices=jax.devices()[:n])
        runtime = PilotRuntime(
            slots=self.spec.cores, mode=self.mode,
            journal=Journal(self.journal_path),
            max_retries=self.max_retries,
            straggler_factor=self.straggler_factor)
        self.pilot = Pilot(self.spec, runtime, mesh)
        self.overheads["t_core"] += time.perf_counter() - t0
        return self.pilot

    # ------------------------------------------------------------ run
    def run(self, pattern, **kw):
        if self.pilot is None or not self.pilot.active:
            raise RuntimeError("allocate() the pilot before run()")
        from repro.core.execution_plugin import get_plugin
        plugin = get_plugin(pattern, self.pilot, **kw)
        profile = plugin.execute()
        profile.t_core_overhead = self.overheads["t_core"]
        return profile

    # ------------------------------------------------------------ release
    def deallocate(self):
        t0 = time.perf_counter()
        if self.pilot is not None:
            # close() also GCs unreferenced spill files (journaled refs
            # are kept — deallocate must not end restartability)
            self.pilot.runtime.close()
            self.pilot.active = False
        self.overheads["t_core"] += time.perf_counter() - t0
