"""grok-1-314b — MoE, 8 experts top-2, 314B total params.

[hf:xai-org/grok-1; unverified]  64L, d_model=6144, 48 heads, GQA kv=8,
head_dim=128, expert d_ff=32768, 8 experts top-2, vocab=131072, attention and
final logit softcaps (tanh 30), embedding scaling.  With only 8 experts the
"model" axis (16) cannot shard the expert dim, so experts are sharded
*internally* (Megatron-style TP on d_ff over "model", d_model over "data").
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    moe_d_ff=32768,
    num_experts=8,
    experts_per_tok=2,
    vocab_size=131_072,
    layer_pattern=("global",),
    mlp="geglu",
    norm="rmsnorm",
    attn_softcap=30.0,
    final_softcap=30.0,
    emb_scale=True,
    tie_embeddings=False,
    rope_theta=10_000.0,
    sharding_profile="tp",      # experts internally TP-sharded (E=8 < 16)
    optstate_dtype="bfloat16",
    microbatches=8,             # 256/8 = 32 = pod*data batch shards
    remat="full",
    source="hf:xai-org/grok-1; unverified",
    notes="largest assigned arch; FSDP+TP, bf16 optimizer states, 8 "
          "microbatches; pure full attention -> long_500k skipped",
))

ENSEMBLE_NOTES = "Stress config for memory_analysis at 256/512 chips."
