"""nemotron-4-15b — dense, GQA, squared-ReLU MLP.

[arXiv:2402.16819; unverified]  32L, d_model=6144, 48 heads, GQA kv=8,
d_ff=24576 (squared-ReLU, non-gated), vocab=256000, untied embeddings,
LayerNorm (no-bias variant).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256_000,
    layer_pattern=("global",),
    mlp="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    tie_embeddings=False,
    sharding_profile="tp",
    optstate_dtype="bfloat16",
    microbatches=4,
    remat="full",
    source="arXiv:2402.16819; unverified",
    notes="pure full attention -> long_500k skipped",
))

ENSEMBLE_NOTES = "Mid-size TP-profile member; squared-ReLU exercises mlp=relu2."
