"""The paper's own validation workload (Section 4.3): mkfile + ccount.

A two-stage toy application: stage 1 creates a buffer of random characters
(``misc.mkfile``), stage 2 counts characters (``misc.ccount``).  Used by the
Fig.5 pattern-characterization benchmark with all three execution patterns.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class ToyWorkloadConfig:
    name: str = "charcount"
    file_bytes: int = 1 << 20      # per-task buffer size (paper: ~MB files)
    stages: int = 2
    # Fig.5 sweep: tasks = cores, 24..192
    task_sweep: tuple = (24, 48, 96, 192)


CONFIG = ToyWorkloadConfig()
