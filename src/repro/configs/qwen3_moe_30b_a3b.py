"""qwen3-moe-30b-a3b — MoE, 128 experts top-8, ~3B active params.

[hf:Qwen/Qwen3-30B-A3B]  48L, d_model=2048, 32 heads, GQA kv=4, head_dim=128,
expert d_ff=768, 128 experts top-8, vocab=151936, SwiGLU experts, qk-norm.
Expert parallelism: experts sharded over the "model" mesh axis (128/16 = 8
experts per shard).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    moe_d_ff=768,
    num_experts=128,
    experts_per_tok=8,
    vocab_size=151_936,
    layer_pattern=("global",),
    mlp="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    sharding_profile="tp_ep",
    optstate_dtype="bfloat16",
    microbatches=4,
    remat="full",
    source="hf:Qwen/Qwen3-30B-A3B",
    notes="EP over model axis; pure full attention -> long_500k skipped",
))

ENSEMBLE_NOTES = "Exercises EP + dense one-hot dispatch (kernels/moe_gmm)."
