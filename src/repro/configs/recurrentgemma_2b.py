"""recurrentgemma-2b — Griffin: RG-LRU recurrent blocks + local attention, 1:2.

[arXiv:2402.19427; hf:google/recurrentgemma-2b]  26L, d_model=2560, 10 heads
(MQA kv=1) on the local-attention blocks, d_ff=7680 (GeGLU), vocab=256000.
Block pattern repeats (rec, rec, local) — two RG-LRU residual blocks per
local-attention block; sliding window 2048.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    layer_pattern=("rec", "rec", "local"),
    sliding_window=2048,
    lru_width=2560,
    mlp="geglu",
    norm="rmsnorm",
    emb_scale=True,
    tie_embeddings=True,
    sharding_profile="fsdp",
    remat="full",  # measured best on the bytes roofline (§Perf gemma2)

    scan_layers=False,   # heterogeneous block params -> unrolled stack
    source="arXiv:2402.19427; hf",
    notes="RG-LRU state + 2048 window => O(1) per-token state; long_500k runs",
))

ENSEMBLE_NOTES = (
    "Representative RE-pattern population member (2B-scale). RG-LRU scan is a "
    "Pallas kernel hot spot (kernels/rglru)."
)
