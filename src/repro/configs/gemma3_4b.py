"""gemma3-4b — dense, 5:1 local:global attention, qk-norm, 128k context.

[hf:google/gemma-3-4b-pt; unverified]  34L, d_model=2560, 8 heads, GQA kv=4,
d_ff=10240 (GeGLU), vocab=262144; sliding window 1024 on local layers; global
layers use rope theta 1M (local 10k); qk-norm instead of softcaps.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    sliding_window=1024,
    mlp="geglu",
    norm="rmsnorm",
    post_norms=True,
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    emb_scale=True,
    tie_embeddings=True,
    sharding_profile="fsdp",
    remat="full",  # measured best on the bytes roofline (§Perf gemma2)

    source="hf:google/gemma-3-4b-pt; unverified",
    notes="5:1 local:global, designed for 128k+; long_500k runs",
))

ENSEMBLE_NOTES = "SAL-pattern train->eval loop member in examples."
