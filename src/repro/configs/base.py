"""Config system: model configs, input-shape specs, and the arch registry.

Every assigned architecture registers a ``ModelConfig`` here.  Shapes are the
four assigned input-shape cells (train_4k / prefill_32k / decode_32k /
long_500k).  ``input_specs`` produces ``jax.ShapeDtypeStruct`` stand-ins for
every model input so the multi-pod dry-run can lower/compile without
allocating anything.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Model configuration
# --------------------------------------------------------------------------

# Layer kinds used in ``layer_pattern`` (cycled over the depth of the stack):
#   "global" - full causal attention
#   "local"  - sliding-window causal attention
#   "rec"    - RG-LRU recurrent block (Griffin / RecurrentGemma)
#   "mamba"  - Mamba-1 selective-SSM block
LAYER_KINDS = ("global", "local", "rec", "mamba")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # -- block structure ----------------------------------------------------
    layer_pattern: Tuple[str, ...] = ("global",)
    sliding_window: int = 0          # >0 for "local" layers
    mlp: str = "swiglu"              # swiglu | geglu | relu2 | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    post_norms: bool = False         # gemma2-style post-sublayer norms

    # -- attention details ----------------------------------------------------
    attn_softcap: float = 0.0        # tanh softcap on attention logits
    final_softcap: float = 0.0       # tanh softcap on final logits
    qk_norm: bool = False            # rmsnorm on q and k heads (gemma3/qwen3)
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # if >0, separate theta for global layers
    attn_scale: float = 0.0          # 0 => 1/sqrt(head_dim)

    # -- embeddings ----------------------------------------------------------
    tie_embeddings: bool = True
    emb_scale: bool = False          # multiply embeddings by sqrt(d_model)

    # -- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0                # expert hidden dim (0 => use d_ff)

    # -- SSM (mamba) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                 # 0 => ceil(d_model / 16)

    # -- RG-LRU (hybrid) -------------------------------------------------------
    lru_width: int = 0               # 0 => d_model

    # -- encoder/decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0          # 0 => decoder-only
    encoder_seq: int = 1500          # frontend-stub sequence length

    # -- VLM (internvl) ---------------------------------------------------------
    vision_tokens: int = 0           # prepended patch-embedding stub tokens

    # -- numerics / parallelism -----------------------------------------------
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"     # master parameter dtype
    optstate_dtype: str = "float32"  # Adam m/v dtype (bf16 for the huge archs)
    sharding_profile: str = "fsdp"   # fsdp | tp | tp_ep
    remat: str = "full"              # none | dots | full
    microbatches: int = 1            # gradient-accumulation steps
    scan_layers: bool = True         # lax.scan over homogeneous layer stacks
    loss_chunk: int = 1024           # seq chunk for fused lm-head + loss

    # free-form provenance / notes
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------ utils
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or math.ceil(self.d_model / 16)

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.num_layers))

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("rec", "mamba") for k in self.layer_kinds)

    @property
    def is_pure_full_attention(self) -> bool:
        return all(k == "global" for k in self.layer_kinds)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic per-token state: SSM / recurrent / local-dominant."""
        return not self.is_pure_full_attention

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------------- params
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline N."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d                      # token embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_layer = {}
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        gated = self.mlp in ("swiglu", "geglu")
        def mlp_params(ff):
            return d * ff * (3 if gated else 2)
        for kind in set(self.layer_kinds):
            if kind in ("global", "local"):
                p = attn + (mlp_params(self.d_ff) if self.num_experts == 0
                            else d * self.num_experts
                            + self.num_experts * (self.expert_ff * d * (3 if gated else 2)))
            elif kind == "rec":
                w = self.lru_width_
                p = 2 * d * w + w * d + 3 * w * w + self.ssm_conv * w + mlp_params(self.d_ff)
            elif kind == "mamba":
                di, st, dr = self.d_inner, self.ssm_state, self.dt_rank_
                p = (d * 2 * di + self.ssm_conv * di + di * (dr + 2 * st)
                     + dr * di + di * st + di + di * d)
            else:
                raise ValueError(kind)
            per_layer[kind] = p
        n += sum(per_layer[k] for k in self.layer_kinds)
        if self.encoder_layers:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            n += self.encoder_layers * (attn + mlp_params(self.d_ff))
            n += self.num_layers * attn              # cross attention
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        dense = self.replace(num_experts=0, experts_per_tok=0,
                             d_ff=self.expert_ff)
        base = dense.param_count()
        gated = self.mlp in ("swiglu", "geglu")
        per_expert = self.expert_ff * self.d_model * (3 if gated else 2)
        n_attn_layers = sum(1 for k in self.layer_kinds if k in ("global", "local"))
        # dense.param_count used one expert-sized ffn per layer; swap in top-k
        base += n_attn_layers * (self.experts_per_tok - 1) * per_expert
        base += n_attn_layers * self.d_model * self.num_experts  # router
        return int(base)


# --------------------------------------------------------------------------
# Input-shape cells
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeSpec("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeSpec("long_500k",   "decode",  524_288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no decode step"
    return True, ""


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Stand-ins for every model input of the given shape cell."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    f32 = jnp.float32
    bf16 = jnp.bfloat16
    i32 = jnp.int32

    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "seg_ids": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), bf16)
        if cfg.encoder_layers:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), bf16)
        return specs

    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), bf16)
        if cfg.encoder_layers:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), bf16)
        return specs

    if shape.kind == "decode":
        # one new token against a KV/state cache of length S (cache specs are
        # produced by repro.serve.cache_specs)
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "positions": jax.ShapeDtypeStruct((B,), i32),
        }
        return specs

    raise ValueError(shape.kind)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (triggers arch registration)
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# --------------------------------------------------------------------------

def reduced(cfg: ModelConfig, *, layers: Optional[int] = None) -> ModelConfig:
    """Tiny same-family config: identical structure, laptop-scale dims."""
    pat = cfg.layer_pattern
    L = layers or max(2, len(pat))
    kv = max(1, min(cfg.num_kv_heads, 2))
    heads = max(kv, 4)
    kw: Dict[str, Any] = dict(
        name=cfg.name + "-reduced",
        num_layers=L,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=257,
        dtype="float32",
        param_dtype="float32",
        optstate_dtype="float32",
        microbatches=1,
        remat="none",
        loss_chunk=64,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_tok=min(2, cfg.experts_per_tok),
                  moe_d_ff=32)
    if cfg.ssm_state:
        kw.update(ssm_state=4, ssm_conv=4, ssm_expand=2, dt_rank=8)
    if cfg.lru_width:
        kw.update(lru_width=64)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=16)
    if cfg.vision_tokens:
        kw.update(vision_tokens=8)
    out = cfg.replace(**kw)
    _REGISTRY.pop(out.name, None)
    return out
