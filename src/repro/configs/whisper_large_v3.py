"""whisper-large-v3 — encoder-decoder backbone; conv/mel frontend stubbed.

[arXiv:2212.04356; unverified]  32 encoder + 32 decoder layers, d_model=1280,
20 heads MHA (kv=20), head_dim=64, d_ff=5120 (GELU), vocab=51866, LayerNorm.
Per the assignment the audio frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, 1500, 1280).  Backbone adaptation: absolute
sinusoidal positions are computed on the fly so the decoder backbone can be
exercised at the assigned 32k decode shape (real whisper caps at 448).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,           # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    layer_pattern=("global",),
    mlp="gelu",
    norm="layernorm",
    rope_theta=0.0,          # 0 => absolute sinusoidal positions
    tie_embeddings=True,
    sharding_profile="fsdp",
    remat="full",  # measured best on the bytes roofline (§Perf gemma2)

    source="arXiv:2212.04356; unverified",
    notes="enc-dec; decode runs (causal decoder); long_500k skipped "
          "(full attention + enc-dec semantics)",
))

ENSEMBLE_NOTES = (
    "Pipeline-pattern example: frontend-stub -> encode -> decode stages map "
    "onto a 3-stage pipe per utterance batch."
)
