"""gemma2-2b — dense, local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf:google/gemma-2-2b]  26L, d_model=2304, 8 heads, GQA
kv=4, d_ff=9216 (GeGLU), vocab=256000, sliding window 4096 on local layers,
attn softcap 50, final softcap 30, post-sublayer norms, tied embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=("local", "global"),
    sliding_window=4096,
    mlp="geglu",
    norm="rmsnorm",
    post_norms=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    emb_scale=True,
    tie_embeddings=True,
    attn_scale=1.0 / 16.0,   # gemma2 scales by 1/sqrt(256)=1/16
    sharding_profile="fsdp",
    remat="full",  # measured BEST on the bytes roofline: recompute reads
                   # small gathered weights; "dots"/"none" store+load big
                   # f32 activations instead (see §Perf gemma2 steps 2-3)

    source="arXiv:2408.00118; hf",
    notes="1:1 local:global; global layers hold full KV at 500k (sharded)",
))

ENSEMBLE_NOTES = (
    "Primary RE/SAL population member in examples and Fig.6 kernel-swap bench."
)
