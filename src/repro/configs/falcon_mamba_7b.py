"""falcon-mamba-7b — attention-free Mamba-1 SSM.

[arXiv:2410.05355; unverified]  64L, d_model=4096, d_inner=8192 (expand 2),
ssm_state=16, conv 4, dt_rank=256, vocab=65024.  No attention layers at all;
the per-layer mixer is the selective scan (Pallas kernel kernels/mamba).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,           # unused (attention-free)
    num_kv_heads=1,        # unused
    head_dim=64,           # unused
    d_ff=0,                # mamba blocks have no separate MLP
    vocab_size=65024,
    layer_pattern=("mamba",),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dt_rank=256,
    norm="rmsnorm",
    tie_embeddings=False,
    # channel-parallel TP: mamba channels are independent through the scan,
    # so d_inner shards over "model" collective-free; batch over
    # ("pod","data").  (fsdp profile measured 16x compute replication on the
    # multi-pod mesh: batch 256 < 512 shards — EXPERIMENTS.md §Perf falcon.)
    sharding_profile="tp",
    microbatches=1,
    source="arXiv:2410.05355; unverified",
    notes="attention-free; O(1) decode state; long_500k runs",
))

ENSEMBLE_NOTES = (
    "Attention-inapplicable arch: the paper's orchestration is agnostic; the "
    "selective scan replaces attention as the kernel hot spot."
)
