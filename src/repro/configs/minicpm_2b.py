"""minicpm-2b — llama-like dense; trained with the WSD schedule.

[arXiv:2404.06395; hf:openbmb/MiniCPM-2B]  40L, d_model=2304, 36 heads,
MHA (kv=36), head_dim=64, d_ff=5760 (SwiGLU), vocab=122753.  The paper's WSD
(warmup-stable-decay) LR schedule is implemented in repro.optim.schedules and
selected by this config.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,
    layer_pattern=("global",),
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    sharding_profile="fsdp",
    remat="full",  # measured best on the bytes roofline (§Perf gemma2)

    source="arXiv:2404.06395; hf",
    notes="WSD schedule (repro.optim.schedules.wsd); pure full attention -> "
          "long_500k skipped",
))

SCHEDULE = "wsd"
ENSEMBLE_NOTES = "PBT/RE population member exercising the WSD schedule."
