"""internvl2-26b — InternViT-6B frontend (stubbed) + InternLM2-20B backbone.

[arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B]  48L, d_model=6144, 48 heads,
GQA kv=8, d_ff=16384, vocab=92553.  The vision frontend is a STUB per the
assignment: ``input_specs`` supplies precomputed patch embeddings that replace
the first ``vision_tokens`` positions of the sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    layer_pattern=("global",),
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    vision_tokens=256,
    sharding_profile="tp",
    optstate_dtype="bfloat16",
    microbatches=4,
    remat="full",
    source="arXiv:2404.16821; hf",
    notes="pure full attention -> long_500k skipped (assignment rule)",
))

ENSEMBLE_NOTES = (
    "Paper technique fully applicable: backbone train/serve steps are kernel "
    "plugins (lm.train_step/lm.prefill/lm.decode); VLM frontend stub adds a "
    "vision_embeds input produced by the data plane."
)
