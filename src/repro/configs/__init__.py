"""Arch registry: importing this package registers all assigned architectures."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeSpec,
    cell_applicable,
    get_config,
    input_specs,
    list_configs,
    reduced,
    register,
)

# one module per assigned architecture (filenames use underscores; registry
# names keep the assignment's dashes)
from repro.configs import internvl2_26b      # noqa: F401
from repro.configs import recurrentgemma_2b  # noqa: F401
from repro.configs import gemma2_2b          # noqa: F401
from repro.configs import gemma3_4b          # noqa: F401
from repro.configs import minicpm_2b         # noqa: F401
from repro.configs import nemotron_4_15b     # noqa: F401
from repro.configs import falcon_mamba_7b    # noqa: F401
from repro.configs import whisper_large_v3   # noqa: F401
from repro.configs import qwen3_moe_30b_a3b  # noqa: F401
from repro.configs import grok_1_314b        # noqa: F401
from repro.configs import toy                # noqa: F401

ALL_ARCHS = list_configs()
