"""Content-addressed staging + broadcast fan-out: one blob, N takes.

A producer ensemble streams trajectory-sized payloads into a BROADCAST
channel consumed by two independent analysis ensembles — each analysis
round needs EVERY trajectory (the fan-out the FIFO work-queue cannot
express).  The pilot runs with a ``repro.staging.StagingLayer``:

  - every cycle's payload is staged ONCE into the content-addressed store
    (the channel moves a ``StagedRef``, not the bytes), so the 2-way
    fan-out costs one blob instead of two copies;
  - the scheduler grants analysis tasks slots in pods that already hold
    the trajectory replica, so transfers resolve to pod-local *links*;
  - every move is charged to ``t_data`` — the paper's data term, finally
    visible in the profile (per task and in aggregate).

    PYTHONPATH=src python examples/pst_staged.py          # real kernels
    PYTHONPATH=src python examples/pst_staged.py --sim    # DES, modeled
    PYTHONPATH=src python examples/pst_staged.py --validate-only

Set REPRO_JOURNAL_DIR to journal the run (the CI sanitizer gate replays
the journal's invariants with ``python -m repro.analysis sanitize``).
"""
import argparse
import sys

from repro.core import AppManager, Channel, Kernel, PipelineSpec, Stage, \
    TaskSpec
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import journal_from_env
from repro.staging import LocalityMap, StagingLayer

CYCLES = 3
MEMBERS = 4
SLOTS = MEMBERS + 2
TRAJ_FLOATS = 4096                  # ~36 KB staged payload per cycle
SIM_NBYTES = 256 << 20              # declared member output in DES mode


def kernel(mode, sim_duration, payload=None):
    if mode == "sim":
        k = Kernel("synthetic.noop")
        k.sim_duration = sim_duration
        k.output_nbytes = SIM_NBYTES
        return k
    k = Kernel("synthetic.echo")
    k.arguments = {"value": payload}
    return k


def build(mode):
    traj = Channel("trajectories", mode="broadcast")

    producer = PipelineSpec(
        [Stage([TaskSpec(kernel(mode, 4.0,
                                {"member": m, "cycle": c,
                                 "traj": [0.5] * TRAJ_FLOATS}),
                         name=f"prod.c{c}.md{m}")
                for m in range(MEMBERS)],
               name=f"cycle{c}", outputs=[traj])
         for c in range(CYCLES)], name="producer")

    analyses = [
        PipelineSpec(
            [Stage([TaskSpec(kernel(mode, 1.0, {"ana": w, "round": c}),
                             name=f"{w}.r{c}")],
                   name=f"round{c}", inputs={"traj": traj})
             for c in range(CYCLES)], name=w)
        for w in ("contacts", "rmsd")]
    return [producer, *analyses], traj


def validate_only(mode) -> int:
    """Pre-flight lint of the declared pipelines; no task launches."""
    from repro.analysis import validate_app
    pipes, _traj = build(mode)
    report = validate_app(pipes)
    print(report.format())
    return 0 if report.ok else 1


def main(mode):
    staging = StagingLayer(
        locality=LocalityMap(SLOTS, slots_per_pod=SLOTS // 2),
        threshold_bytes=1 << 10)
    # journal name carries the mode: a sim journal must not be replayed
    # into a real run (same task names would be skipped as already done)
    rt = PilotRuntime(slots=SLOTS, mode=mode, staging=staging,
                      journal=journal_from_env(f"pst_staged_{mode}"))
    am = AppManager(rt)
    pipes, traj = build(mode)
    prof = am.run(pipes, validate="error")

    print(f"mode={mode}: ttc={prof.ttc:.2f}s, {prof.n_tasks} tasks, "
          f"t_data={prof.t_data:.4f}s")
    for name, info in prof.results["pipelines"].items():
        print(f"  {name}: {info['state']} after {info['n_tasks']} tasks")
    assert all(info["state"] == "done"
               for info in prof.results["pipelines"].values())
    assert prof.n_failed == 0

    # broadcast fan-out: one staged blob per cycle, taken by BOTH analyses
    assert len(traj.puts) == CYCLES
    assert traj.n_unconsumed() == 0
    summ = prof.results["staging"]
    tr = summ["transfers"]
    print(f"  staged blobs: {summ['store']['puts']} "
          f"(fan-out takes: {tr['n_transfers']})")
    print(f"  transfers: {tr['link']} link / {tr['copy']} copy / "
          f"{tr['materialize']} materialize -> "
          f"locality hit-rate {tr['locality_hit_rate']:.2f}")
    per_task = {n: round(t.t_data, 5)
                for n, t in am.session.graph.tasks.items() if t.t_data}
    print(f"  per-task t_data (charged tasks): {per_task}")

    # the acceptance property: the pod-local link path avoided copies
    assert tr["locality_hit_rate"] > 0, \
        "expected pod-local links on the broadcast fan-out"
    assert summ["store"]["puts"] == CYCLES, \
        "each cycle's payload must be staged exactly once"
    if mode == "real":
        # both consumers saw the SAME staged payload, by value
        a = prof.results["tasks"]["contacts.r0"]["inputs"]["traj"]
        b = prof.results["tasks"]["rmsd.r0"]["inputs"]["traj"]
        assert a == b and a["prod.c0.md0"]["value"]["cycle"] == 0
        print("  broadcast consumers dereferenced identical payloads: ok")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim", action="store_true",
                    help="DES mode: modeled durations and transfer costs")
    ap.add_argument("--validate-only", action="store_true",
                    help="lint the declared pipelines and exit (no run)")
    args = ap.parse_args()
    mode = "sim" if args.sim else "real"
    if args.validate_only:
        sys.exit(validate_only(mode))
    main(mode)
