"""Quickstart: the paper's five-step application flow (Fig. 1) on the
paper's own character-count workload.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Kernel, Pipeline, SingleClusterEnvironment


# Step 1: pick the execution pattern that matches the application
class CharCountApp(Pipeline):
    # Step 2: fill the stages with kernel plugins
    def stage_1(self, instance):
        k = Kernel("misc.mkfile")
        k.arguments = {"bytes": 1 << 20, "seed": instance}
        return k

    def stage_2(self, instance):
        return Kernel("misc.ccount")   # consumes stage_1's output


def main():
    # Step 3: create the resource handler and allocate the pilot
    cluster = SingleClusterEnvironment(
        resource="local.cpu",   # on a fleet: "tpu.v5e-256"
        cores=16,
        walltime=10,
    )
    cluster.allocate()

    # Step 4: run the pattern (execution plugin binds kernels to tasks)
    app = CharCountApp(stages=2, instances=16)
    profile = cluster.run(app)

    # Step 5: control returns; deallocate
    cluster.deallocate()

    print("TTC decomposition (paper eq. 1-2):")
    for k, v in profile.summary().items():
        print(f"  {k:22s} {v}")
    print(f"  t_enmd_overhead        {profile.t_enmd_overhead:.6f}")
    some = next(v for k, v in profile.results["tasks"].items()
                if k.endswith("stage2"))
    print(f"example ccount result: {some}")


if __name__ == "__main__":
    main()
