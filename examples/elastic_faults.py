"""Fault-tolerance showcase: injected task failures with bounded retries,
straggler speculation, elastic pilot resize, and journal-based restart —
all at the ensemble layer where the paper's contribution lives.

    PYTHONPATH=src python examples/elastic_faults.py
"""
import tempfile

from repro.core import BagOfTasks, Kernel, SingleClusterEnvironment
from repro.runtime.executor import PilotRuntime
from repro.runtime.journal import Journal
from repro.runtime.states import Task, TaskGraph


class FlakyBag(BagOfTasks):
    def task(self, i):
        if i % 5 == 0:
            k = Kernel("synthetic.fail")
            k.arguments = {"fail_times": 1}     # fails once, then recovers
        else:
            k = Kernel("misc.mkfile")
            k.arguments = {"bytes": 1 << 12, "seed": i}
        return k


def main():
    print("== 1) bounded retries recover injected failures ==")
    cl = SingleClusterEnvironment(cores=4, max_retries=2)
    cl.allocate()
    prof = cl.run(FlakyBag(instances=10))
    cl.deallocate()
    print(f"  {prof.n_tasks} tasks, {prof.n_retries} retries, "
          f"{prof.n_failed} permanently failed")
    assert prof.n_failed == 0

    print("== 2) straggler speculation (DES) ==")
    g = TaskGraph()
    for i in range(16):
        g.add(Task(name=f"t{i}", duration=100.0 if i == 15 else 10.0,
                   stage="sim"))
    prof = PilotRuntime(slots=8, mode="sim", straggler_factor=2.0).run(g)
    print(f"  makespan {prof.ttc:.0f}s with {prof.n_speculative} "
          "speculative duplicate(s) (vs 110s unmitigated)")

    print("== 3) elastic resize mid-run ==")
    rt = PilotRuntime(slots=2, mode="sim")
    rt.resize(8)      # grow before next scheduling step
    g = TaskGraph()
    for i in range(16):
        g.add(Task(name=f"t{i}", duration=10.0))
    prof = rt.run(g)
    print(f"  makespan {prof.ttc:.0f}s after growing 2 -> 8 slots")

    print("== 4) journal restart: crashed run resumes, done work skipped ==")
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/journal.jsonl"
        g1 = TaskGraph()
        for i in range(6):
            g1.add(Task(name=f"t{i}", duration=5.0))
        PilotRuntime(slots=2, mode="sim", journal=Journal(path)).run(g1)
        # "restart": same pattern, same journal
        g2 = TaskGraph()
        for i in range(6):
            g2.add(Task(name=f"t{i}", duration=5.0))
        prof = PilotRuntime(slots=2, mode="sim",
                            journal=Journal(path)).run(g2)
        print(f"  restarted makespan {prof.ttc:.0f}s "
              "(all tasks replayed from journal)")


if __name__ == "__main__":
    main()
