"""Fault-tolerance showcase + chaos bench: injected task failures with
bounded retries, straggler speculation, elastic pilot resize, journal-based
restart — and pod death as a NORMAL event during a 1000-member coupled
ensemble, with retries re-placed off the dead pod and TTC degrading
gracefully instead of the run aborting.

    PYTHONPATH=src python examples/elastic_faults.py [--fast]
    PYTHONPATH=src python examples/elastic_faults.py --validate-only

Set REPRO_JOURNAL_DIR to journal the bag-of-tasks and chaos runs (the CI
sanitizer gate replays the journals' invariants afterwards).

Emits BENCH_faults.json (repo root): fault-free baseline vs chaos run
(a pod killed every KILL_EVERY virtual seconds, replacement pods joining
RESPAWN_AFTER seconds later) over the same coupled producer/analysis
workload.  Fails loudly unless the chaos run finishes every task
(n_failed == 0), in-flight attempts were actually lost and retried off
their dead pods, and TTC stays under 2x the fault-free baseline.
"""
import argparse
import json
import os
import sys
import tempfile

from repro.core import AppManager, BagOfTasks, Channel, Kernel, \
    PipelineSpec, SingleClusterEnvironment, Stage, TaskSpec
from repro.runtime.executor import PilotRuntime
from repro.runtime.faults import FaultInjector
from repro.runtime.journal import Journal, journal_from_env
from repro.runtime.states import Task, TaskGraph
from repro.staging import LocalityMap, StagingLayer

SLOTS = 16
PODS = 4
MEMBER_NBYTES = 64 << 20
FULL = dict(pipelines=4, cycles=25, members=10)   # 1000 members + 100 ana
FAST = dict(pipelines=2, cycles=5, members=4)     # 40 members + 10 ana
# virtual seconds between pod kills / until the replacement pod joins,
# scaled so the shorter fast run still sees several kills
CADENCE = {"full": (15.0, 8.0), "fast": (1.5, 1.0)}


class FlakyBag(BagOfTasks):
    def task(self, i):
        if i % 5 == 0:
            k = Kernel("synthetic.fail")
            k.arguments = {"fail_times": 1}     # fails once, then recovers
        else:
            k = Kernel("misc.mkfile")
            k.arguments = {"bytes": 1 << 12, "seed": i}
        return k


# ------------------------------------------------------------------ chaos
def _member(dur=1.0, nbytes=MEMBER_NBYTES):
    k = Kernel("synthetic.noop")
    k.sim_duration = dur
    k.output_nbytes = nbytes
    return k


def _coupled(pipelines, cycles, members):
    """P producer ensembles streaming cycle outputs into channels consumed
    by P analysis pipelines (the staging bench's coupled shape)."""
    pipes = []
    for p in range(pipelines):
        ch = Channel(f"traj{p}")
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(), name=f"p{p}.c{c}.m{m}")
                    for m in range(members)],
                   name=f"cycle{c}", outputs=[ch])
             for c in range(cycles)], name=f"producer{p}"))
        pipes.append(PipelineSpec(
            [Stage([TaskSpec(_member(dur=0.5, nbytes=0),
                             name=f"a{p}.r{c}")],
                   name=f"round{c}", inputs={"traj": ch})
             for c in range(cycles)], name=f"analysis{p}"))
    return pipes


def _chaos_run(sizes, faults=None, journal_name="faults_baseline"):
    staging = StagingLayer(
        locality=LocalityMap(SLOTS, slots_per_pod=SLOTS // PODS),
        threshold_bytes=1024)
    # distinct journal names per run: baseline and chaos share task names,
    # so one file would make the second run replay the first's results
    rt = PilotRuntime(slots=SLOTS, mode="sim", staging=staging,
                      faults=faults, max_retries=3,
                      journal=journal_from_env(journal_name))
    am = AppManager(rt)
    prof = am.run(_coupled(**sizes), validate="error")
    return prof, am, rt


def _retry_placement(graph):
    """(off, back): tasks whose successful attempt ran off every pod a
    pod-loss blamed, vs tasks that landed back on one (legitimate only
    after the replacement pod joined or when nothing else was free)."""
    off = back = 0
    for t in graph.tasks.values():
        lost = {h["pod"] for h in t.history
                if h["outcome"] in ("pod_lost", "worker_died") and h["pod"]}
        if not lost:
            continue
        done = [h for h in t.history if h["outcome"] == "done"]
        if not done:
            continue
        if done[-1]["pod"] in lost:
            back += 1
        else:
            off += 1
    return off, back


def chaos_bench(fast=False):
    sizes = FAST if fast else FULL
    kill_every, respawn_after = CADENCE["fast" if fast else "full"]
    n_members = sizes["pipelines"] * sizes["cycles"] * sizes["members"]
    print(f"== 5) chaos bench: pod kill every {kill_every:g}s over "
          f"{n_members} coupled members ==")

    base_prof, _, base_rt = _chaos_run(sizes)
    base_rt.close()
    print(f"  fault-free: ttc={base_prof.ttc:.1f}s "
          f"n_failed={base_prof.n_failed}")

    faults = FaultInjector(kill_every=kill_every,
                           respawn_after=respawn_after)
    prof, am, rt = _chaos_run(sizes, faults=faults,
                              journal_name="faults_chaos")
    off, back = _retry_placement(am.session.graph)
    n_gc = rt.close()
    ratio = prof.ttc / max(base_prof.ttc, 1e-12)
    print(f"  chaos     : ttc={prof.ttc:.1f}s ({ratio:.2f}x) "
          f"kills={faults.n_kills} attempts_lost={prof.n_pod_lost} "
          f"retries={prof.n_retries} n_failed={prof.n_failed}")
    print(f"  retries off dead pod: {off}; back on revived pod: {back}; "
          f"spill files GCed at close: {n_gc}")

    out = {
        "slots": SLOTS, "pods": PODS,
        "kill_every_s": kill_every, "respawn_after_s": respawn_after,
        "sizes": sizes,
        "baseline": {"ttc": round(base_prof.ttc, 3),
                     "n_tasks": base_prof.n_tasks,
                     "n_failed": base_prof.n_failed,
                     "t_data": round(base_prof.t_data, 4)},
        "chaos": {"ttc": round(prof.ttc, 3), "n_tasks": prof.n_tasks,
                  "n_failed": prof.n_failed,
                  "n_kills": faults.n_kills,
                  "n_pod_lost": prof.n_pod_lost,
                  "n_retries": prof.n_retries,
                  "t_data": round(prof.t_data, 4),
                  "retried_off_dead_pod": off,
                  "retried_on_revived_pod": back,
                  "pipelines": prof.results["pipelines"]},
        "summary": {"ttc_degradation": round(ratio, 4)},
    }
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    with open(os.path.join(root, "BENCH_faults.json"), "w") as f:
        json.dump(out, f, indent=1)

    assert prof.n_failed == 0, \
        f"{prof.n_failed} tasks permanently failed under chaos"
    assert faults.n_kills > 0 and prof.n_pod_lost > 0, \
        "chaos run lost no in-flight attempts — kills missed all work"
    assert off > 0, "no retry demonstrably re-placed off its dead pod"
    assert ratio < 2.0, \
        f"TTC degraded {ratio:.2f}x under chaos (>= 2x baseline)"
    return out


# ------------------------------------------------------------------ main
def validate_only(fast=False) -> int:
    """Pre-flight lint of the chaos bench's coupled pipelines."""
    from repro.analysis import validate_app
    report = validate_app(_coupled(**(FAST if fast else FULL)))
    print(report.format())
    return 0 if report.ok else 1


def main(fast=False):
    print("== 1) bounded retries recover injected failures ==")
    cl = SingleClusterEnvironment(
        cores=4, max_retries=2,
        database_url=os.environ.get("REPRO_JOURNAL_DIR"),
        database_name="faults_bag")
    cl.allocate()
    prof = cl.run(FlakyBag(instances=10))
    cl.deallocate()
    print(f"  {prof.n_tasks} tasks, {prof.n_retries} retries, "
          f"{prof.n_failed} permanently failed")
    assert prof.n_failed == 0

    print("== 2) straggler speculation (DES) ==")
    g = TaskGraph()
    for i in range(16):
        g.add(Task(name=f"t{i}", duration=100.0 if i == 15 else 10.0,
                   stage="sim"))
    prof = PilotRuntime(slots=8, mode="sim", straggler_factor=2.0).run(g)
    print(f"  makespan {prof.ttc:.0f}s with {prof.n_speculative} "
          "speculative duplicate(s) (vs 110s unmitigated)")

    print("== 3) elastic resize mid-run ==")
    rt = PilotRuntime(slots=2, mode="sim")
    rt.resize(8)      # grow before next scheduling step
    g = TaskGraph()
    for i in range(16):
        g.add(Task(name=f"t{i}", duration=10.0))
    prof = rt.run(g)
    print(f"  makespan {prof.ttc:.0f}s after growing 2 -> 8 slots")

    print("== 4) journal restart: crashed run resumes, done work skipped ==")
    with tempfile.TemporaryDirectory() as d:
        path = f"{d}/journal.jsonl"
        g1 = TaskGraph()
        for i in range(6):
            g1.add(Task(name=f"t{i}", duration=5.0))
        PilotRuntime(slots=2, mode="sim", journal=Journal(path)).run(g1)
        # "restart": same pattern, same journal
        g2 = TaskGraph()
        for i in range(6):
            g2.add(Task(name=f"t{i}", duration=5.0))
        prof = PilotRuntime(slots=2, mode="sim",
                            journal=Journal(path)).run(g2)
        print(f"  restarted makespan {prof.ttc:.0f}s "
              "(all tasks replayed from journal)")

    chaos_bench(fast=fast)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small chaos sizes (CI smoke)")
    ap.add_argument("--validate-only", action="store_true",
                    help="lint the chaos pipelines and exit (no run)")
    args = ap.parse_args()
    if args.validate_only:
        sys.exit(validate_only(fast=args.fast))
    main(fast=args.fast)
