"""PST showcase: workloads the 2016 hook API structurally could not express.

1. Heterogeneous coupled ensembles — two replica-exchange ensembles with
   very different cycle times run over ONE pilot session; the fast ensemble
   streams through its cycles inside the slack of the slow one (no global
   barrier, no per-cycle graph teardown).
2. Adaptive sampling — an analysis stage inspects its results and keeps
   appending refinement stages until converged (the pipeline grows at
   runtime via Stage.on_done).

Everything runs in DES (sim) mode: durations are modeled, scheduling is
real, so the printout shows true interleavings instantly.

    PYTHONPATH=src python examples/pst_adaptive.py
"""
from repro.core import AppManager, Kernel, PipelineSpec, Stage, TaskSpec
from repro.runtime.executor import PilotRuntime


def kernel(sim_duration):
    k = Kernel("synthetic.noop")
    k.sim_duration = sim_duration
    return k


def re_ensemble(name, members, cycles, sim_dur, x_dur, log):
    """Replica exchange as PST: each exchange's on_done appends the next
    cycle — lazily, after this cycle's result is known."""
    def cycle_stages(c):
        sims = Stage([TaskSpec(kernel(sim_dur), name=f"{name}.c{c}.md{i}")
                      for i in range(members)], name="simulation")

        def on_exchange(stage, pipe):
            log.append((name, c))
            if c + 1 < cycles:
                pipe.extend(cycle_stages(c + 1))

        return [sims, Stage([TaskSpec(kernel(x_dur), name=f"{name}.c{c}.x")],
                            name="exchange", on_done=on_exchange)]

    return PipelineSpec(cycle_stages(0), name=name)


def adaptive_sampler(name, log, max_rounds=6):
    """Simulate-analyze that decides AT RUNTIME how many rounds it needs."""
    def round_stages(r):
        sim = Stage([TaskSpec(kernel(2.0), name=f"{name}.r{r}.sim{i}")
                     for i in range(4)], name="simulation")

        def on_analysis(stage, pipe):
            # toy convergence signal: pretend variance halves per round
            converged = (0.5 ** r) < 0.1
            log.append((name, r, "converged" if converged else "refine"))
            if not converged and r + 1 < max_rounds:
                pipe.extend(round_stages(r + 1))

        ana = Stage([TaskSpec(kernel(0.5), name=f"{name}.r{r}.ana")],
                    name="analysis", on_done=on_analysis)
        return [sim, ana]

    return PipelineSpec(round_stages(0), name=name)


def main():
    rt = PilotRuntime(slots=8, mode="sim")
    log = []
    fast = re_ensemble("fast_re", members=2, cycles=6, sim_dur=1.0,
                       x_dur=0.1, log=log)
    slow = re_ensemble("slow_re", members=2, cycles=2, sim_dur=20.0,
                       x_dur=0.5, log=log)
    adaptive = adaptive_sampler("adaptive", log)
    am = AppManager(rt)
    prof = am.run([fast, slow, adaptive])

    print("event order (one shared pilot session, virtual time):")
    for ev in log:
        print("  ", ev)
    pipes = prof.results["pipelines"]
    print(f"\nttc={prof.ttc:.1f}s virtual, {prof.n_tasks} tasks, "
          f"utilization={prof.utilization:.2f}")
    for name, info in pipes.items():
        print(f"  {name}: {info['state']} after {info['n_tasks']} tasks")
    # the fast ensemble finished all 6 cycles before the slow one's first
    # exchange — impossible under the legacy one-graph-per-cycle barrier
    assert log.index(("fast_re", 5)) < log.index(("slow_re", 0))
    print("\nfast_re streamed 6 cycles inside slow_re's first cycle: "
          "no global barrier")


if __name__ == "__main__":
    main()
