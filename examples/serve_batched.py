"""Batched serving example: a reduced model serving greedy-decoded requests
through the continuous-batching-lite server.

    PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serve import BatchedServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, batch=args.batch,
                        prompt_len=args.prompt_len,
                        max_len=args.prompt_len + args.new_tokens + 1)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    srv.submit(reqs)
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0

    lat = [r.done_at - r.submitted_at for r in done]
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s incl. compile)")
    print(f"stats: {srv.stats}")
    print(f"latency p50={np.percentile(lat, 50):.3f}s "
          f"p95={np.percentile(lat, 95):.3f}s")
    print(f"request 0 tokens: {done[0].out_tokens}")


if __name__ == "__main__":
    main()
