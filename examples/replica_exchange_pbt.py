"""Replica-exchange ensemble training (parallel tempering over learning
rates), in BOTH execution modes:

  task mode  - paper-faithful: each replica is a scheduled task; exchange is
               a barrier task (RADICAL-Pilot style).
  fused mode - beyond-paper: the whole population is ONE SPMD program;
               exchange happens on-device (O(1) dispatch per cycle).

    PYTHONPATH=src python examples/replica_exchange_pbt.py [--members 4]
"""
import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec
from repro.core import (FusedEnsemble, Kernel, ReplicaExchange,
                        SingleClusterEnvironment)

SHAPE = ShapeSpec("pbt", "train", 64, 2)


class TaskModePBT(ReplicaExchange):
    def __init__(self, cycles, replicas):
        super().__init__(cycles, replicas)
        self.temps = [3e-4 * 1.4 ** i for i in range(replicas)]

    def prepare_replica_for_md(self, r):
        k = Kernel("lm.train")
        k.arguments = {"arch": "reduced:gemma2-2b", "steps": 2,
                       "member": r.id, "ensemble": "ex_pbt",
                       "lr": self.temps[r.id], "batch": 2, "seq": 64}
        return k

    def prepare_exchange(self, replicas):
        k = Kernel("re.exchange")
        k.arguments = {"replicas": len(replicas),
                       "cycle": replicas[0].cycle, "temps": self.temps,
                       "ensemble": "ex_pbt"}
        return k

    def apply_exchange(self, result, replicas):
        self.temps = result["temps"]
        print(f"  cycle {result['cycle']}: losses="
              f"{[round(l, 3) for l in result['losses']]} "
              f"accepted={result['accepted']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=3)
    args = ap.parse_args()

    print(f"== task mode ({args.members} members, {args.cycles} cycles) ==")
    cl = SingleClusterEnvironment(cores=args.members)
    cl.allocate()
    t0 = time.perf_counter()
    prof = cl.run(TaskModePBT(args.cycles, args.members))
    cl.deallocate()
    print(f"task-mode TTC={prof.ttc:.2f}s "
          f"dispatch-overhead={prof.t_enmd_overhead:.4f}s "
          f"({prof.n_tasks} tasks)")

    print("\n== fused SPMD mode ==")
    cfg = reduced(get_config("gemma2-2b"))
    fe = FusedEnsemble(cfg, args.members)
    t0 = time.perf_counter()
    ens, hist = fe.run(jax.random.PRNGKey(0), cycles=args.cycles,
                       steps_per_cycle=2, shape=SHAPE)
    dt = time.perf_counter() - t0
    for c, h in enumerate(hist):
        print(f"  cycle {c}: losses="
              f"{[round(float(x), 3) for x in h['losses']]} "
              f"accepted={int(h['accepted'])}")
    print(f"fused-mode wall={dt:.2f}s (includes one-time jit compile); "
          "dispatch per cycle is a single program launch")


if __name__ == "__main__":
    main()
